"""Attention paths agree with the dense reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.lm import attention as A

K = jax.random.PRNGKey(0)


def qkv(B=2, S=128, Hq=8, Hkv=2, hd=32, key=K):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, hd))
    k = jax.random.normal(ks[1], (B, S, Hkv, hd))
    v = jax.random.normal(ks[2], (B, S, Hkv, hd))
    return q, k, v


def test_chunked_matches_full_causal():
    q, k, v = qkv()
    o_full = A.attend_full(q, k, v, causal=True)
    o_chunk = A.attend_chunked(q, k, v, causal=True, chunk=32)
    np.testing.assert_allclose(np.asarray(o_full), np.asarray(o_chunk),
                               rtol=2e-4, atol=2e-4)


def test_chunked_matches_full_bidirectional():
    q, k, v = qkv()
    o_full = A.attend_full(q, k, v, causal=False)
    o_chunk = A.attend_chunked(q, k, v, causal=False, chunk=64)
    np.testing.assert_allclose(np.asarray(o_full), np.asarray(o_chunk),
                               rtol=2e-4, atol=2e-4)


def test_local_matches_full_window_mask():
    q, k, v = qkv(S=128)
    W = 32
    o_full = A.attend_full(q, k, v, causal=True, window=W)
    o_loc = A.attend_local(q, k, v, window=W)
    np.testing.assert_allclose(np.asarray(o_full), np.asarray(o_loc),
                               rtol=2e-4, atol=2e-4)


def test_chunked_grad_finite():
    q, k, v = qkv(S=64)

    def loss(q):
        return jnp.sum(A.attend_chunked(q, k, v, causal=True, chunk=16) ** 2)
    g = jax.grad(loss)(q)
    assert np.all(np.isfinite(np.asarray(g)))


def test_decode_matches_full_last_position():
    q, k, v = qkv(S=64)
    o_full = A.attend_full(q, k, v, causal=True)
    o_dec = A.attend_decode(q[:, -1:], k, v, pos=63)
    np.testing.assert_allclose(np.asarray(o_full[:, -1:]), np.asarray(o_dec),
                               rtol=2e-4, atol=2e-4)


def test_decode_windowed_ring():
    """Ring-buffer local decode == full attention restricted to window."""
    B, S, Hq, Hkv, hd, W = 1, 96, 4, 2, 16, 32
    q, k, v = qkv(B, S, Hq, Hkv, hd)
    # build ring cache holding the last W keys at pos = S-1
    pos = S - 1
    ring_idx = (jnp.arange(pos - W + 1, pos + 1)) % W
    kc = jnp.zeros((B, W, Hkv, hd)).at[:, ring_idx].set(k[:, pos - W + 1: pos + 1])
    vc = jnp.zeros((B, W, Hkv, hd)).at[:, ring_idx].set(v[:, pos - W + 1: pos + 1])
    o_dec = A.attend_decode(q[:, -1:], kc, vc, pos, window=W)
    o_full = A.attend_full(q, k, v, causal=True, window=W)[:, -1:]
    np.testing.assert_allclose(np.asarray(o_dec), np.asarray(o_full),
                               rtol=2e-4, atol=2e-4)


def test_rope_rotation_preserves_norm():
    x = jax.random.normal(K, (2, 16, 4, 32))
    cos, sin = A.rope_frequencies(32, 10_000.0, jnp.arange(16))
    y = A.apply_rope(x, cos, sin)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-4)


def test_rope_relative_property():
    """<rope(q,m), rope(k,n)> depends only on m-n."""
    hd = 64
    q = jax.random.normal(K, (1, 1, 1, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, hd))

    def dot_at(m, n):
        cm, sm = A.rope_frequencies(hd, 1e4, jnp.array([m]))
        cn, sn = A.rope_frequencies(hd, 1e4, jnp.array([n]))
        qq = A.apply_rope(q, cm, sm)
        kk = A.apply_rope(k, cn, sn)
        return float(jnp.sum(qq * kk))
    assert np.isclose(dot_at(5, 3), dot_at(10, 8), rtol=1e-4)
    assert np.isclose(dot_at(7, 0), dot_at(107, 100), rtol=1e-4)
