"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import zebra_mask_op, zebra_spmm_op, zebra_ffn_hidden
from repro.kernels import ref

K = jax.random.PRNGKey(0)


def _blocky(key, M, Kd, bs, bc, live_p=0.5, dtype=jnp.float32):
    """Activations with genuine zero-block structure (>=1 live, >=1 dead)."""
    x = jax.random.normal(key, (M, Kd), jnp.float32)
    scale = (jax.random.uniform(jax.random.PRNGKey(7), (M // bs, Kd // bc))
             < live_p).astype(jnp.float32)
    scale = scale.at[0, 0].set(1.0)            # force one live block
    if scale.size > 1:
        scale = scale.reshape(-1).at[-1].set(0.0).reshape(scale.shape)
    x = x * jnp.repeat(jnp.repeat(scale, bs, 0), bc, 1) * 2.0 + x * 0.01
    return x.astype(dtype)


@pytest.mark.parametrize("M,Kd,bs,bc", [
    (16, 128, 8, 128), (64, 512, 8, 128), (128, 256, 16, 64),
    (256, 1024, 8, 256), (24, 384, 8, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_zebra_mask_sweep(M, Kd, bs, bc, dtype):
    x = _blocky(K, M, Kd, bs, bc, dtype=dtype)
    y, bm = zebra_mask_op(x, 0.5, bs=bs, bc=bc)
    yr, bmr = ref.zebra_mask_ref(x, 0.5, bs, bc)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(bm), np.asarray(bmr))
    assert 0.0 < 1 - np.mean(np.asarray(bmr)) < 1.0   # sparsity exercised


@pytest.mark.parametrize("M,Kd,N", [(16, 256, 128), (64, 512, 256), (32, 384, 64)])
def test_zebra_spmm_sweep(M, Kd, N):
    bs, bc = 8, 128
    x = _blocky(K, M, Kd, bs, bc)
    w = jax.random.normal(jax.random.PRNGKey(1), (Kd, N), jnp.float32)
    _, bm = zebra_mask_op(x, 0.5, bs=bs, bc=bc)
    y = zebra_spmm_op(x, w, bm, bs=bs, bc=bc)
    yr = ref.zebra_spmm_ref(x, w, np.asarray(bm), bs, bc)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-4, atol=1e-4)


def test_fused_ffn_hidden():
    x = _blocky(K, 64, 512, 8, 128)
    w = jax.random.normal(jax.random.PRNGKey(2), (512, 128), jnp.float32)
    y, bm = zebra_ffn_hidden(x, w, 0.5)
    yr, bmr = ref.zebra_mask_then_spmm_ref(x, w, 0.5, 8, 128)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(bm), np.asarray(bmr))


def test_spmm_skips_dead_blocks_exactly():
    """A dead block's x values must not leak into the product even if the
    raw (pre-mask) x is nonzero there."""
    bs, bc = 8, 128
    x = jnp.ones((16, 256), jnp.float32) * 0.01       # all below threshold
    x = x.at[:8, :128].set(5.0)                       # one live block
    w = jnp.ones((256, 64), jnp.float32)
    _, bm = zebra_mask_op(x, 0.5, bs=bs, bc=bc)
    assert int(np.asarray(bm).sum()) == 1
    y = zebra_spmm_op(x, w, bm, bs=bs, bc=bc)
    np.testing.assert_allclose(np.asarray(y[:8]), 5.0 * 128, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(y[8:]), 0.0, atol=1e-6)


def test_spmm_entire_bitmap_row_dead():
    """Revolving-door kmap edge case: when every block in a bitmap row is
    dead, the associative-scan kmap degenerates to all-zeros for that row
    (always 'replaying' K-block 0). The pl.when guard must still keep the
    output row exactly zero, and live rows must be unaffected."""
    bs, bc = 8, 128
    x = jax.random.normal(K, (24, 256), jnp.float32)
    bm = jnp.asarray([[1, 1], [0, 0], [1, 0]], jnp.int8)   # row 1 fully dead
    w = jax.random.normal(jax.random.PRNGKey(3), (256, 64), jnp.float32)
    y = zebra_spmm_op(x, w, bm, bs=bs, bc=bc)
    yr = ref.zebra_spmm_ref(x, w, np.asarray(bm), bs, bc)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(y[8:16]), 0.0)
    assert float(np.abs(np.asarray(y[:8])).max()) > 0.0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**30), live=st.floats(0.1, 0.9))
def test_property_mask_then_spmm_equals_dense_masked(seed, live):
    bs, bc = 8, 128
    x = _blocky(jax.random.PRNGKey(seed), 32, 256, bs, bc, live)
    w = jax.random.normal(jax.random.PRNGKey(seed + 1), (256, 32), jnp.float32)
    y, _ = zebra_ffn_hidden(x, w, 0.5)
    ymask, _ = ref.zebra_mask_ref(x, 0.5, bs, bc)
    dense = np.asarray(ymask, np.float32) @ np.asarray(w)
    np.testing.assert_allclose(np.asarray(y), dense, rtol=1e-4, atol=1e-4)
