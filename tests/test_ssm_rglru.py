"""Mamba-2 SSD and RG-LRU vs naive sequential recurrences."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm.config import LMConfig
from repro.models.lm import ssm as S
from repro.models.lm import rglru as R

K = jax.random.PRNGKey(0)

CFG = LMConfig(name="t", d_model=32, n_layers=1, layer_pattern=("ssm",),
               d_ff=0, vocab=64, ssm_state=8, ssm_head_dim=8, ssm_expand=2,
               ssm_chunk=8, head_dim=8, zebra_enabled=False)


def naive_ssd(p, hidden, cfg):
    """Sequential reference: h_t = h_{t-1} * exp(dt A) + dt B x; y = C h + Dx."""
    B, Sq, d = hidden.shape
    di, ds, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xr, Bm, Cm, dt = S._projections(p, hidden)
    xr = jax.nn.silu(S._causal_conv1d(xr, p["conv_x"]))
    Bm = jax.nn.silu(S._causal_conv1d(Bm, p["conv_b"]))
    Cm = jax.nn.silu(S._causal_conv1d(Cm, p["conv_c"]))
    xs = np.asarray(xr.reshape(B, Sq, nh, hd), np.float64)
    Bn = np.asarray(Bm, np.float64)
    Cn = np.asarray(Cm, np.float64)
    A = -np.exp(np.asarray(p["A_log"], np.float64))
    dtv = np.log1p(np.exp(np.asarray(dt, np.float64) + np.asarray(p["dt_bias"], np.float64)))
    H = np.zeros((B, nh, ds, hd))
    ys = np.zeros((B, Sq, nh, hd))
    for t in range(Sq):
        decay = np.exp(dtv[:, t] * A[None, :])                    # (B,nh)
        H = H * decay[..., None, None] + np.einsum(
            "bs,bh,bhp->bhsp", Bn[:, t], dtv[:, t], xs[:, t])
        ys[:, t] = np.einsum("bs,bhsp->bhp", Cn[:, t], H) \
            + np.asarray(p["D"])[None, :, None] * xs[:, t]
    y = ys.reshape(B, Sq, di)
    y = y * np.asarray(jax.nn.silu(z), np.float64)
    from repro.models.layers import rmsnorm_apply
    y = np.asarray(rmsnorm_apply(p["out_norm"], jnp.asarray(y, jnp.float32)))
    return y @ np.asarray(p["out_proj"])


def test_ssd_chunked_matches_naive():
    p = S.ssm_init(K, CFG, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32)) * 0.5
    y = S.ssm_apply(p, x, CFG)
    y_ref = naive_ssd(p, x, CFG)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-3, atol=2e-3)


def test_ssd_decode_matches_full():
    p = S.ssm_init(K, CFG, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 16, 32)) * 0.5
    y_full = S.ssm_apply(p, x, CFG)
    cache = S.ssm_init_cache(CFG, 1, jnp.float32)
    outs = []
    for t in range(16):
        y, cache = S.ssm_decode_step(p, x[:, t:t+1], cache, CFG)
        outs.append(y)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_seq),
                               rtol=2e-3, atol=2e-3)


def test_ssd_prefill_state_matches_decode_state():
    p = S.ssm_init(K, CFG, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 24, 32)) * 0.5
    st = S.ssm_prefill_state(p, x, CFG)
    cache = S.ssm_init_cache(CFG, 1, jnp.float32)
    for t in range(24):
        _, cache = S.ssm_decode_step(p, x[:, t:t+1], cache, CFG)
    np.testing.assert_allclose(np.asarray(st["H"]), np.asarray(cache["H"]),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(st["conv_x"]),
                               np.asarray(cache["conv_x"]), rtol=1e-5)


RCFG = LMConfig(name="t", d_model=32, n_layers=1, layer_pattern=("rglru",),
                d_ff=64, vocab=64, lru_dim=32, zebra_enabled=False)


def naive_rglru(p, x, cfg):
    gate = jax.nn.gelu(x @ p["w_gate_branch"])
    u = R._causal_conv1d(x @ p["w_rec_branch"], p["conv_w"])
    a, b = R._gates(p, u)
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    h = np.zeros_like(a[:, 0])
    hs = np.zeros_like(a)
    for t in range(a.shape[1]):
        h = a[:, t] * h + b[:, t]
        hs[:, t] = h
    return (hs.astype(np.float32) * np.asarray(gate)) @ np.asarray(p["w_out"])


def test_rglru_scan_matches_naive():
    p = R.rglru_init(K, RCFG, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 20, 32)) * 0.5
    y = R.rglru_apply(p, x, RCFG)
    np.testing.assert_allclose(np.asarray(y), naive_rglru(p, x, RCFG),
                               rtol=2e-3, atol=2e-3)


def test_rglru_decode_matches_full():
    p = R.rglru_init(K, RCFG, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 12, 32)) * 0.5
    y_full = R.rglru_apply(p, x, RCFG)
    cache = R.rglru_init_cache(RCFG, 1, jnp.float32)
    outs = []
    for t in range(12):
        y, cache = R.rglru_decode_step(p, x[:, t:t+1], cache, RCFG)
        outs.append(y)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.concatenate(outs, 1)),
                               rtol=2e-3, atol=2e-3)
