"""CNN zoo smoke tests + Network Slimming + Weight Pruning units."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ZebraConfig, slimming, weight_pruning
from repro.models.cnn import build as build_cnn

K = jax.random.PRNGKey(0)


@pytest.mark.parametrize("name", ["vgg16", "resnet18", "resnet56", "mobilenet"])
def test_cnn_forward_shapes_no_nan(name):
    model = build_cnn(name, num_classes=10, in_hw=32, width_mult=0.125)
    zcfg = ZebraConfig(t_obj=0.1)
    variables = model.init(K, zcfg)
    x = jax.random.normal(K, (2, 3, 32, 32))
    logits, new_state, auxes = model.apply(variables, x, True, zcfg)
    assert logits.shape == (2, 10)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert len(auxes) > 5
    # eval path with constant-threshold Zebra
    logits2, _, auxes2 = model.apply(variables, x, False, zcfg.replace(mode="infer"))
    assert bool(jnp.all(jnp.isfinite(logits2)))


@pytest.mark.parametrize("name", ["resnet18", "vgg16", "mobilenet"])
def test_map_specs_match_apply(name):
    """map_specs (bandwidth accounting) must agree with the real site count
    and block counts seen during apply."""
    model = build_cnn(name, num_classes=10, in_hw=32, width_mult=0.25)
    zcfg = ZebraConfig(t_obj=0.1)
    variables = model.init(K, zcfg)
    x = jax.random.normal(K, (1, 3, 32, 32))
    _, _, auxes = model.apply(variables, x, False, zcfg.replace(mode="infer"))
    specs = model.map_specs(32, zcfg)
    assert len(specs) == len(auxes)
    for spec, aux in zip(specs, auxes):
        assert spec.n_blocks == aux["n_blocks"], (spec, aux["n_blocks"])


def test_weight_pruning_sparsity():
    model = build_cnn("resnet18", 10, 32, 0.125)
    variables = model.init(K, ZebraConfig())
    masks = weight_pruning.magnitude_masks(variables["params"], 0.5)
    sp = weight_pruning.sparsity(masks)
    assert 0.45 < sp < 0.55
    pruned = weight_pruning.apply_masks(variables["params"], masks)
    w = pruned["s0b0"]["conv1"]["w"]
    assert float(jnp.mean((w == 0).astype(jnp.float32))) > 0.4


def test_network_slimming_masks():
    model = build_cnn("vgg16", 10, 32, 0.125)
    variables = model.init(K, ZebraConfig())
    # randomize gammas so a quantile exists
    params = jax.tree_util.tree_map(lambda x: x, variables["params"])
    gammas = slimming.collect_gammas(params)
    assert len(gammas) == 13           # one BN per conv in VGG16
    key = K
    def randomize(path, leaf):
        names = [str(getattr(p, "key", "")) for p in path]
        if any(n.startswith("bn") for n in names) and str(names[-1]) == "scale":
            return jax.random.uniform(jax.random.PRNGKey(hash(tuple(names)) % 2**31), leaf.shape)
        return leaf
    params = jax.tree_util.tree_map_with_path(randomize, params)
    masks = slimming.channel_masks(params, 0.3)
    frac = slimming.pruned_channel_frac(masks)
    assert 0.2 < frac < 0.4
    slim = slimming.apply_masks(params, masks)
    g2 = slimming.collect_gammas(slim)
    zeroed = sum(float(jnp.sum(g == 0)) for _, g in g2)
    total = sum(int(g.size) for _, g in g2)
    assert np.isclose(zeroed / total, frac, atol=0.02)


def test_gamma_l1_positive():
    model = build_cnn("resnet18", 10, 32, 0.125)
    variables = model.init(K, ZebraConfig())
    assert float(slimming.gamma_l1(variables["params"])) > 0
