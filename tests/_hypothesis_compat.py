"""Property-test shim: use hypothesis when installed, otherwise degrade to
deterministic fixed-seed parametrized cases so tier-1 still collects and
runs (the container has no network; hypothesis may be absent).

Usage in tests (instead of ``from hypothesis import ...``)::

    from _hypothesis_compat import given, settings, st

The fallback draws a small fixed number of examples per test from a PRNG
seeded by the test's qualified name — stable across runs and processes
(``random.Random(str)`` does not depend on PYTHONHASHSEED).
"""
from __future__ import annotations

try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import random

    _FALLBACK_EXAMPLES = 5      # per test; keep the degraded tier-1 quick

    class _Strategy:
        def example(self, rng: random.Random):
            raise NotImplementedError

    class _Integers(_Strategy):
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def example(self, rng):
            return rng.randint(self.lo, self.hi)

    class _Floats(_Strategy):
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def example(self, rng):
            return rng.uniform(self.lo, self.hi)

    class _SampledFrom(_Strategy):
        def __init__(self, options):
            self.options = list(options)

        def example(self, rng):
            return rng.choice(self.options)

    class _Booleans(_Strategy):
        def example(self, rng):
            return rng.random() < 0.5

    class _St:
        @staticmethod
        def integers(min_value, max_value):
            return _Integers(min_value, max_value)

        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Floats(min_value, max_value)

        @staticmethod
        def sampled_from(options):
            return _SampledFrom(options)

        @staticmethod
        def booleans():
            return _Booleans()

    st = _St()

    def settings(max_examples: int = 10, **_kw):
        """Records max_examples on the (already-@given-wrapped) test."""
        def deco(f):
            f._max_examples = max_examples
            return f
        return deco

    def given(**strategies):
        def deco(f):
            @functools.wraps(f)
            def wrapper(*args, **kwargs):
                n = min(getattr(wrapper, "_max_examples", _FALLBACK_EXAMPLES),
                        _FALLBACK_EXAMPLES)
                rng = random.Random(f.__qualname__)
                for _ in range(n):
                    draw = {k: s.example(rng) for k, s in strategies.items()}
                    f(*args, **draw, **kwargs)
            # pytest must not see the strategy params as fixtures: drop the
            # __wrapped__ link so inspect.signature reports (*args, **kwargs)
            wrapper.__dict__.pop("__wrapped__", None)
            return wrapper
        return deco
