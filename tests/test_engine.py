"""Unified Zebra site engine (core.engine): backend parity matrix,
measured-bytes consistency with the BandwidthMeter predictions, aux
structs, and the engine-routed model paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (LayerAux, SiteAux, TokenMapSpec, ZebraConfig,
                        stored_bits, zebra_infer_bitmap_nchw,
                        zebra_infer_bitmap_tokens, zebra_site)

K = jax.random.PRNGKey(0)
KERNEL_BACKENDS = ("pallas", "stream", "fused")


def _blocky_tokens(key, B, S, D, bs, bc, dtype=jnp.float32):
    x = jax.random.normal(key, (B, S, D), jnp.float32)
    scale = jax.random.uniform(jax.random.fold_in(key, 1),
                               (B * S // bs, D // bc))
    x = x * jnp.repeat(jnp.repeat(scale, bs, 0), bc, 1).reshape(B, S, D)
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# Backend parity matrix — bitwise-identical infer outputs on both layouts
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", KERNEL_BACKENDS)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_token_layout_backend_parity(backend, dtype):
    x = _blocky_tokens(K, 2, 32, 256, 8, 128, dtype)
    cfg = ZebraConfig(t_obj=0.8, mode="infer")
    yr, ar = zebra_site(x, cfg.replace(backend="reference"))
    yb, ab = zebra_site(x, cfg.replace(backend=backend))
    np.testing.assert_array_equal(np.asarray(yr, np.float32),
                                  np.asarray(yb, np.float32))
    assert ar.n_blocks == ab.n_blocks == (32 // 8) * (256 // 128)
    assert np.isclose(float(ar.zero_frac), float(ab.zero_frac))
    assert ab.backend == backend


@pytest.mark.parametrize("backend", KERNEL_BACKENDS)
@pytest.mark.parametrize("shape,block_hw", [
    ((2, 4, 8, 8), 4),
    ((2, 3, 2, 2), 4),     # paper's shrink-to-2 edge case (2x2 maps)
    ((1, 8, 16, 16), 8),
])
def test_nchw_layout_backend_parity(backend, shape, block_hw):
    B, C, H, W = shape
    b = min(block_hw, H)
    x = jax.nn.relu(jax.random.normal(K, shape))
    scale = jax.random.uniform(jax.random.fold_in(K, 2),
                               (B, C, H // b, W // b))
    x = x * jnp.repeat(jnp.repeat(scale, b, 2), b, 3)   # blocky magnitudes
    cfg = ZebraConfig(t_obj=0.8, block_hw=block_hw, mode="infer")
    yr, ar = zebra_site(x, cfg.replace(backend="reference"), layout="nchw")
    yb, ab = zebra_site(x, cfg.replace(backend=backend), layout="nchw")
    np.testing.assert_array_equal(np.asarray(yr), np.asarray(yb))
    assert ar.n_blocks == ab.n_blocks > 0
    assert np.isclose(float(ar.zero_frac), float(ab.zero_frac))
    # at least exercise real sparsity in the bigger cases
    if shape[-1] > 2:
        assert 0.0 < float(ab.zero_frac) < 1.0


def test_fused_backend_ffn_bitwise_matches_reference():
    """Acceptance: dense-FFN fused backend == reference backend bitwise on
    the infer path (bf16 serving dtype)."""
    from repro.models.lm.config import LMConfig
    from repro.models.lm.ffn import ffn_apply, ffn_init

    cfg = LMConfig(n_layers=1, d_model=64, n_heads=4, d_ff=256, vocab=128,
                   zebra_t_obj=0.5)
    p = ffn_init(jax.random.PRNGKey(0), cfg, jnp.bfloat16)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64), jnp.bfloat16)
    y_ref, a_ref = ffn_apply(p, x, cfg.replace(zebra_backend="reference"), "infer")
    y_fus, a_fus = ffn_apply(p, x, cfg.replace(zebra_backend="fused"), "infer")
    np.testing.assert_array_equal(np.asarray(y_ref, np.float32),
                                  np.asarray(y_fus, np.float32))
    assert a_fus.backend == "fused"
    assert np.isclose(float(a_ref.zero_frac), float(a_fus.zero_frac))
    assert float(a_fus.measured_bytes) > 0          # fetched payload + index
    # decode-shaped input (S=1): fused degrades to the reference path
    x1 = jax.random.normal(jax.random.PRNGKey(2), (2, 1, 64), jnp.bfloat16)
    y1r, _ = ffn_apply(p, x1, cfg.replace(zebra_backend="reference"), "infer")
    y1f, a1f = ffn_apply(p, x1, cfg.replace(zebra_backend="fused"), "infer")
    np.testing.assert_array_equal(np.asarray(y1r, np.float32),
                                  np.asarray(y1f, np.float32))
    assert a1f.backend == "reference(degenerate-rows)"   # reason surfaced


def test_per_site_backend_override_and_capability_degrades():
    x = _blocky_tokens(K, 2, 16, 256, 8, 128)
    cfg = ZebraConfig(t_obj=0.5, mode="infer", backend="pallas",
                      site_backends=(("kv_cache", "stream"),))
    _, a1 = zebra_site(x, cfg, site="ffn_hidden")
    _, a2 = zebra_site(x, cfg, site="kv_cache")
    assert a1.backend == "pallas" and a2.backend == "stream"
    # threshold-net training: per-sample learned thresholds are jnp-only,
    # so the capability check resolves to reference WITH the reason
    from repro.core import init_token_threshold_net
    tnet = init_token_threshold_net(K, 256, 2)
    yt, at = zebra_site(x, cfg.replace(mode="train", backend="stream"),
                        tnet=tnet)
    assert at.backend == "reference(tnet)"
    g = jax.grad(lambda xx: jnp.sum(
        zebra_site(xx, cfg.replace(mode="train", backend="stream"),
                   tnet=tnet)[0] ** 2))(x)
    assert np.all(np.isfinite(np.asarray(g)))
    # fused has no backward rule: train-mode requests degrade with reason
    _, af = zebra_site(x, cfg.replace(mode="train", backend="fused",
                                      use_tnet=False))
    assert af.backend == "reference(not-trainable)"
    # constant-threshold train mode stays ON the kernel backend
    _, ak = zebra_site(x, cfg.replace(mode="train", backend="stream",
                                      use_tnet=False))
    assert ak.backend == "stream"
    # use_tnet=False is authoritative: stray legacy net params are ignored
    # (gating with them would train un-regularized thresholds, since the
    # loss excludes the Eq. 1 L2 term in this mode)
    _, ai = zebra_site(x, cfg.replace(mode="train", backend="stream",
                                      use_tnet=False), tnet=tnet)
    assert ai.backend == "stream"


def test_backend_registry_capabilities_and_config_validation():
    from repro.core import BackendSpec, backend_names, backend_spec

    assert set(backend_names()) >= {"reference", "pallas", "stream", "fused"}
    assert backend_spec("reference").trainable
    assert backend_spec("pallas").trainable and not backend_spec("pallas").emits_stream
    assert backend_spec("stream").trainable and backend_spec("stream").emits_stream
    assert not backend_spec("fused").trainable and backend_spec("fused").consumes_w
    assert backend_spec("pallas").grad_variant == "mask"
    assert backend_spec("stream").grad_variant == "stream"
    # a typo'd backend fails at config construction, not at first dispatch
    with pytest.raises(ValueError, match="unknown zebra backend"):
        ZebraConfig(backend="bogus")
    with pytest.raises(ValueError, match="unknown zebra backend"):
        ZebraConfig(site_backends=(("ffn_hidden", "bogus"),))
    # w is rejected against the requested spec's consumes_w capability
    x = _blocky_tokens(K, 2, 16, 256, 8, 128)
    w = jnp.ones((256, 4), jnp.float32)
    with pytest.raises(ValueError, match="does not consume"):
        zebra_site(x, ZebraConfig(t_obj=0.5, mode="infer", backend="stream"),
                   w=w)
    # a trainable spec must bring its forward pipeline (or reuse one)
    from repro.core import register_engine_backend
    bad = BackendSpec("exotic", trainable=True, emits_stream=False,
                      consumes_w=False, vmem_bounded=False,
                      grad_variant="exotic")
    with pytest.raises(ValueError, match="forward_variant"):
        register_engine_backend(bad, lambda *a: None)


# ---------------------------------------------------------------------------
# Measured bytes vs BandwidthMeter / Eq. 2+3 predictions
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t_obj", [0.0, 0.6, 1e9])
def test_stream_measured_bytes_match_prediction(t_obj):
    B, S, D, bs, bc = 2, 32, 256, 8, 128
    x = _blocky_tokens(K, B, S, D, bs, bc, jnp.bfloat16)
    cfg = ZebraConfig(t_obj=t_obj, mode="infer", backend="stream")
    _, aux = zebra_site(x, cfg)
    spec = TokenMapSpec(s=B * S, d=D, bits=16, block_seq=bs, block_ch=bc)
    predicted = stored_bits(spec, float(aux.zero_frac)) / 8.0
    delta = float(aux.measured_bytes) - predicted
    assert -1e-3 <= delta < 1.0 + 1e-3, (delta, t_obj)   # index padding only


def test_cnn_stream_measured_bytes_match_prediction():
    from repro.core import MapSpec
    B, C, H, W, b = 2, 4, 8, 8, 4
    x = jax.nn.relu(jax.random.normal(K, (B, C, H, W)))
    cfg = ZebraConfig(t_obj=0.8, block_hw=b, mode="infer", backend="stream")
    _, aux = zebra_site(x, cfg, layout="nchw")
    spec = MapSpec(c=B * C, h=H, w=W, bits=32, block=b)
    predicted = stored_bits(spec, float(aux.zero_frac)) / 8.0
    delta = float(aux.measured_bytes) - predicted
    assert -1e-3 <= delta < 1.0 + 1e-3, delta


# ---------------------------------------------------------------------------
# Aux structs
# ---------------------------------------------------------------------------

def test_siteaux_dict_compat_and_layeraux_guard():
    aux = SiteAux.empty()
    assert aux["zero_frac"] == 0.0 and aux.get("n_blocks") == 0
    assert aux.get("missing", 123) == 123
    la = LayerAux.zero()
    assert float(la.zero_frac) == 0.0       # n_blocks == 0: no div-by-zero
    s = SiteAux(reg=jnp.float32(1.0), zero_frac=jnp.float32(0.5),
                measured_bytes=jnp.float32(8.0), n_blocks=10)
    acc = la + LayerAux.of_site(s) + LayerAux.of_site(s, router_aux=2.0)
    assert float(acc.reg) == 2.0 and float(acc.n_blocks) == 20.0
    assert float(acc.zero_frac) == 0.5
    assert float(acc.measured_bytes) == 16.0 and float(acc.router_aux) == 2.0
    # scan-carry friendly
    def body(c, _):
        return c + LayerAux.of_site(s), None
    out, _ = jax.lax.scan(body, LayerAux.zero(), jnp.arange(3))
    assert float(out.n_blocks) == 30.0


def test_layeraux_byte_pair_exact_past_16mib():
    """Satellite regression: measured bytes accumulate exactly past the
    f32 integer limit (2**24 B = 16 MiB). A single f32 accumulator
    already rounds 2**24 + 1 to 2**24; the (mb_hi, mb_lo) pair doesn't."""
    per_site = 2 ** 24 + 1                      # unrepresentable in f32
    assert float(jnp.float32(per_site)) != per_site
    s = SiteAux(reg=jnp.float32(0.0), zero_frac=jnp.float32(0.0),
                measured_bytes=jnp.int32(per_site), n_blocks=1)
    acc = LayerAux.zero()
    for _ in range(3):
        acc = acc + LayerAux.of_site(s)
    assert acc.measured_bytes_exact() == 3 * per_site       # > 48 MiB, exact
    # and through a lax.scan carry (the form every LM layer stack uses)
    def body(c, _):
        return c + LayerAux.of_site(s), None
    out, _ = jax.lax.scan(body, LayerAux.zero(), jnp.arange(5))
    assert out.measured_bytes_exact() == 5 * per_site
    # the lo leg stays renormalized below the base (f32-exact territory)
    assert float(out.mb_lo) < 2 ** 24 and float(out.mb_hi) == 5.0
    # odd lo-leg sum crossing the base: an f32 addition would round
    # 2**24 + 1 to 2**24 before the carry could be extracted
    a = LayerAux.of_site(SiteAux(measured_bytes=jnp.int32(2 ** 24 - 1),
                                 n_blocks=1))
    b = LayerAux.of_site(SiteAux(measured_bytes=jnp.int32(2),
                                 n_blocks=1))
    assert (a + b).measured_bytes_exact() == 2 ** 24 + 1


def test_transport_state_spot_check_rotates_and_bounds_every_leaf(capsys):
    """Satellite: serve's compressed KV handoff rotates the losslessness
    spot-check across leaves (configurable via sample_leaf) and asserts
    the Eq. 2/3 reconcile bound for every leaf, not just the max."""
    import re
    from repro.compress import CompressedMap
    from repro.launch.serve import transport_state_compressed
    from repro.models.lm.config import LMConfig

    cfg = LMConfig()                        # block_seq 8, block_ch 128
    k1 = jax.random.normal(K, (2, 8, 2, 64))            # (..., 16, 128) view
    k2 = jax.random.normal(jax.random.fold_in(K, 1), (2, 8, 2, 64))
    state = ([{"sub0": {"k": k1, "v": k2}}], None)

    def sampled_leaf(out):
        m = re.search(r"lossless \(sampled leaf (\d)/2\): True", out)
        assert m, out
        return int(m.group(1))

    ccaches, enc = transport_state_compressed(state, cfg)
    out1 = capsys.readouterr().out
    first = sampled_leaf(out1)              # counter is process-global:
    assert "every leaf within the index-padding bound" in out1
    leaves = jax.tree_util.tree_leaves(
        ccaches, is_leaf=lambda l: isinstance(l, CompressedMap))
    assert all(isinstance(l, CompressedMap) for l in leaves)
    # second call rotates to the OTHER leaf; explicit index pins one
    transport_state_compressed(state, cfg)
    second = sampled_leaf(capsys.readouterr().out)
    assert {first, second} == {1, 2}
    transport_state_compressed(state, cfg, sample_leaf=0)
    assert sampled_leaf(capsys.readouterr().out) == 1


def test_infer_bitmap_helpers_respect_enabled():
    """Satellite fix: zebra_infer_bitmap_* honor cfg.enabled like
    zebra_cnn/zebra_tokens do."""
    x = jax.random.normal(K, (2, 4, 8, 8))
    off = ZebraConfig(enabled=False, t_obj=100.0, block_hw=4, mode="infer")
    y, keep = zebra_infer_bitmap_nchw(x, off)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
    assert bool(jnp.all(keep)) and keep.shape == (2, 4, 2, 2)
    xt = jax.random.normal(K, (2, 16, 256))
    yt, keept = zebra_infer_bitmap_tokens(xt, off.replace(block_seq=8,
                                                          block_ch=128))
    np.testing.assert_array_equal(np.asarray(yt), np.asarray(xt))
    assert bool(jnp.all(keept)) and keept.shape == (2, 2, 2)
    # enabled path unchanged (t_obj high enough to mask everything)
    y2, keep2 = zebra_infer_bitmap_nchw(x, off.replace(enabled=True))
    assert not bool(jnp.any(keep2)) and not bool(jnp.any(y2))


def test_token_layout_2d_map_all_backends():
    """A bare (M, K) map works on every backend — including the reference
    fallbacks (train mode, degenerate S) that 3-D callers rely on."""
    x = _blocky_tokens(K, 1, 32, 256, 8, 128)[0]         # (32, 256)
    cfg = ZebraConfig(t_obj=0.8, mode="infer")
    yr, ar = zebra_site(x, cfg.replace(backend="reference"))
    assert yr.shape == x.shape and ar.n_blocks == 4 * 2
    for backend in ("pallas", "stream"):
        yb, ab = zebra_site(x, cfg.replace(backend=backend))
        np.testing.assert_array_equal(np.asarray(yr), np.asarray(yb))
        assert ab.n_blocks == ar.n_blocks


def test_save_acts_nchw_block_layout_roundtrip(tmp_path):
    """Satellite: save_acts compresses 4-D NCHW maps with the engine's
    spatial b x b block layout (even when the flattened view would divide
    by the token tiles) and restores them bit-exactly."""
    import os
    from repro.checkpoint import CheckpointManager
    from repro.checkpoint.manager import _stream_layout

    # W = 128 divides the token bc — the spatial layout must still win
    assert _stream_layout((1, 4, 8, 128), 8, 128, 4) == ((1 * 4 * 8, 128), 4, 4)
    assert _stream_layout((2, 8, 16, 16), 8, 128, 4) == ((2 * 8 * 16, 16), 4, 4)
    assert _stream_layout((4, 16, 256), 8, 128, 4) == ((64, 256), 8, 128)

    b = 4
    x = jax.nn.relu(jax.random.normal(K, (2, 8, 16, 16)))
    masked, _ = zebra_site(x, ZebraConfig(t_obj=1.0, block_hw=b, mode="infer"),
                           layout="nchw")
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    stats = mgr.save_acts(0, {"relu3": np.asarray(masked)}, bs=8, bc=128,
                          block_hw=b)
    assert stats["relu3"]["stored_bytes"] < stats["relu3"]["dense_bytes"]
    back = mgr.restore_acts(0)
    np.testing.assert_array_equal(back["relu3"], np.asarray(masked))
    assert os.path.exists(os.path.join(str(tmp_path), "acts_0.npz"))


# ---------------------------------------------------------------------------
# Engine-routed model paths
# ---------------------------------------------------------------------------

def test_cnn_model_stream_backend_matches_reference_and_reports_bytes():
    from repro.models.cnn import build as build_cnn

    model = build_cnn("resnet18", 10, 8, 0.125)
    variables = model.init(K, ZebraConfig(mode="infer"))
    x = jax.random.normal(jax.random.fold_in(K, 3), (2, 3, 8, 8))
    ref = ZebraConfig(t_obj=0.3, mode="infer", backend="reference")
    st = ref.replace(backend="stream")
    logits_r, _, aux_r = model.apply(variables, x, False, ref)
    logits_s, _, aux_s = model.apply(variables, x, False, st)
    np.testing.assert_array_equal(np.asarray(logits_r), np.asarray(logits_s))
    assert sum(float(a["measured_bytes"]) for a in aux_s) > 0
    assert sum(float(a["measured_bytes"]) for a in aux_r) == 0
    for ar, as_ in zip(aux_r, aux_s):
        assert np.isclose(float(ar["zero_frac"]), float(as_["zero_frac"]))


def test_generate_scan_matches_python_decode_loop():
    """serve.py's single-dispatch lax.scan generation == per-token loop."""
    import repro.configs as configs
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import make_decode_step, make_generate, make_prefill
    from repro.models.lm import LM

    cfg = configs.reduced("gemma3-4b").replace(
        param_dtype="bfloat16", zebra_sites=("ffn_hidden", "kv_cache"))
    mesh = make_host_mesh(model=1)
    model = LM(cfg)
    params = jax.jit(model.init)(jax.random.PRNGKey(0))
    B, S, G = 2, 16, 4
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    prefill = jax.jit(make_prefill(model, mesh))
    from repro.launch.serve import model_prefill_pad
    logits, state, aux = model_prefill_pad(prefill, params, prompts, S + G)
    # named LayerAux fields (satellite: no positional aux indexing)
    assert float(aux.n_blocks) > 0
    assert 0.0 <= float(aux.zero_frac) <= 1.0
    tok0 = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]

    decode = jax.jit(make_decode_step(model, mesh))
    toks_loop, tok, st = [], tok0, state
    for i in range(G - 1):
        lg, st = decode(params, tok, st, jnp.int32(S + i))
        tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)[:, None]
        toks_loop.append(tok)
    loop_out = np.asarray(jnp.concatenate(toks_loop, axis=1))

    generate = jax.jit(make_generate(model, mesh, G - 1))
    scan_out, _ = generate(params, tok0, state, jnp.int32(S))
    np.testing.assert_array_equal(np.asarray(scan_out), loop_out)
