"""Continuous-batching serving over the paged compressed-KV pool.

Covers the serve subsystem's load-bearing contracts: the power-of-two
shape ladders (bounded compile shapes, asserted not observed), the slab
page-out -> page-in bitwise round trip (including all-dead pages),
output parity between continuous batching and the one-shot generate
path, eviction-under-pressure correctness (a request that loses its
lane resumes from the compressed pool with identical output), chaos at
the page-ingest boundary (corrupt page -> per-page dense fallback,
detection asserted against the injection plan), hot-state buffer
donation on the decode dispatch, and the bucketed ``model_prefill_pad``
compile count.
"""
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.ft import Fault, inject
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import (_next_token, make_decode_step, make_generate,
                                make_prefill)
from repro.models.lm import LM
from repro.serve import (PagedKVPool, Request, Scheduler, ServeEngine,
                         bucket_ladder, pow2_bucket, pow2_ceil, pow2_floor,
                         synthetic_trace)


# ---------------------------------------------------------------------------
# fixtures (module-cached: one model init for the whole file)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _model(zebra_kv: bool = False):
    sites = ("ffn_hidden", "kv_cache") if zebra_kv else ()
    cfg = configs.reduced("gemma3-4b").replace(
        param_dtype="bfloat16", zebra_sites=sites, zebra_t_obj=2.5)
    mesh = make_host_mesh(model=1)
    model = LM(cfg)
    params = jax.jit(model.init)(jax.random.PRNGKey(0))
    return cfg, mesh, model, params


def _engine(**kw):
    cfg, mesh, model, params = _model(kw.pop("zebra_kv", False))
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_cache_len", 64)
    kw.setdefault("page_tokens", 16)
    return ServeEngine(model, params, mesh, **kw), cfg, model, params, mesh


def _prompt(n, seed=0, vocab=512):
    rng = np.random.default_rng(seed)
    return rng.integers(1, vocab, size=n).astype(np.int32)


# ---------------------------------------------------------------------------
# bucket ladder unit
# ---------------------------------------------------------------------------

def test_pow2_helpers():
    assert [pow2_ceil(n) for n in (1, 2, 3, 5, 8, 9)] == [1, 2, 4, 8, 8, 16]
    assert [pow2_floor(n) for n in (1, 2, 3, 5, 8, 9)] == [1, 2, 2, 4, 8, 8]
    assert pow2_bucket(1, lo=8) == 8          # floor of the ladder
    assert pow2_bucket(20, lo=8) == 32
    assert pow2_bucket(32, lo=8, hi=32) == 32
    with pytest.raises(ValueError):
        pow2_bucket(33, lo=8, hi=32)          # above the ladder top
    assert bucket_ladder(8, 64) == (8, 16, 32, 64)
    assert bucket_ladder(1, 1) == (1,)


# ---------------------------------------------------------------------------
# paged slab round trip
# ---------------------------------------------------------------------------

def test_slab_round_trip_bitwise_including_all_dead_pages():
    """page_out -> page_in is bitwise, with mixed live / all-zero pages
    and a non-pageable (odd-shape) dense leaf in the same tree."""
    rng = np.random.default_rng(7)
    k = rng.normal(size=(1, 32, 2, 32)).astype(np.float32)
    k[:, 16:] = 0.0                           # pages 1.. are all-dead
    v = np.zeros((1, 32, 2, 32), np.float32)  # every page all-dead
    odd = rng.normal(size=(3, 5)).astype(np.float32)
    tree = {"k": jnp.asarray(k), "v": jnp.asarray(v), "odd": jnp.asarray(odd)}

    pool = PagedKVPool(page_tokens=16, bs=8, bc=128)
    pool.page_out(0, tree)
    back = pool.page_in(0)
    for key in tree:
        np.testing.assert_array_equal(np.asarray(back[key]),
                                      np.asarray(tree[key]))
    assert pool.n_pages_out == 4              # 2 leaves x 32/16 pages
    assert pool.n_recovered == 0
    # all-dead pages still move their index bytes but no payload blocks
    rb = pool.request_bytes(0)
    assert 0 < rb["measured"] < rb["dense"]
    assert rb["pages"] == 4
    assert 0 in pool
    pool.free(0)
    assert 0 not in pool


def test_slab_reemit_replaces_and_remeters():
    """page_out for an rid that already has a slab re-emits the stream —
    eviction traffic is metered again, not deduplicated."""
    x = {"k": jnp.ones((1, 16, 2, 32), jnp.float32)}
    pool = PagedKVPool(page_tokens=16)
    pool.page_out(1, x)
    b1 = pool.request_bytes(1)["measured"]
    pool.page_out(1, x)
    assert pool.request_bytes(1)["measured"] == 2 * b1
    np.testing.assert_array_equal(np.asarray(pool.page_in(1)["k"]),
                                  np.asarray(x["k"]))


# ---------------------------------------------------------------------------
# chaos at the page-ingest boundary
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["bitflip", "truncate", "nan"])
def test_page_ingest_detects_and_degrades_one_page(kind):
    """A corrupt page is DETECTED (asserted against the plan's ground
    truth, not inferred from parity), kept dense (per-page fallback),
    and the request round-trips bitwise anyway."""
    rng = np.random.default_rng(3)
    tree = {"k": jnp.asarray(rng.normal(size=(1, 32, 2, 32)), jnp.float32)}
    level = "checksum" if kind == "bitflip" else "structural"
    pool = PagedKVPool(page_tokens=16, validation=level)
    with inject(Fault(kind, site="page", times=1)) as plan:
        pool.page_out(5, tree)
    assert plan.injected == [(kind, "page")]
    assert pool.n_recovered == 1
    rb = pool.request_bytes(5)
    assert rb["pages"] == 1                   # the other page stayed compressed
    np.testing.assert_array_equal(np.asarray(pool.page_in(5)["k"]),
                                  np.asarray(tree["k"]))


def test_page_ingest_off_level_admits_silently():
    """validation='off' is the no-check baseline: the fault is injected
    but nothing detects it — n_recovered stays 0. (The integrity matrix
    itself is pinned by test_faults.py; this pins the pool's gate.)"""
    tree = {"k": jnp.ones((1, 16, 2, 32), jnp.float32)}
    pool = PagedKVPool(page_tokens=16, validation="off")
    with inject(Fault("nan", site="page", times=1)) as plan:
        pool.page_out(9, tree)
    assert plan.injected == [("nan", "page")]
    assert pool.n_recovered == 0


def test_engine_serves_through_page_chaos():
    """End-to-end: a stream fault at the page boundary during a real
    serve run degrades one page and the trace still completes, with the
    recovery visible in the report."""
    eng, cfg, *_ = _engine(validation="structural")
    reqs = [Request(rid=i, prompt=_prompt(12, seed=i), max_new=4)
            for i in range(2)]
    with inject(Fault("truncate", site="page", times=1)) as plan:
        rep = eng.run(reqs, preempt_after=0)
    assert plan.injected == [("truncate", "page")]
    assert rep["pages_recovered"] == 1
    assert rep["n_requests"] == 2
    assert all(len(r.out) == 4 for r in eng.scheduler.completed)


# ---------------------------------------------------------------------------
# scheduler policy unit
# ---------------------------------------------------------------------------

def test_scheduler_fcfs_admission_and_rejection():
    reqs = [Request(rid=0, prompt=_prompt(8), max_new=4, arrival=0),
            Request(rid=1, prompt=_prompt(8), max_new=4, arrival=5),
            Request(rid=2, prompt=_prompt(8), max_new=4, arrival=0)]
    s = Scheduler(reqs)
    got = s.admit(tick=0, free_slots=4)
    assert [r.rid for r in got] == [0, 2]      # rid 1 hasn't arrived
    assert s.admit(tick=5, free_slots=4, fits=lambda r: False) == []
    assert [r.status for r in s.completed] == ["rejected"]


def test_scheduler_preemption_clock():
    r = Request(rid=0, prompt=_prompt(8), max_new=4)
    s = Scheduler([Request(rid=1, prompt=_prompt(8), max_new=4)],
                  preempt_after=3)
    r.slot_steps = 3
    assert s.should_preempt(r)                # others are waiting
    s.waiting.clear()
    assert not s.should_preempt(r)            # nobody waiting: keep the lane
    s2 = Scheduler([], preempt_after=0)
    r.slot_steps = 10**6
    assert not s2.should_preempt(r)           # preemption disabled


def test_synthetic_trace_deterministic():
    a = synthetic_trace(4, vocab=512, seed=3, arrival_every=2)
    b = synthetic_trace(4, vocab=512, seed=3, arrival_every=2)
    assert [r.arrival for r in a] == [0, 2, 4, 6]
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x.prompt, y.prompt)
        assert x.max_new == y.max_new


# ---------------------------------------------------------------------------
# engine: parity, eviction, bounded shapes, donation
# ---------------------------------------------------------------------------

def test_continuous_matches_one_shot_generate():
    """The slotted engine's tokens == the one-shot prefill+generate path
    for the same prompt, greedy. Chunked admission (pow2-prefix prefill
    + teacher-forced tail) must be invisible in the output."""
    eng, cfg, model, params, mesh = _engine(n_slots=1, max_cache_len=32)
    P, G = 20, 8                              # P+G=28 -> both paths cache at 32
    prompt = _prompt(P, seed=11, vocab=cfg.vocab)
    rep = eng.run([Request(rid=0, prompt=prompt, max_new=G)])
    served = eng.scheduler.completed[0].out
    assert rep["n_requests"] == 1 and len(served) == G

    from repro.launch.serve import model_prefill_pad
    prefill = jax.jit(make_prefill(model, mesh))
    logits, state, _ = model_prefill_pad(
        prefill, params, jnp.asarray(prompt)[None, :], P + G)
    tok0 = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    generate = jax.jit(make_generate(model, mesh, G - 1))
    toks, _ = generate(params, tok0, state, jnp.int32(P))
    one_shot = np.concatenate([np.asarray(tok0), np.asarray(toks)], 1)[0]
    np.testing.assert_array_equal(np.asarray(served), one_shot)


def test_short_prompt_decode_only_admission_parity():
    """A prompt below the smallest prefill bucket skips prefill and
    teacher-forces from pos 0 — tokens match a manual scalar decode loop
    over the same cache bucket."""
    eng, cfg, model, params, mesh = _engine(n_slots=1, max_cache_len=32,
                                            min_prefill=8)
    P, G = 5, 6
    prompt = _prompt(P, seed=4, vocab=cfg.vocab)
    eng.run([Request(rid=0, prompt=prompt, max_new=G)])
    served = eng.scheduler.completed[0].out
    assert eng._prefill_shapes == set()       # prefill never dispatched

    decode = jax.jit(make_decode_step(model, mesh))
    st = (model.init_cache(1, eng._C), None)
    tok = jnp.asarray([[int(prompt[0])]], jnp.int32)
    out = []
    for pos in range(P + G - 1):
        lg, st = decode(params, tok, st, jnp.int32(pos))
        nxt = int(jnp.argmax(lg, axis=-1)[0])
        if pos + 1 < P:
            tok = jnp.asarray([[int(prompt[pos + 1])]], jnp.int32)
        else:
            out.append(nxt)
            tok = jnp.asarray([[nxt]], jnp.int32)
    assert served == out


def test_eviction_under_pressure_outputs_unchanged():
    """Slot pressure + preemption: requests get evicted to the pool and
    resume later, and every request's tokens equal the no-preemption
    run. The page round trip is bitwise, so eviction must be invisible."""
    def trace():
        return [Request(rid=i, prompt=_prompt(10 + 3 * i, seed=20 + i),
                        max_new=6) for i in range(4)]
    eng1, *_ = _engine(n_slots=2, max_cache_len=64)
    eng1.run(trace(), preempt_after=0)
    base = {r.rid: r.out for r in eng1.scheduler.completed}

    eng2, *_ = _engine(n_slots=2, max_cache_len=64)
    rep = eng2.run(trace(), preempt_after=3)
    assert rep["evictions"] > 0
    pressured = {r.rid: (r.out, r.evictions) for r in eng2.scheduler.completed}
    assert any(ev for _, ev in pressured.values())
    for rid, out in base.items():
        assert pressured[rid][0] == out, f"rid {rid} diverged after eviction"
    # pool metering saw the eviction traffic: more pages than a clean run
    assert rep["kv_pages"] >= eng1.report(1.0)["kv_pages"]


def test_decode_dispatch_shapes_are_asserted_not_observed():
    """A hot-set shape outside the declared ladder raises BEFORE tracing,
    and the compiled-shape count is bounded by the ladder product."""
    eng, *_ = _engine(n_slots=2, max_cache_len=64)
    rep = eng.run(synthetic_trace(3, vocab=512, seed=1, prompt_lo=8,
                                  prompt_hi=20, gen_lo=2, gen_hi=6))
    assert rep["decode_shapes"] <= rep["decode_shape_bound"]
    # the jit cache itself is bounded — compiled shape count, not calls
    assert eng._decode._cache_size() <= rep["decode_shape_bound"]
    assert eng._prefill._cache_size() <= len(eng.prefill_ladder)
    eng._Bb = 3                               # not a power of two
    with pytest.raises(RuntimeError, match="outside the bucketed ladder"):
        eng._step(time.time())


def test_engine_rejects_requests_beyond_cache_ladder():
    eng, *_ = _engine(n_slots=1, max_cache_len=32)
    reqs = [Request(rid=0, prompt=_prompt(30), max_new=30),   # needs 64 > 32
            Request(rid=1, prompt=_prompt(8), max_new=2)]
    rep = eng.run(reqs)
    assert rep["n_rejected"] == 1 and rep["n_requests"] == 1
    assert eng.scheduler.completed[0].status == "rejected"


def test_decode_step_donates_hot_state():
    """The decode dispatch donates the old hot working set (argnum 2):
    after one step the previous cache buffers are actually deleted —
    serving at bucket (Bb, C) holds ONE dense cache, not two."""
    eng, *_ = _engine(n_slots=1, max_cache_len=32)
    eng.scheduler = Scheduler([Request(rid=0, prompt=_prompt(9), max_new=4)])
    eng._schedule(0, time.time())
    old = jax.tree_util.tree_leaves(eng._hot)
    eng._step(time.time())
    assert all(x.is_deleted() for x in old)
    new = jax.tree_util.tree_leaves(eng._hot)
    assert not any(x.is_deleted() for x in new)


def test_engine_refuses_unsupported_stacks():
    cfg, mesh, model, params = _model()
    bad = LM(cfg.replace(window=24))          # non-pow2 ring
    with pytest.raises(ValueError, match="power of two"):
        ServeEngine(bad, params, mesh)
    rec = configs.reduced("recurrentgemma-2b").replace(
        param_dtype="bfloat16", zebra_sites=())
    rmodel = LM(rec)
    with pytest.raises(NotImplementedError, match="recurrent state"):
        ServeEngine(rmodel, jax.eval_shape(rmodel.init, jax.random.PRNGKey(0)),
                    mesh)


def test_report_reconciles_every_page():
    """The report path runs meter.reconcile over every page — Eq. 2/3
    within the index-padding bound, per page, or it raises."""
    eng, *_ = _engine(n_slots=2, max_cache_len=64, zebra_kv=True)
    rep = eng.run(synthetic_trace(3, vocab=512, seed=5, prompt_lo=8,
                                  prompt_hi=24, gen_lo=2, gen_hi=6))
    assert rep["kv_pages"] > 0
    assert rep["reconcile_max_delta_bytes"] <= 1.0 + 1.0   # tol + roundoff
    assert rep["kv_bytes_measured"] > 0
    assert abs(rep["kv_bytes_measured"] - rep["kv_bytes_predicted"]) \
        <= rep["kv_pages"] * 2.0
    assert 0.0 <= rep["zero_frac"] <= 1.0


# ---------------------------------------------------------------------------
# satellite: temperature + bucketed model_prefill_pad
# ---------------------------------------------------------------------------

def test_next_token_greedy_and_sampled():
    logits = jnp.asarray([[0.1, 3.0, -1.0]])
    assert int(_next_token(logits, 0.0, None)[0, 0]) == 1
    key = jax.random.PRNGKey(0)
    t = _next_token(logits, 0.7, key)
    assert t.shape == (1, 1) and t.dtype == jnp.int32
    with pytest.raises(ValueError, match="temperature"):
        _next_token(logits, 0.7, None)
    # sampling is key-deterministic
    np.testing.assert_array_equal(np.asarray(_next_token(logits, 0.7, key)),
                                  np.asarray(t))


def test_generate_temperature_zero_matches_greedy_default():
    cfg, mesh, model, params = _model()
    prompts = jnp.asarray(_prompt(16, seed=2, vocab=cfg.vocab))[None, :]
    from repro.launch.serve import model_prefill_pad
    prefill = jax.jit(make_prefill(model, mesh))
    logits, state, _ = model_prefill_pad(prefill, params, prompts, 24)
    tok0 = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    g0 = jax.jit(make_generate(model, mesh, 4))
    g1 = jax.jit(make_generate(model, mesh, 4, 0.0))
    a, _ = g0(params, tok0, state, jnp.int32(16))
    _, state2, _ = model_prefill_pad(prefill, params, prompts, 24)
    b, _ = g1(params, tok0, state2, jnp.int32(16))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_model_prefill_pad_buckets_compile_count():
    """Distinct cache_lens collapse onto the pow2 ladder: decode jits
    keyed on the padded cache shape compile ONCE per bucket. This is the
    recompile fix, asserted as a compile count."""
    cfg, mesh, model, params = _model()
    from repro.launch.serve import model_prefill_pad
    prefill = jax.jit(make_prefill(model, mesh))
    prompts = jnp.asarray(_prompt(16, seed=6, vocab=cfg.vocab))[None, :]
    decode = jax.jit(make_decode_step(model, mesh))
    shapes = set()
    for cache_len in (17, 20, 25, 28, 32):    # all bucket to 32
        _, state, _ = model_prefill_pad(prefill, params, prompts, cache_len)
        shapes.add(jax.tree_util.tree_leaves(state[0])[0].shape)
        decode(params, jnp.zeros((1, 1), jnp.int32), state, jnp.int32(16))
    assert len(shapes) == 1
    assert decode._cache_size() == 1
    # opt-out keeps the exact length (legacy shape behavior)
    _, state, _ = model_prefill_pad(prefill, params, prompts, 20, bucket=False)
    glb = [x for x in jax.tree_util.tree_leaves(state[0]) if x.shape[-3] == 20]
    assert glb, "exact-length pad lost"


# ---------------------------------------------------------------------------
# resilience: deadlines, shedding, breaker, crash recovery (PR 10)
# ---------------------------------------------------------------------------

def test_engine_report_deadline_miss_accounting():
    """A request whose TTL cannot be met given the engine's slot-clock
    estimate is shed at admission, and the report carries the SLO
    accounting."""
    eng, *_ = _engine(n_slots=2, max_cache_len=64)
    reqs = [Request(rid=0, prompt=_prompt(12), max_new=6, deadline_ticks=2),
            Request(rid=1, prompt=_prompt(12), max_new=6, deadline_ticks=64)]
    rep = eng.run(reqs)
    assert rep["n_requests"] == 1
    assert rep["n_shed"] == 1 and rep["deadline_misses"] == 1
    assert rep["deadline_miss_frac"] == 0.5 and rep["shed_frac"] == 0.5
    shed = [r for r in eng.scheduler.completed if r.status == "shed"]
    assert [r.rid for r in shed] == [0]
    assert shed[0].shed_reason == "deadline"
    assert rep["n_rejected"] == 0             # shed is NOT rejected


def test_engine_cancels_lane_past_deadline_midflight():
    eng, *_ = _engine(n_slots=1, max_cache_len=32)
    eng.scheduler = Scheduler([Request(rid=0, prompt=_prompt(9), max_new=8)])
    eng._schedule(0, time.time())
    r = eng._lanes[0]
    assert r is not None and r.rid in eng.pool
    r.deadline = 3                            # TTL expires under the lane
    eng._cancel_deadlines(4)
    assert eng._lanes[0] is None
    assert r.status == "shed" and r.shed_reason == "deadline"
    assert r.rid not in eng.pool              # slab freed (unsupervised)
    assert eng.scheduler.deadline_misses == 1


def test_engine_queue_bound_sheds_overload_end_to_end():
    """More fresh arrivals than the bound: the newest are shed with
    reason "overload", the rest complete with tokens identical to an
    unbounded run (shedding must not perturb survivors)."""
    def trace():
        return [Request(rid=i, prompt=_prompt(10, seed=30 + i), max_new=4)
                for i in range(5)]
    eng1, *_ = _engine(n_slots=2, max_cache_len=64)
    eng1.run(trace())
    base = {r.rid: r.out for r in eng1.scheduler.completed
            if r.status == "done"}
    eng2, *_ = _engine(n_slots=2, max_cache_len=64, queue_bound=2)
    rep = eng2.run(trace())
    shed = [r for r in eng2.scheduler.completed if r.status == "shed"]
    assert len(shed) == 1 and shed[0].shed_reason == "overload"
    assert shed[0].rid == 4                   # newest fresh arrival goes first
    assert rep["n_requests"] == 4 and rep["n_shed"] == 1
    for r in eng2.scheduler.completed:
        if r.status == "done":
            assert r.out == base[r.rid], f"rid {r.rid} perturbed by shedding"


def test_crash_recovery_token_parity():
    """The tentpole end-to-end: an injected engine crash mid-run
    restores the snapshot and re-admits every in-flight request from its
    paged compressed KV — and every request finishes with tokens
    bitwise-equal to the un-crashed run, without replaying generated
    tokens (the restored bookkeeping keeps them)."""
    from repro.ft import FTConfig

    def trace():
        return [Request(rid=i, prompt=_prompt(10 + i, seed=40 + i),
                        max_new=6) for i in range(3)]
    eng1, *_ = _engine(n_slots=2, max_cache_len=64)
    eng1.run(trace())
    base = {r.rid: r.out for r in eng1.scheduler.completed}
    assert all(len(out) == 6 for out in base.values())

    eng2, *_ = _engine(n_slots=2, max_cache_len=64)
    ft_cfg = FTConfig(max_failures=2, backoff_base_s=0.0)
    with inject(Fault("crash", site="engine_tick", arg=4)) as plan:
        rep = eng2.run(trace(), ft_cfg=ft_cfg)
    assert plan.injected == [("crash", "engine_tick")]
    assert rep["crash_recoveries"] == 1
    assert rep["n_requests"] == 3             # nobody lost to the crash
    assert rep["recovered_requests"] >= 1     # in-flight lanes survived
    assert rep["retries"] >= 1
    crashed = {r.rid: r for r in eng2.scheduler.completed}
    for rid, out in base.items():
        assert crashed[rid].out == out, f"rid {rid} diverged across the crash"
    assert any(r.recovered for r in crashed.values())


def test_crash_unsupervised_run_reraises():
    eng, *_ = _engine(n_slots=1, max_cache_len=32)
    with inject(Fault("crash", site="engine_tick", arg=1)):
        with pytest.raises(Exception, match="injected engine crash"):
            eng.run([Request(rid=0, prompt=_prompt(9), max_new=6)])


def test_crash_retry_budget_exhaustion_sheds():
    """A request whose crash re-admissions exhaust its retry budget is
    shed with reason "retry-budget" instead of looping forever."""
    from repro.ft import FTConfig
    eng, *_ = _engine(n_slots=1, max_cache_len=32)
    r = Request(rid=0, prompt=_prompt(9), max_new=6, retry_budget=0)
    with inject(Fault("crash", site="engine_tick", arg=2)):
        rep = eng.run([r], ft_cfg=FTConfig(max_failures=2,
                                           backoff_base_s=0.0))
    assert rep["crash_recoveries"] == 1
    assert r.status == "shed" and r.shed_reason == "retry-budget"
    assert rep["n_shed"] == 1 and rep["n_requests"] == 0
    assert r.rid not in eng.pool              # slab freed with the shed


def test_page_storm_trips_breaker_then_recovers():
    """Persistent page-ingest corruption trips the page breaker to the
    dense path wholesale (skipping per-page validate+fallback), half-open
    probes fail against the remaining armed faults on the decayed
    schedule, and the breaker closes once the storm exhausts — with the
    served tokens identical to a clean run throughout."""
    from repro.ft import BreakerConfig, FTConfig

    def trace():
        return [Request(rid=i, prompt=_prompt(12, seed=50 + i), max_new=6)
                for i in range(2)]
    eng1, *_ = _engine(n_slots=2, max_cache_len=64)
    eng1.run(trace())
    base = {r.rid: r.out for r in eng1.scheduler.completed}

    brk = BreakerConfig(trip_after=2, window=32, probe_after=1,
                        probe_backoff=2.0, probe_cap=4, close_after=1)
    eng2, *_ = _engine(n_slots=2, max_cache_len=64, validation="structural",
                       breaker=brk)
    # supervised: snapshots page out every lane each tick, so the open
    # breaker sees traffic to skip and the probes see traffic to test
    # kind "count" (n_live += 1) is detectable on ANY page, including
    # the all-dead zero-tail pages — detection stays 1:1 with injection
    with inject(Fault("count", site="page", times=3)) as plan:
        rep = eng2.run(trace(), ft_cfg=FTConfig(backoff_base_s=0.0))
    assert [k for k, _ in plan.injected] == ["count"] * 3
    page = rep["breakers"]["page"]
    assert rep["breaker_trips"] == 1          # one trip; reopens don't count
    assert rep["breaker_tripped_sites"] == ["page"]
    assert page["probe_fails"] == 1           # fault 3 fails the first probe
    assert page["state"] == "closed"          # storm exhausted: recovered
    assert rep["pages_breaker_dense"] > 0     # open path actually skipped
    # 2 pre-trip per-page fallbacks + 1 during the failed probe: every
    # detection recovers the page dense even while the breaker reacts
    assert rep["pages_recovered"] == 3
    assert any("page:closed" in lbl for lbl in rep["breaker_labels"])
    for r in eng2.scheduler.completed:
        assert r.out == base[r.rid], f"rid {r.rid} corrupted by the storm"


def test_fits_verdicts_never_later_ok():
    """The hot-set position budget drives the transient "later" verdict:
    infeasible-even-alone is "never", crowded-right-now is "later", and
    the engine report counts the deferrals."""
    eng, *_ = _engine(n_slots=2, max_cache_len=64)
    eng.max_hot_positions = 128               # budget: 2 lanes x 64 cache
    small = Request(rid=0, prompt=_prompt(10), max_new=6)   # bucket 32
    big = Request(rid=1, prompt=_prompt(40), max_new=20)    # bucket 64
    assert eng._fits(small, n_active=0) == "ok"
    assert eng._fits(big, n_active=0) == "ok"               # 1x64 <= 128
    eng._C = 64
    assert eng._fits(small, n_active=1) == "ok"             # 2x64 == 128
    eng.max_hot_positions = 64
    assert eng._fits(small, n_active=1) == "later"          # crowded
    assert eng._fits(small, n_active=0) == "ok"             # 1x64 == 64 alone
    eng.max_hot_positions = 32        # budget below one lane's cache bucket
    assert eng._fits(big, n_active=0) == "never"            # 1x64 > 32 alone
    assert eng._fits(Request(rid=2, prompt=np.zeros(0, np.int32), max_new=1),
                     n_active=0) == "never"                 # empty prompt
