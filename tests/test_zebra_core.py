"""Unit + property tests for the Zebra core (the paper's mechanism)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (ZebraConfig, init_threshold_net, init_token_threshold_net,
                        zebra_cnn, zebra_tokens, zebra_infer_bitmap_nchw,
                        collect_zebra_loss, mean_zero_frac)

K = jax.random.PRNGKey(0)


def manual_block_mask(x, t, b):
    """Reference: per-(channel, b x b block) zero if max|block| < t."""
    B, C, H, W = x.shape
    y = np.array(x, np.float32)
    keep = np.zeros((B, C, H // b, W // b), bool)
    for bi in range(B):
        for c in range(C):
            for i in range(H // b):
                for j in range(W // b):
                    blk = y[bi, c, i*b:(i+1)*b, j*b:(j+1)*b]
                    k = np.max(np.abs(blk)) >= t
                    keep[bi, c, i, j] = k
                    if not k:
                        y[bi, c, i*b:(i+1)*b, j*b:(j+1)*b] = 0
    return y, keep


def test_infer_matches_manual():
    x = jax.nn.relu(jax.random.normal(K, (2, 3, 8, 8)))
    cfg = ZebraConfig(t_obj=0.8, block_hw=4, mode="infer")
    y, aux = zebra_cnn(x, cfg)
    y_ref, keep = manual_block_mask(np.asarray(x), 0.8, 4)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-6)
    assert np.isclose(float(aux["zero_frac"]), 1 - keep.mean(), atol=1e-6)


def test_bitmap_matches_mask():
    x = jax.random.normal(K, (2, 4, 8, 8))
    cfg = ZebraConfig(t_obj=1.2, block_hw=2, mode="infer")
    y, keep = zebra_infer_bitmap_nchw(x, cfg)
    y2, aux = zebra_cnn(x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2))


def test_train_mode_reg_pulls_to_tobj():
    """Eq. 1: the reg term is ||T_obj - T||^2 summed over channels."""
    x = jax.nn.relu(jax.random.normal(K, (4, 8, 8, 8)))
    tnet = init_threshold_net(K, 8)
    cfg = ZebraConfig(t_obj=0.5, block_hw=4, mode="train")
    _, aux = zebra_cnn(x, cfg, tnet)
    gap = jnp.mean(x, axis=(2, 3))
    thr = gap @ tnet["w"] + tnet["b"]
    expect = jnp.mean(jnp.sum((0.5 - thr) ** 2, axis=-1))
    assert np.isclose(float(aux["reg"]), float(expect), rtol=1e-5)


def test_gradient_modes():
    x = jax.random.normal(K, (2, 4, 8, 8))
    tnet = init_threshold_net(K, 4)
    for gm in ("hard", "ste", "soft"):
        cfg = ZebraConfig(t_obj=0.3, block_hw=4, mode="train", grad_mode=gm)

        def loss(xx):
            y, aux = zebra_cnn(xx, cfg, tnet)
            return jnp.sum(y ** 2)
        g = jax.grad(loss)(x)
        assert np.all(np.isfinite(np.asarray(g))), gm
    # hard: gradient is zero exactly on masked blocks (force thresholds
    # above every activation via the net's bias: T = GAP@W + b)
    tnet_hi = dict(tnet, b=tnet["b"] + 100.0)
    cfg = ZebraConfig(t_obj=10.0, block_hw=4, mode="train", grad_mode="hard")
    g = jax.grad(lambda xx: jnp.sum(zebra_cnn(xx, cfg, tnet_hi)[0] ** 2))(x)
    assert float(jnp.max(jnp.abs(g))) == 0.0
    # ste: gradient flows through masked blocks
    cfg = cfg.replace(grad_mode="ste")
    g = jax.grad(lambda xx: jnp.sum(zebra_cnn(xx, cfg, tnet)[0] * 1.0))(x)
    assert float(jnp.min(jnp.abs(g))) >= 0.0  # finite, defined everywhere


def test_threshold_only_reg_gradient_in_hard_mode():
    """Paper semantics: with hard masking, threshold-net weights learn only
    from the regularizer."""
    x = jax.nn.relu(jax.random.normal(K, (2, 4, 8, 8)))
    tnet = init_threshold_net(jax.random.PRNGKey(1), 4)
    cfg = ZebraConfig(t_obj=0.4, block_hw=4, mode="train", grad_mode="hard")

    def ce_only(tn):   # task-loss part only
        y, aux = zebra_cnn(x, cfg, tn)
        return jnp.sum(y ** 2)
    g = jax.grad(ce_only)(tnet)
    assert float(jnp.max(jnp.abs(g["w"]))) == 0.0

    def reg_only(tn):
        return zebra_cnn(x, cfg, tn)[1]["reg"]
    g2 = jax.grad(reg_only)(tnet)
    assert float(jnp.max(jnp.abs(g2["w"]))) > 0.0


@settings(max_examples=20, deadline=None)
@given(t=st.floats(0.0, 2.0), b=st.sampled_from([2, 4]),
       seed=st.integers(0, 2**30))
def test_property_block_all_or_none(t, b, seed):
    """Every b x b block is either untouched or exactly zero."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, 2, 8, 8))
    cfg = ZebraConfig(t_obj=t, block_hw=b, mode="infer")
    y, _ = zebra_cnn(x, cfg)
    xn, yn = np.asarray(x), np.asarray(y)
    for c in range(2):
        for i in range(8 // b):
            for j in range(8 // b):
                blk_x = xn[0, c, i*b:(i+1)*b, j*b:(j+1)*b]
                blk_y = yn[0, c, i*b:(i+1)*b, j*b:(j+1)*b]
                assert (np.array_equal(blk_y, blk_x)
                        or not blk_y.any())


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**30))
def test_property_zero_frac_monotone_in_tobj(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, 4, 8, 8))
    fracs = []
    for t in (0.0, 0.5, 1.0, 2.0, 4.0):
        cfg = ZebraConfig(t_obj=t, block_hw=4, mode="infer")
        _, aux = zebra_cnn(x, cfg)
        fracs.append(float(aux["zero_frac"]))
    assert all(a <= b + 1e-9 for a, b in zip(fracs, fracs[1:]))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**30))
def test_property_idempotent(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 4, 8, 8))
    cfg = ZebraConfig(t_obj=0.7, block_hw=4, mode="infer")
    y1, _ = zebra_cnn(x, cfg)
    y2, _ = zebra_cnn(y1, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2))


def test_tokens_layout():
    x = jax.random.normal(K, (2, 32, 256))
    cfg = ZebraConfig(t_obj=0.5, block_seq=8, block_ch=128, mode="infer")
    y, aux = zebra_tokens(x, cfg)
    assert y.shape == x.shape
    assert aux["n_blocks"] == (32 // 8) * (256 // 128)
    # train mode with per-channel-block threshold net
    tnet = init_token_threshold_net(K, 256, 2)
    cfgt = cfg.replace(mode="train")
    y2, aux2 = zebra_tokens(x, cfgt, tnet)
    assert np.isfinite(float(aux2["reg"]))


def test_collect_and_mean():
    auxes = [
        {"reg": jnp.float32(1.0), "zero_frac": jnp.float32(0.5), "n_blocks": 10},
        {"reg": jnp.float32(2.0), "zero_frac": jnp.float32(0.0), "n_blocks": 30},
    ]
    assert float(collect_zebra_loss(auxes)) == 3.0
    assert np.isclose(float(mean_zero_frac(auxes)), 0.125)


def test_disabled_passthrough():
    x = jax.random.normal(K, (2, 4, 8, 8))
    y, aux = zebra_cnn(x, ZebraConfig(enabled=False))
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
