"""Fault-tolerance: crash->restore, straggler detection, heartbeat, elastic."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.ft import FTConfig, StepSupervisor


class FlakyStep:
    """Fails once at a chosen step, then recovers (simulated node failure)."""

    def __init__(self, fail_at):
        self.fail_at = fail_at
        self.calls = 0

    def __call__(self, state, batch):
        self.calls += 1
        step = int(state["step"])
        if step == self.fail_at and self.calls == self.fail_at + 1:
            # matches the taxonomy's transient-preemption marker — a bare
            # unclassifiable exception would (correctly) re-raise now
            raise RuntimeError("simulated preemption: device failure")
        new = {"w": state["w"] + batch.mean(), "step": state["step"] + 1}
        return new, {"loss": jnp.float32(1.0 / (step + 1))}


class CountingIter:
    def __init__(self):
        self.i = 0

    def __next__(self):
        self.i += 1
        return jnp.full((4,), float(self.i))

    def restore(self, step):
        self.i = int(step)


def test_crash_restore_resume(tmp_path):
    cfg = FTConfig(ckpt_dir=str(tmp_path), ckpt_every=3, max_failures=2)
    sup = StepSupervisor(cfg)
    state = {"w": jnp.float32(0.0), "step": jnp.int32(0)}
    flaky = FlakyStep(fail_at=5)
    it = CountingIter()
    final, step = sup.run(state, flaky, it, steps=10,
                          loader_state_fn=lambda: it.i)
    assert step == 10
    assert sup.failures == 1
    assert sup.ckpt.latest_step() == 10


def test_resume_or_init_from_disk(tmp_path):
    cfg = FTConfig(ckpt_dir=str(tmp_path), ckpt_every=2)
    sup = StepSupervisor(cfg)
    state = {"w": jnp.float32(7.0), "step": jnp.int32(4)}
    sup.ckpt.save(4, state, {"loader_step": 4})
    sup.ckpt.wait()
    restored, step, extra = sup.resume_or_init(lambda: {"w": jnp.float32(0.0),
                                                        "step": jnp.int32(0)})
    assert step == 4 and float(restored["w"]) == 7.0


def test_straggler_detection(tmp_path):
    sup = StepSupervisor(FTConfig(ckpt_dir=str(tmp_path),
                                  straggler_window=10, straggler_zscore=3.0))
    for _ in range(10):
        assert not sup.check_straggler(0.10 + np.random.rand() * 1e-3)
    assert sup.check_straggler(5.0)          # 50x the mean -> flagged
    assert len(sup.straggler_events) == 1


def test_heartbeat_written(tmp_path):
    sup = StepSupervisor(FTConfig(ckpt_dir=str(tmp_path)))
    sup.heartbeat(12, {"loss": jnp.float32(0.5)})
    hb = json.load(open(sup.hb_path))
    assert hb["step"] == 12 and "time" in hb


def test_elastic_remesh_same_devices():
    """remesh_state re-derives the mesh from live devices and re-shards."""
    from repro.ft import remesh_state
    from repro.launch.mesh import make_host_mesh
    from jax.sharding import PartitionSpec as P
    mesh = make_host_mesh(model=1)
    state = {"w": jnp.ones((8, 4))}
    new_state, new_mesh = remesh_state(
        state, None, mesh,
        lambda s, c, m: jax.tree_util.tree_map(lambda _: P(), s))
    assert new_mesh.size == len(jax.devices())
    np.testing.assert_array_equal(np.asarray(new_state["w"]),
                                  np.asarray(state["w"]))
