"""Optimizers, schedules, gradient compression, data pipeline, checkpoints."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import (ImageDatasetConfig, LMDatasetConfig, StreamingLoader,
                        image_batch, lm_batch)
from repro.optim import (adamw, apply_updates, clip_by_global_norm, sgd,
                         step_decay, warmup_cosine)
from repro.optim.compress import compressed_gradients, init_state

K = jax.random.PRNGKey(0)


def test_sgd_momentum_closed_form():
    lr = 0.1
    opt = sgd(lambda s: jnp.float32(lr), momentum=0.9, weight_decay=0.0)
    p = {"w": jnp.ones((3,))}
    st = opt.init(p)
    g = {"w": jnp.full((3,), 2.0)}
    u1, st = opt.update(g, st, p, jnp.int32(0))
    np.testing.assert_allclose(np.asarray(u1["w"]), -lr * 2.0)
    u2, st = opt.update(g, st, p, jnp.int32(1))
    np.testing.assert_allclose(np.asarray(u2["w"]), -lr * (2.0 + 0.9 * 2.0))


def test_adamw_first_step_is_lr_sized():
    opt = adamw(lambda s: jnp.float32(1e-3), weight_decay=0.0)
    p = {"w": jnp.ones((4,))}
    st = opt.init(p)
    g = {"w": jnp.full((4,), 0.5)}
    u, st = opt.update(g, st, p, jnp.int32(0))
    np.testing.assert_allclose(np.asarray(u["w"]), -1e-3, rtol=1e-3)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((3,), 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    from repro.utils import global_norm
    assert np.isclose(float(global_norm(clipped)), 1.0, rtol=1e-5)


def test_schedules():
    f = step_decay(0.1, (0.5, 0.75), 100)
    assert np.isclose(float(f(0)), 0.1)
    assert np.isclose(float(f(60)), 0.01)
    assert np.isclose(float(f(90)), 0.001)
    w = warmup_cosine(1.0, 10, 100)
    assert float(w(0)) < 0.2
    assert np.isclose(float(w(10)), 1.0, atol=0.1)


def test_compression_bf16_and_int8_error_feedback():
    g = {"w": jax.random.normal(K, (256,))}
    st = init_state(g, "bf16")
    dec, st = compressed_gradients(g, st, "bf16")
    assert float(jnp.max(jnp.abs(dec["w"] - g["w"]))) < 0.01
    # int8: single-shot error is bounded; error feedback carries residual
    st8 = init_state(g, "int8")
    dec8, st8 = compressed_gradients(g, st8, "int8")
    resid = g["w"] - dec8["w"]
    np.testing.assert_allclose(np.asarray(st8.error["w"]), np.asarray(resid),
                               rtol=1e-5, atol=1e-6)
    # accumulated compressed sum converges to true sum (bias-free)
    total_dec = jnp.zeros_like(g["w"])
    st8 = init_state(g, "int8")
    for _ in range(50):
        dec8, st8 = compressed_gradients(g, st8, "int8")
        total_dec = total_dec + dec8["w"]
    np.testing.assert_allclose(np.asarray(total_dec / 50), np.asarray(g["w"]),
                               atol=0.01)


def test_data_determinism_and_host_sharding():
    cfg = ImageDatasetConfig()
    a1, l1 = image_batch(cfg, 8, 3)
    a2, l2 = image_batch(cfg, 8, 3)
    np.testing.assert_array_equal(a1, a2)
    np.testing.assert_array_equal(l1, l2)
    b1, _ = image_batch(cfg, 8, 4)
    assert not np.array_equal(a1, b1)
    # hosts draw disjoint streams
    mk = lambda b, s: image_batch(cfg, b, s)
    h0 = StreamingLoader(mk, 8, host_id=0, n_hosts=2)
    h1 = StreamingLoader(mk, 8, host_id=1, n_hosts=2)
    x0, _ = next(h0)
    x1, _ = next(h1)
    assert not np.array_equal(x0, x1)
    assert x0.shape[0] == 4


def test_lm_batch_structure():
    cfg = LMDatasetConfig(vocab=1000, effective_vocab=101, noise_p=0.0)
    t = lm_batch(cfg, 4, 64, 0)
    assert t.shape == (4, 65) and t.dtype == np.int32
    assert t.max() < 1000
    # noiseless stream is exactly predictable by the affine rule
    x = t[0].astype(np.int64)
    diffs_consistent = 0
    for i in range(1, 30):
        # consecutive pairs satisfy x_{t+1} = a x_t + b (mod V) for fixed a,b
        pass
    # weaker check: sequence is eventually periodic mod effective vocab
    assert len(np.unique(x)) <= 101


def test_checkpoint_roundtrip_and_keep_last(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2, async_save=False)
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "n": {"b": jnp.ones((4,), jnp.bfloat16)}}
    for s in (10, 20, 30):
        mgr.save(s, jax.tree_util.tree_map(lambda x: x * s, tree),
                 extra={"loader_step": s})
    assert mgr.all_steps() == [20, 30]
    step, restored, extra = mgr.restore(tree)
    assert step == 30 and extra["loader_step"] == 30
    np.testing.assert_allclose(np.asarray(restored["a"], np.float32),
                               np.asarray(tree["a"]) * 30)
    assert restored["n"]["b"].dtype == tree["n"]["b"].dtype


def test_checkpoint_async_and_atomicity(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=3, async_save=True)
    tree = {"w": jnp.ones((8, 8))}
    mgr.save(1, tree)
    mgr.wait()
    assert mgr.latest_step() == 1
    # no tmp dirs left behind
    assert not [d for d in os.listdir(tmp_path) if d.startswith("tmp.")]
