"""Trainable kernel backends (kernels.grad + the capability registry):
``jax.grad`` through a pallas/stream Zebra site equals the reference
backend across dtypes {f32, bf16}, layouts {tokens, NCHW}, all three
gradient modes, and the degenerate bs=1 decode fallback — plus the
end-to-end FFN/train-step acceptance checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ZebraConfig, zebra_site

K = jax.random.PRNGKey(0)
KERNEL_TRAINABLE = ("pallas", "stream")
GRAD_MODES = ("hard", "ste", "soft")


def _blocky_tokens(key, B, S, D, bs, bc, dtype=jnp.float32):
    x = jax.random.normal(key, (B, S, D), jnp.float32)
    scale = jax.random.uniform(jax.random.fold_in(key, 1),
                               (B * S // bs, D // bc))
    x = x * jnp.repeat(jnp.repeat(scale, bs, 0), bc, 1).reshape(B, S, D)
    return x.astype(dtype)


def _train_cfg(backend, grad_mode, **kw):
    kw.setdefault("t_obj", 0.5)
    return ZebraConfig(mode="train", backend=backend,
                       grad_mode=grad_mode, use_tnet=False, **kw)


def _grads(x, cfg, layout="tokens"):
    def loss(xx):
        y, _ = zebra_site(xx, cfg, layout=layout)
        return jnp.sum((y.astype(jnp.float32)) ** 2)
    return jax.grad(loss)(x)


# ---------------------------------------------------------------------------
# The parity matrix (acceptance: <= 1e-5 in f32; same ops -> tight in bf16)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", KERNEL_TRAINABLE)
@pytest.mark.parametrize("grad_mode", GRAD_MODES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_token_grad_parity(backend, grad_mode, dtype):
    x = _blocky_tokens(K, 2, 16, 256, 8, 128, dtype)
    g_ref = _grads(x, _train_cfg("reference", grad_mode))
    g_ker = _grads(x, _train_cfg(backend, grad_mode))
    atol = 1e-5 if dtype == jnp.float32 else 1e-2
    np.testing.assert_allclose(np.asarray(g_ref, np.float32),
                               np.asarray(g_ker, np.float32), atol=atol)
    # forward values are the deployed hard mask on every mode/backend
    y_ref, a_ref = zebra_site(x, _train_cfg("reference", grad_mode))
    y_ker, a_ker = zebra_site(x, _train_cfg(backend, grad_mode))
    np.testing.assert_array_equal(np.asarray(y_ref, np.float32),
                                  np.asarray(y_ker, np.float32))
    assert a_ker.backend == backend                       # no degrade
    assert np.isclose(float(a_ref.zero_frac), float(a_ker.zero_frac))


@pytest.mark.parametrize("backend", KERNEL_TRAINABLE)
@pytest.mark.parametrize("grad_mode", GRAD_MODES)
@pytest.mark.parametrize("shape,block_hw", [((2, 4, 8, 8), 4),
                                            ((2, 3, 2, 2), 4)])   # shrink-to-2
def test_nchw_grad_parity(backend, grad_mode, shape, block_hw):
    x = jax.nn.relu(jax.random.normal(K, shape))
    cfg_r = _train_cfg("reference", grad_mode, block_hw=block_hw, t_obj=0.6)
    cfg_k = _train_cfg(backend, grad_mode, block_hw=block_hw, t_obj=0.6)
    g_ref = _grads(x, cfg_r, layout="nchw")
    g_ker = _grads(x, cfg_k, layout="nchw")
    np.testing.assert_allclose(np.asarray(g_ref), np.asarray(g_ker),
                               atol=1e-5)


def test_hard_mode_f32_grad_is_bitwise_and_zero_on_dead_blocks():
    x = _blocky_tokens(K, 2, 16, 256, 8, 128)
    g_ref = _grads(x, _train_cfg("reference", "hard"))
    for backend in KERNEL_TRAINABLE:
        g = _grads(x, _train_cfg(backend, "hard"))
        np.testing.assert_array_equal(np.asarray(g_ref), np.asarray(g))
    # dead blocks carry exactly zero task gradient (paper semantics)
    cfg_hi = _train_cfg("pallas", "hard", t_obj=0.8)
    y, aux = zebra_site(x, cfg_hi)
    assert 0.0 < float(aux.zero_frac) < 1.0
    dead = np.asarray(y) == 0
    g = np.asarray(_grads(x, cfg_hi))
    assert not np.any(g[dead & (np.asarray(x) != 0)])


def test_ste_mode_grad_flows_through_dead_blocks():
    x = _blocky_tokens(K, 2, 16, 256, 8, 128)
    for backend in KERNEL_TRAINABLE + ("reference",):
        g = np.asarray(jax.grad(lambda xx: jnp.sum(
            zebra_site(xx, _train_cfg(backend, "ste"))[0]))(x))
        np.testing.assert_array_equal(g, np.ones_like(g))   # identity


@pytest.mark.parametrize("backend", KERNEL_TRAINABLE)
def test_degenerate_bs1_decode_grad_is_exactly_reference(backend):
    """S=1 decode-shaped train maps fall back to reference — gradients and
    the surfaced degrade reason must be exactly the reference path's."""
    x = jax.random.normal(K, (2, 1, 256))
    cfg_k = _train_cfg(backend, "hard")
    g_ref = _grads(x, _train_cfg("reference", "hard"))
    g_ker = _grads(x, cfg_k)
    np.testing.assert_array_equal(np.asarray(g_ref), np.asarray(g_ker))
    _, aux = zebra_site(x, cfg_k)
    assert aux.backend == "reference(degenerate-rows)"


# ---------------------------------------------------------------------------
# Live train-time observables on the kernel backends
# ---------------------------------------------------------------------------

def test_train_reg_is_realized_zero_block_count_on_every_trainable_backend():
    x = _blocky_tokens(K, 2, 16, 256, 8, 128)
    ref_aux = zebra_site(x, _train_cfg("reference", "hard"))[1]
    for backend in KERNEL_TRAINABLE:
        aux = zebra_site(x, _train_cfg(backend, "hard"))[1]
        expect = float(aux.zero_frac) * aux.n_blocks
        assert np.isclose(float(aux.reg), expect)
        assert np.isclose(float(aux.reg), float(ref_aux.reg))
        # the count is an observable, not a gradient source
        g = jax.grad(lambda xx: jnp.float32(
            zebra_site(xx, _train_cfg(backend, "hard"))[1].reg))(x)
        assert not np.any(np.asarray(g))


def test_train_stream_backend_meters_bytes():
    """measured_bytes stays live while TRAINING through the stream
    backend — the bytes the deployed site will move are observable in the
    phase that shapes the zero blocks."""
    x = _blocky_tokens(K, 2, 16, 256, 8, 128, jnp.bfloat16)
    y, aux = zebra_site(x, _train_cfg("stream", "hard"))
    n_blocks_total = (2 * 16 // 8) * (256 // 128)
    live = round((1.0 - float(aux.zero_frac)) * n_blocks_total)
    expect = live * 8 * 128 * 2 + (n_blocks_total + 7) // 8
    assert float(aux.measured_bytes) == expect
    # pallas moves the map dense: no stream, no bytes
    _, ap = zebra_site(x, _train_cfg("pallas", "hard"))
    assert float(ap.measured_bytes) == 0


# ---------------------------------------------------------------------------
# End-to-end: grad of a real FFN / train step through the kernel site
# ---------------------------------------------------------------------------

def test_ffn_loss_grad_through_pallas_site_matches_reference():
    """Acceptance: jax.grad of a loss through a pallas-backend Zebra site
    (params AND activations) matches the reference backend <= 1e-5 f32."""
    from repro.models.lm.config import LMConfig
    from repro.models.lm.ffn import ffn_apply, ffn_init

    cfg = LMConfig(n_layers=1, d_model=64, n_heads=4, d_ff=256, vocab=128,
                   zebra_t_obj=0.5, zebra_tnet=False)
    p = ffn_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    assert "zebra_tnet" not in p                 # constant-threshold mode
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64), jnp.float32)

    def loss(params, backend):
        y, _ = ffn_apply(params, x, cfg.replace(zebra_backend=backend),
                         "train")
        return jnp.sum(y ** 2)

    for backend in KERNEL_TRAINABLE:
        g_ref = jax.grad(loss)(p, "reference")
        g_ker = jax.grad(loss)(p, backend)
        for k in g_ref:
            np.testing.assert_allclose(np.asarray(g_ref[k]),
                                       np.asarray(g_ker[k]), atol=1e-5,
                                       err_msg=f"{backend}/{k}")


def test_lm_train_step_stream_backend_under_remat_and_grad_accum():
    """Regression: training through the STREAM backend inside
    jax.checkpoint'd layer bodies (remat) must not choke on the launch's
    integer outputs (float0 tangents), and the measured-bytes metric is
    extensive and exact across gradient-accumulation microbatching."""
    from repro.data import LMDatasetConfig, lm_batch
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import make_train_state_shape, make_train_step
    from repro.models.lm import LM, LMConfig
    from repro.optim import adamw, warmup_cosine

    mesh = make_host_mesh(model=1)
    vals = {}
    for K_acc in (1, 2):
        cfg = LMConfig(n_layers=1, d_model=64, n_heads=4, n_kv_heads=4,
                       d_ff=256, vocab=256, zebra_t_obj=0.2,
                       zebra_backend="stream", zebra_tnet=False,
                       grad_accum=K_acc)
        model = LM(cfg)
        opt = adamw(warmup_cosine(1e-3, 2, 20))
        _, init_fn = make_train_state_shape(model, opt)
        state = jax.jit(init_fn)(jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(model, opt, mesh))
        batch = {"tokens": jnp.asarray(
            lm_batch(LMDatasetConfig(vocab=256), 4, 32, 0))}
        _, m = step(state, batch)
        assert float(m["grad_norm"]) > 0
        vals[K_acc] = (float(m["measured_bytes_hi"]) * 2 ** 24
                       + float(m["measured_bytes_lo"]))
    assert vals[1] > 0 and vals[1] == vals[2]     # extensive, K-invariant


def test_cnn_train_step_runs_on_pallas_backend():
    """2-step CNN train smoke on the pallas backend: loss finite, grads
    nonzero, loss equal to the reference backend (same function)."""
    from repro.data import ImageDatasetConfig, image_batch
    from repro.optim import sgd, step_decay
    from repro.train import CNNTrainer, CNNTrainConfig

    ds = ImageDatasetConfig("syn-cifar10", 10, 8, seed=3)
    losses = {}
    for backend in ("reference", "pallas"):
        zcfg = ZebraConfig(t_obj=0.25, block_hw=4, backend=backend,
                           use_tnet=False)
        cfg = CNNTrainConfig(model="resnet18", width_mult=0.125, dataset=ds,
                             batch=8, steps=2, zebra=zcfg, seed=0)
        tr = CNNTrainer(cfg, sgd(step_decay(0.05, total_steps=2)))
        state = tr.init_state()
        images, labels = image_batch(ds, cfg.batch, 0)
        for _ in range(2):
            state, metrics = tr._train_step(state, images, labels)
        assert np.isfinite(float(metrics["loss"]))
        assert float(metrics["grad_norm"]) > 0
        losses[backend] = float(metrics["loss"])
    assert np.isclose(losses["reference"], losses["pallas"], atol=1e-4)
