"""Bandwidth accounting — paper Eq. (2)-(5) and Table V scale checks."""
import numpy as np

from repro.core import (MapSpec, TokenMapSpec, stored_bits, conv_flops,
                        reduced_bandwidth_pct, index_overhead_pct,
                        required_bandwidth_bytes, zebra_overhead_flops,
                        overhead_ratio)
from repro.models.cnn import build as build_cnn


def test_eq2_eq3():
    s = MapSpec(c=64, h=32, w=32, bits=16, block=4)
    assert s.map_bits == 64 * 32 * 32 * 16
    assert s.index_bits == 64 * 32 * 32 // 16          # Eq. 3
    # Eq. 2 at 50% reduction
    assert stored_bits(s, 0.5) == s.map_bits * 0.5 + s.index_bits


def test_index_overhead_magnitude():
    """Paper Table V: index overhead is fractions of a percent (1 bit per
    block of block^2 elements of B bits)."""
    s = MapSpec(c=64, h=32, w=32, bits=16, block=4)
    pct = index_overhead_pct([s])
    assert np.isclose(pct, 100.0 / (16 * 16))          # 1/(b^2 * B)
    assert pct < 1.0


def test_reduced_bandwidth_net_of_overhead():
    s = MapSpec(c=8, h=8, w=8, bits=16, block=4)
    # zero reduction -> negative saving equal to index overhead
    assert reduced_bandwidth_pct([s], [0.0]) < 0
    assert reduced_bandwidth_pct([s], [1.0]) > 99.0


def test_eq4_eq5_overhead_negligible():
    # Eq. 4 vs Eq. 5 for a typical conv layer
    r = overhead_ratio(c_in=128, h=16, w=16, k=3, c_out=128, stride=1)
    assert r == zebra_overhead_flops(128, 16, 16) / conv_flops(128, 16, 16, 3, 128)
    assert r < 1e-2                                     # "totally negligible"


def test_resnet18_required_bandwidth_scale():
    """Table V: ResNet-18 on CIFAR-10 required bandwidth ~ 2.06 MB/image at
    8-bit activations. Our CIFAR ResNet-18 map inventory should land in the
    same ballpark (architectural variants differ slightly)."""
    model = build_cnn("resnet18", 10, 32)
    from repro.core import ZebraConfig
    specs = model.map_specs(32, ZebraConfig(act_bits=8, block_hw=4))
    mb = required_bandwidth_bytes(specs) / 2 ** 20
    # paper reports 2.06 MB for its variant; our CIFAR-stem inventory is
    # self-consistent at ~0.5 MB — same order of magnitude
    assert 0.2 < mb < 4.0, mb
    assert index_overhead_pct(specs) < 1.0              # Table V: ~0.2%


def test_eq2_eq3_golden_values():
    """Pinned numbers for the paper's reference map (64x32x32, B=16, b=4)."""
    s = MapSpec(c=64, h=32, w=32, bits=16, block=4)
    assert s.map_bits == 1_048_576
    assert s.index_bits == 4_096
    assert stored_bits(s, 0.0) == 1_052_672.0        # dense + index
    assert np.isclose(stored_bits(s, 0.7), 318_668.8)
    assert stored_bits(s, 1.0) == 4_096.0            # index only


def test_reduced_bandwidth_golden_at_70pct_operating_point():
    """The paper's ~70% operating point: net saving = 70% minus the
    1/(b^2*B) index overhead -> 69.609375% exactly for b=4, B=16."""
    s = MapSpec(c=64, h=32, w=32, bits=16, block=4)
    assert reduced_bandwidth_pct([s], [0.7]) == 70.0 - 100.0 / (4 * 4 * 16)
    assert np.isclose(reduced_bandwidth_pct([s], [0.7]), 69.609375)
    # token-map layout at the same point: index is 1/(bs*bc*B) of the map
    t = TokenMapSpec(s=256, d=1024, bits=16, block_seq=8, block_ch=128)
    assert np.isclose(reduced_bandwidth_pct([t], [0.7]),
                      70.0 - 100.0 / (8 * 128 * 16))
    assert np.isclose(index_overhead_pct([t]), 100.0 / (8 * 128 * 16))


def test_eq4_eq5_golden_values():
    assert conv_flops(128, 16, 16, 3, 128) == 37_748_736
    assert conv_flops(128, 16, 16, 3, 128, stride=2) == 18_874_368
    assert zebra_overhead_flops(128, 16, 16) == 32_768
    assert np.isclose(overhead_ratio(128, 16, 16, 3, 128),
                      32_768 / 37_748_736)


def test_token_map_spec():
    s = TokenMapSpec(s=4096, d=8192, bits=16, block_seq=8, block_ch=128)
    assert s.n_blocks == (4096 // 8) * (8192 // 128)
    assert s.index_bits == s.n_blocks
