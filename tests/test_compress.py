"""Compressed activation transport: pack/unpack round trips, kernel vs
oracle parity, measured-bytes accounting vs Eq. 2/3, and the persistence
codec."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint import load_compressed_acts, save_compressed_acts
from repro.compress import (BandwidthMeter, CompressedMap, compress,
                            compress_tree, decompress, decompress_tree,
                            nonzero_bitmap, pack_bitmap, transport_tokens,
                            unpack_bitmap)
from repro.core import stored_bits
from repro.kernels import (ref, zebra_mask_op, zebra_pack_op, zebra_spmm_op,
                           zebra_unpack_op)
from repro.utils import cdiv

K = jax.random.PRNGKey(0)


def _blocky(key, M, Kd, bs, bc, live_p=0.5, dtype=jnp.float32):
    """Block-magnitude-structured activations (as in test_kernels)."""
    x = jax.random.normal(key, (M, Kd), jnp.float32)
    scale = (jax.random.uniform(jax.random.fold_in(key, 1),
                                (M // bs, Kd // bc)) < live_p)
    x = x * jnp.repeat(jnp.repeat(scale.astype(jnp.float32), bs, 0), bc, 1) \
        * 2.0 + x * 0.01
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# Round trip + parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,Kd,bs,bc", [
    (16, 128, 8, 128), (64, 512, 8, 128), (128, 256, 16, 64),
    (24, 384, 8, 128), (32, 256, 8, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pack_unpack_roundtrip_sweep(M, Kd, bs, bc, dtype):
    x = _blocky(K, M, Kd, bs, bc, dtype=dtype)
    y, bm = zebra_mask_op(x, 0.5, bs=bs, bc=bc)
    p, nl = zebra_pack_op(y, bm, bs=bs, bc=bc)
    z = zebra_unpack_op(p, bm, bs=bs, bc=bc)
    np.testing.assert_array_equal(np.asarray(z), np.asarray(y))   # bit-exact
    assert int(nl) == int(np.asarray(bm).sum())


@pytest.mark.parametrize("t_obj,expect", [
    (0.0, 0.0),          # zero_frac 0: every block survives
    (0.5, None),         # ~live_p dead
    (1e9, 1.0),          # zero_frac 1: nothing survives
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_roundtrip_zero_fraction_extremes(t_obj, expect, dtype):
    x = _blocky(K, 32, 256, 8, 128, dtype=dtype)
    y, bm = zebra_mask_op(x, t_obj)
    cm = compress(y, bm)
    np.testing.assert_array_equal(np.asarray(decompress(cm)), np.asarray(y))
    if expect is not None:
        assert cm.zero_frac() == expect


def test_kernel_matches_oracle():
    x = _blocky(K, 64, 512, 8, 128)
    y, bm = zebra_mask_op(x, 0.5)
    p, nl = zebra_pack_op(y, bm)
    pr, nlr = ref.zebra_pack_ref(y, bm, 8, 128)
    np.testing.assert_array_equal(np.asarray(p), np.asarray(pr))
    assert int(nl) == int(nlr)
    np.testing.assert_array_equal(
        np.asarray(zebra_unpack_op(p, bm)),
        np.asarray(ref.zebra_unpack_ref(pr, bm, 8, 128)))


def test_payload_is_live_blocks_in_order():
    """Stream layout contract: live slots come first in CONSUMER order —
    grouped by K-block column, columns ascending, rows ascending within
    a column (kernels.schedule) — and the tail is zero. Block (2,0) is
    in column 0, so it precedes block (0,1) even though it comes later
    in row-major order."""
    bs, bc = 8, 128
    x = jnp.zeros((24, 256), jnp.float32)
    x = x.at[:8, 128:].set(1.0)      # block (0,1): column 1 -> slot 1
    x = x.at[16:, :128].set(2.0)     # block (2,0): column 0 -> slot 0
    bm = nonzero_bitmap(x, bs, bc)
    p, nl = zebra_pack_op(x, bm)
    assert int(nl) == 2
    np.testing.assert_array_equal(np.asarray(p[0]), 2 * np.ones((bs, bc)))
    np.testing.assert_array_equal(np.asarray(p[1]), np.ones((bs, bc)))
    np.testing.assert_array_equal(np.asarray(p[2:]), 0.0)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**30), live=st.floats(0.05, 0.95),
       dt=st.sampled_from(["float32", "bfloat16"]))
def test_property_roundtrip_lossless(seed, live, dt):
    dtype = jnp.dtype(dt)
    x = _blocky(jax.random.PRNGKey(seed), 32, 256, 8, 128, live, dtype)
    y, bm = zebra_mask_op(x, 0.5)
    cm = compress(y, bm)
    np.testing.assert_array_equal(np.asarray(decompress(cm)), np.asarray(y))
    # and lossless on the UNMASKED map via the nonzero bitmap
    cm2 = compress(x)
    np.testing.assert_array_equal(np.asarray(decompress(cm2)), np.asarray(x))


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**30), live=st.floats(0.1, 0.9))
def test_property_mask_pack_unpack_spmm_matches_ref(seed, live):
    """zebra_mask -> pack -> unpack -> spmm == zebra_mask_then_spmm_ref."""
    bs, bc = 8, 128
    x = _blocky(jax.random.PRNGKey(seed), 32, 256, bs, bc, live)
    w = jax.random.normal(jax.random.PRNGKey(seed + 1), (256, 64), jnp.float32)
    y, bm = transport_tokens(x, 0.5, bs=bs, bc=bc)
    out = zebra_spmm_op(y, w, bm, bs=bs, bc=bc)
    out_ref, bm_ref = ref.zebra_mask_then_spmm_ref(x, w, 0.5, bs, bc)
    np.testing.assert_array_equal(np.asarray(bm), np.asarray(bm_ref))
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Bitmap codec
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**30), nm=st.integers(1, 9), nk=st.integers(1, 9))
def test_property_bitmap_pack_roundtrip(seed, nm, nk):
    bm = (jax.random.uniform(jax.random.PRNGKey(seed), (nm, nk)) < 0.5
          ).astype(jnp.int8)
    packed = pack_bitmap(bm)
    assert packed.dtype == jnp.uint8
    assert packed.shape == (cdiv(nm * nk, 8),)
    np.testing.assert_array_equal(np.asarray(unpack_bitmap(packed, nm, nk)),
                                  np.asarray(bm))


def test_bitmap_bit_order_matches_numpy_packbits():
    bm = jnp.asarray(np.arange(16).reshape(2, 8) % 3 == 0, jnp.int8)
    ours = np.asarray(pack_bitmap(bm))
    ref_bytes = np.packbits(np.asarray(bm).reshape(-1), bitorder="little")
    np.testing.assert_array_equal(ours, ref_bytes)


# ---------------------------------------------------------------------------
# Measured bytes == Eq. 2/3
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("t_obj", [0.0, 0.5, 1e9])
def test_measured_bytes_match_stored_bits(dtype, t_obj):
    x = _blocky(K, 64, 512, 8, 128, dtype=dtype)
    y, bm = zebra_mask_op(x, t_obj)
    cm = compress(y, bm)
    # payload: data term of Eq. 2, exactly
    n_live = int(cm.n_live)
    assert cm.payload_bytes() == n_live * 8 * 128 * jnp.dtype(dtype).itemsize
    data_bits = cm.spec().map_bits * (1.0 - cm.zero_frac())
    assert cm.payload_bytes() * 8 == round(data_bits)
    # index: Eq. 3 rounded up to whole bytes
    assert cm.index_bytes() == cdiv(cm.spec().index_bits, 8)
    # total: within index-padding rounding of stored_bits
    predicted = stored_bits(cm.spec(), cm.zero_frac()) / 8
    assert 0 <= cm.measured_bytes() - predicted < 1.0 + 1e-6


def test_meter_reconciles_and_reports():
    meter = BandwidthMeter()
    for i, t in enumerate((0.0, 0.5, 1e9)):
        x = _blocky(jax.random.PRNGKey(i), 32, 256, 8, 128)
        y, bm = zebra_mask_op(x, t)
        meter.record(f"site{i}", compress(y, bm))
    meter.record_dense("odd", 123)
    rec = meter.reconcile()
    assert rec["n_sites"] == 3
    assert rec["max_abs_delta_bytes"] < 1.0
    rep = meter.report()
    assert "TOTAL" in rep and "site0" in rep
    assert meter.dense_bytes() == 3 * 32 * 256 * 4 + 123
    # all-dead map still pays the index: measured reduction < 100%
    assert 0.0 < meter.measured_reduction_pct() < 100.0


def test_meter_flags_bad_site():
    meter = BandwidthMeter()
    x = _blocky(K, 32, 256, 8, 128)
    y, bm = zebra_mask_op(x, 0.5)
    r = meter.record("s", compress(y, bm))
    r.payload_bytes += 4096            # corrupt the measurement
    with pytest.raises(AssertionError):
        meter.reconcile()


# ---------------------------------------------------------------------------
# Pytree transport + persistence
# ---------------------------------------------------------------------------

def test_tree_transport_lossless_and_metered():
    key = jax.random.PRNGKey(3)
    k4 = jax.random.normal(key, (2, 16, 4, 64), jnp.bfloat16)
    k4 = k4 * (jnp.abs(k4) > 1.5)          # sparsify
    tree = {"k": k4, "small": jnp.ones((3, 5), jnp.float32),
            "ints": jnp.arange(10)}
    meter = BandwidthMeter()
    ct = compress_tree(tree, meter=meter, site="kv")
    assert isinstance(ct["k"], CompressedMap)
    assert not isinstance(ct["small"], CompressedMap)   # indivisible -> dense
    dt = decompress_tree(ct)
    for name in tree:
        np.testing.assert_array_equal(np.asarray(dt[name]),
                                      np.asarray(tree[name]))
    assert any(r.site == "kv/k" and r.compressed for r in meter.records)
    meter.reconcile()


def test_compressed_map_is_pytree():
    x = _blocky(K, 16, 128, 8, 128)
    cm = compress(x)
    leaves, treedef = jax.tree_util.tree_flatten(cm)
    cm2 = jax.tree_util.tree_unflatten(treedef, leaves)
    np.testing.assert_array_equal(np.asarray(decompress(cm2)), np.asarray(x))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_checkpoint_compressed_acts_roundtrip_and_shrinks(dtype):
    x = _blocky(K, 64, 512, 8, 128, live_p=0.3, dtype=dtype)
    y, _ = zebra_mask_op(x, 0.5)
    acts = {"ffn_hidden": np.asarray(y), "odd": np.ones((3, 5), np.float32)}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "acts.npz")
        stats = save_compressed_acts(path, acts)
        back = load_compressed_acts(path)
        for name in acts:
            assert back[name].dtype == acts[name].dtype
            np.testing.assert_array_equal(back[name], acts[name])
        assert stats["ffn_hidden"]["stored_bytes"] \
            < stats["ffn_hidden"]["dense_bytes"]
        assert stats["odd"]["stored_bytes"] == acts["odd"].nbytes


def test_checkpoint_acts_dense_mode_and_f64_fallback(tmp_path):
    """save_acts(compressed=False) must be readable by restore_acts, and
    float64 maps (which jnp would downcast) take the dense path bit-exact."""
    from repro.checkpoint import CheckpointManager

    x = np.random.RandomState(0).randn(16, 256).astype(np.float32)
    x64 = np.random.RandomState(1).randn(16, 256)
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save_acts(1, {"h": x}, compressed=False)
    np.testing.assert_array_equal(mgr.restore_acts(1)["h"], x)
    stats = mgr.save_acts(2, {"h64": x64})
    back = mgr.restore_acts(2)
    assert back["h64"].dtype == np.float64
    np.testing.assert_array_equal(back["h64"], x64)
    assert stats["h64"]["stored_bytes"] == x64.nbytes


# ---------------------------------------------------------------------------
# The serve-path integration point
# ---------------------------------------------------------------------------

def test_ffn_use_kernel_transport_matches_jnp_site():
    from repro.models.lm.config import LMConfig
    from repro.models.lm.ffn import ffn_apply, ffn_init

    cfg = LMConfig(n_layers=1, d_model=64, n_heads=4, d_ff=256, vocab=128,
                   zebra_t_obj=0.5, zebra_block_seq=8, zebra_block_ch=128)
    p = ffn_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64), jnp.float32)
    y0, aux0 = ffn_apply(p, x, cfg, "infer")
    y1, aux1 = ffn_apply(p, x, cfg.replace(use_kernel=True), "infer")
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=1e-6, atol=1e-6)
    # named SiteAux fields (site engine): zero_frac and n_blocks agree
    assert np.isclose(float(aux0.zero_frac), float(aux1.zero_frac))
    assert float(aux0.n_blocks) == float(aux1.n_blocks)
    assert aux0.backend == "reference" and aux1.backend == "stream"
