"""Dry-run plumbing on a multi-device host mesh (subprocess: the 8-device
XLA flag must not leak into the main test process — smoke tests see 1 dev)."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, types, jax
import repro.configs as C
import repro.launch.steps as steps
from repro.configs.shapes import SHAPES, ShapeCell
import repro.configs.granite_moe_1b_a400m as gm
cfgR = gm.reduced().replace(attn_chunk=64)
C._ARCH_MODULES["R"] = "granite_moe_1b_a400m"
mod = types.SimpleNamespace(CONFIG=cfgR, reduced=lambda: cfgR)
_orig = C._mod
C._mod = lambda a: mod if a == "R" else _orig(a)
SHAPES["t_train"] = ShapeCell("t_train", 128, 8, "train")
SHAPES["t_decode"] = ShapeCell("t_decode", 128, 8, "decode")
SHAPES["t_long"] = ShapeCell("t_long", 128, 1, "decode")   # batch=1 path
from repro.launch.mesh import _make_mesh
mesh = _make_mesh((4, 2), ("data", "model"))
from repro.launch import roofline as rl
out = {}
for shape in ("t_train", "t_decode", "t_long"):
    cell = steps.build_cell("R", shape, mesh)
    with mesh:
        compiled = cell.fn.lower(*cell.args).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)): ca = ca[0]
    coll = rl.collective_bytes(compiled.as_text())
    out[shape] = {"flops": float(ca.get("flops", 0)),
                  "coll": coll["total"], "count": coll["count"]}
print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_sharded_cells_compile_on_8_devices():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(os.path.dirname(__file__)),
                       timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][0]
    out = json.loads(line[len("RESULT "):])
    assert out["t_train"]["flops"] > 0
    assert out["t_train"]["count"] > 0          # collectives present (MoE/EP)
    assert out["t_decode"]["flops"] > 0
    assert out["t_long"]["flops"] > 0           # batch=1 decode shards OK
