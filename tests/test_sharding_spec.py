"""distributed/sharding.py + ctx.hint — the previously untested rule layer.

All mesh-dependent assertions run in ONE subprocess on 8 forced host
devices (the XLA flag must not leak into the main test process), mesh
(4 data x 2 model): ``spec_for``'s kv-axis fallback, ``_axis_ok``'s
non-divisible degrade, the pure-DP profile rewriting "model" -> None,
``batch_spec``'s axis dropping, and ``ctx.hint`` dropping unknown /
non-dividing axes under jit.
"""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.distributed import sharding as sh
from repro.distributed.ctx import sharding_hints, hint
from repro.launch.mesh import _make_mesh
from repro.models.lm.config import LMConfig

mesh = _make_mesh((4, 2), ("data", "model"))
out = {}
tp = LMConfig(sharding_profile="tp")            # n_kv_heads=4, model=2 ok
kv_bad = tp.replace(n_kv_heads=3)               # 3 % 2 != 0 -> kv fallback
dp = LMConfig(sharding_profile="dp")

def spec(names, shape, cfg):
    return [list(a) if isinstance(a, tuple) else a
            for a in sh.spec_for(names, shape, cfg, mesh)]

# --- kv-axis fallback: wk shards heads over "model" only when divisible ---
out["wk_tp"] = spec(("layers", "attn", "wk"), (512, 4, 128), tp)
out["wk_kv_bad"] = spec(("layers", "attn", "wk"), (512, 3, 128), kv_bad)

# --- _axis_ok non-divisible degrade: d_ff=100 not divisible by model=2 ---
out["w_up_ok"] = spec(("layers", "ffn", "w_up"), (512, 2048), tp)
out["w_up_bad"] = spec(("layers", "ffn", "w_up"), (512, 99), tp)
# data axis (4) must divide the fan-in too
out["w_up_bad_data"] = spec(("layers", "ffn", "w_up"), (510, 2048), tp)

# --- pure-DP profile: every "model" rewritten to None ---
out["w_up_dp"] = spec(("layers", "ffn", "w_up"), (512, 2048), dp)
out["embed_dp"] = spec(("embed",), (32000, 512), dp)
out["embed_tp"] = spec(("embed",), (32000, 512), tp)

# --- run-stacked leaves get the leading None prepended ---
out["wq_stacked"] = spec(("layers", "attn", "wq"), (8, 512, 8, 64), tp)

# --- unknown leaves replicate ---
out["unknown"] = spec(("whatever", "mystery_w"), (16, 16), tp)

# --- batch_spec axis dropping ---
out["bs_8"] = [list(a) if isinstance(a, tuple) else a
               for a in sh.batch_spec(mesh, 3, batch=8)]
out["bs_1"] = [list(a) if isinstance(a, tuple) else a
               for a in sh.batch_spec(mesh, 3, batch=1)]
out["bs_dp"] = [list(a) if isinstance(a, tuple) else a
                for a in sh.batch_spec(mesh, 3, batch=8, cfg=dp)]

# --- ctx.hint: unknown and non-dividing axes drop under jit ---
def spec_of(x):
    s = getattr(x, "sharding", None)
    return getattr(s, "spec", None)

with sharding_hints(mesh):
    ok = jax.jit(lambda x: hint(x, "data", "model"))(
        jnp.zeros((8, 256)))
    bad_axis = jax.jit(lambda x: hint(x, "data", "nonexistent"))(
        jnp.zeros((8, 256)))
    bad_div = jax.jit(lambda x: hint(x, "data", "model"))(
        jnp.zeros((8, 255)))                      # 255 % 2 != 0
out["hint_ok"] = str(spec_of(ok))
out["hint_unknown_axis"] = str(spec_of(bad_axis))
out["hint_non_dividing"] = str(spec_of(bad_div))
no_ctx = jax.jit(lambda x: hint(x, "data", "model"))(jnp.zeros((8, 256)))
out["hint_no_ctx"] = str(spec_of(no_ctx))

print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_sharding_rules_on_8_devices():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)),
                       timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][0]
    out = json.loads(line[len("RESULT "):])

    # kv fallback: 4 kv heads shard over model; 3 kv heads replicate
    assert out["wk_tp"] == ["data", "model", None]
    assert out["wk_kv_bad"] == ["data", None, None]

    # non-divisible dims drop just their axis, keeping the rest
    assert out["w_up_ok"] == ["data", "model"]
    assert out["w_up_bad"] == ["data", None]
    assert out["w_up_bad_data"] == [None, "model"]

    # pure-DP rewrites "model" -> None everywhere
    assert out["w_up_dp"] == ["data", None]
    assert out["embed_dp"] == [None, None]
    assert out["embed_tp"] == ["model", None]

    # run-stacked leaves: rules fire on trailing dims, leading None
    assert out["wq_stacked"] == [None, "data", "model", None]
    assert out["unknown"] == []

    # batch_spec: full DP when divisible, all dropped at batch=1;
    # pure-DP adds "model" to the batch axes
    assert out["bs_8"] == [["data"], None, None]
    assert out["bs_1"] == [None, None, None]
    assert out["bs_dp"] == [["data", "model"], None, None]

    # hint: valid constraint applies; unknown/non-dividing axes drop to
    # None on that dim; no context leaves the default sharding
    assert "data" in out["hint_ok"] and "model" in out["hint_ok"]
    assert "nonexistent" not in out["hint_unknown_axis"]
    assert "model" not in out["hint_non_dividing"]
    assert "data" in out["hint_non_dividing"]
    assert "data" not in out["hint_no_ctx"]
