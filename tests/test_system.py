"""End-to-end behaviour tests: the paper's training pipeline learns, Zebra
regularization drives thresholds to T_obj and creates zero blocks, and the
LM trainer path (sharded jit, FSDP rules on 1 device) steps and resumes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ZebraConfig
from repro.data import ImageDatasetConfig
from repro.optim import sgd, step_decay
from repro.train import CNNTrainer, CNNTrainConfig


@pytest.fixture(scope="module")
def trained():
    ds = ImageDatasetConfig("syn-cifar10", 10, 32, seed=1)
    cfg = CNNTrainConfig(model="resnet18", width_mult=0.125, dataset=ds,
                         batch=32, steps=80,
                         zebra=ZebraConfig(t_obj=0.25, block_hw=4))
    tr = CNNTrainer(cfg, sgd(step_decay(0.05, total_steps=80)))
    state, hist = tr.train(log_every=20)
    return tr, state, hist


def test_zebra_training_end_to_end(trained):
    tr, state, hist = trained
    # loss falls and the Zebra reg collapses (thresholds -> T_obj, Fig. 3)
    assert hist[-1]["loss"] < hist[0]["loss"]
    assert hist[-1]["zebra_reg"] < hist[0]["zebra_reg"]
    # zero blocks appear (Table I: regularization creates them)
    assert hist[-1]["zero_frac"] > 0.05


def test_thresholds_converge_to_tobj(trained):
    """Paper Fig. 3: learned thresholds ~= T_obj at convergence, enabling
    threshold-net-free inference."""
    tr, state, hist = trained
    reg = hist[-1]["zebra_reg"]
    variables = state["variables"]
    # reg = sum_l sum_c (T-T_obj)^2 -> rms over all (l,c) channels
    n_ch = sum(int(v["b"].size) for v in variables["zebra"].values())
    rms = np.sqrt(reg / n_ch)
    assert rms < 0.25, (reg, n_ch, rms)


def test_eval_reports_bandwidth(trained):
    tr, state, _ = trained
    ev = tr.evaluate(state["variables"], batches=2, batch=64)
    assert 0 <= ev["reduced_bandwidth_pct"] <= 100
    assert ev["zero_frac"] > 0.02
    # eval metrics are well-formed (80 synthetic steps is a smoke budget —
    # learning quality itself is covered by the loss/reg trends above)
    assert 0.0 <= ev["acc"] <= 1.0 and 0.0 <= ev["top5"] <= 1.0
    assert ev["acc"] <= ev["top5"]
    assert np.isfinite(ev["reduced_bandwidth_pct"])


def test_infer_mode_needs_no_threshold_net(trained):
    """Inference uses the constant T_obj — drop the zebra tree entirely."""
    tr, state, _ = trained
    variables = dict(state["variables"])
    variables["zebra"] = {}
    from repro.data import image_batch
    imgs, labels = image_batch(tr.cfg.dataset, 8, 123)
    zcfg = tr.cfg.zebra.replace(mode="infer")
    logits, _, auxes = tr.model.apply(variables, imgs, False, zcfg)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_lm_trainer_steps_and_resumes(tmp_path):
    """Production LM path on 1 CPU device: sharded jit step + ckpt resume."""
    import repro.configs as configs
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import (make_train_state_shape, make_train_step,
                                    train_state_specs)
    from repro.models.lm import LM
    from repro.optim import adamw, warmup_cosine
    from repro.data import LMDatasetConfig, lm_batch
    from jax.sharding import NamedSharding, PartitionSpec as P

    cfg = configs.reduced("granite-moe-1b-a400m")
    mesh = make_host_mesh(model=1)
    model = LM(cfg)
    opt = adamw(warmup_cosine(1e-3, 2, 20))
    state_shape, init_fn = make_train_state_shape(model, opt)
    sspec = train_state_specs(state_shape, cfg, mesh)
    ns = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), sspec,
                                is_leaf=lambda x: isinstance(x, P))
    step = jax.jit(make_train_step(model, opt, mesh),
                   in_shardings=(ns, None), out_shardings=(ns, None),
                   donate_argnums=(0,))
    state = jax.jit(init_fn, out_shardings=ns)(jax.random.PRNGKey(0))
    ds = LMDatasetConfig(vocab=cfg.vocab)
    losses = []
    for i in range(8):
        batch = {"tokens": jnp.asarray(lm_batch(ds, 4, 64, i))}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]
    # checkpoint roundtrip of the sharded state
    from repro.checkpoint import CheckpointManager
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(8, state)
    _, restored, _ = mgr.restore(state)
    np.testing.assert_allclose(
        np.asarray(jax.device_get(state["params"]["embed"]), np.float32),
        np.asarray(restored["params"]["embed"], np.float32))
