"""Two-phase Zebra streaming: zebra_mask_pack / zebra_spmm_cs parity vs
the composed pipelines, the all-dead (n_live == 0) edge case, the VMEM
tile chooser, the supertile grid-shrink contract, TPU-form vs
interpret-form bitwise parity, and the structural ≤2-launch /
no-dense-intermediate contract of the stream and fused engine backends
(asserted on the jaxpr).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ZebraConfig, zebra_site
from repro.kernels import (ref, zebra_mask_op, zebra_mask_pack_op,
                           zebra_pack_op, zebra_spmm_cs_op, zebra_spmm_op,
                           zebra_unpack_op)

K = jax.random.PRNGKey(0)


def _blocky(key, M, Kd, bs, bc, dtype=jnp.float32):
    x = jax.random.normal(key, (M, Kd), jnp.float32)
    scale = jax.random.uniform(jax.random.fold_in(key, 1),
                               (M // bs, Kd // bc))
    x = x * jnp.repeat(jnp.repeat(scale, bs, 0), bc, 1)
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# Bitwise parity: fused producer/consumer vs the composed pipelines
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,Kd,bs,bc", [
    (16, 128, 8, 128), (64, 512, 8, 128), (128, 256, 16, 64),
    (24, 384, 8, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mask_pack_matches_composed(M, Kd, bs, bc, dtype):
    x = _blocky(K, M, Kd, bs, bc, dtype)
    p_f, bm_f, nl_f = zebra_mask_pack_op(x, 0.5, bs=bs, bc=bc)
    y_c, bm_c = zebra_mask_op(x, 0.5, bs=bs, bc=bc)
    p_c, nl_c = zebra_pack_op(y_c, bm_c, bs=bs, bc=bc)
    np.testing.assert_array_equal(np.asarray(bm_f), np.asarray(bm_c))
    np.testing.assert_array_equal(np.asarray(p_f, np.float32),
                                  np.asarray(p_c, np.float32))
    assert int(nl_f) == int(nl_c)
    # and against the pure-jnp oracle
    p_r, bm_r, nl_r = ref.zebra_mask_pack_ref(x, 0.5, bs, bc)
    np.testing.assert_array_equal(np.asarray(p_f, np.float32),
                                  np.asarray(p_r, np.float32))
    assert int(nl_f) == int(nl_r)


@pytest.mark.parametrize("M,Kd,N", [(16, 256, 128), (64, 512, 96),
                                    (32, 384, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_spmm_cs_matches_dense_and_spmm(M, Kd, N, dtype):
    bs, bc = 8, 128
    x = _blocky(K, M, Kd, bs, bc, dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (Kd, N), jnp.float32).astype(dtype)
    payload, bm, _ = zebra_mask_pack_op(x, 0.5, bs=bs, bc=bc)
    y_cs = zebra_spmm_cs_op(payload, w, bm, bs=bs, bc=bc)
    # bitwise vs the dense-input block-skipping GEMM (same accumulation)
    y_mask, _ = zebra_mask_op(x, 0.5, bs=bs, bc=bc)
    np.testing.assert_array_equal(
        np.asarray(y_cs), np.asarray(zebra_spmm_op(y_mask, w, bm, bs=bs, bc=bc)))
    # close to the dense masked matmul oracle
    np.testing.assert_allclose(
        np.asarray(y_cs), np.asarray(ref.zebra_spmm_cs_ref(payload, w, bm, bs, bc)),
        rtol=2e-2 if dtype == jnp.bfloat16 else 1e-4, atol=1e-2)


def test_engine_stream_fused_parity_nchw_shrink_to_2():
    """Shrunken NCHW blocks (b=2) run the streaming path bitwise equal
    to reference on both compressed backends."""
    B, C, H, W = 2, 3, 2, 2
    x = jax.nn.relu(jax.random.normal(K, (B, C, H, W)))
    cfg = ZebraConfig(t_obj=0.6, block_hw=4, mode="infer")   # shrinks to 2
    yr, ar = zebra_site(x, cfg.replace(backend="reference"), layout="nchw")
    for backend in ("stream", "fused"):
        yb, ab = zebra_site(x, cfg.replace(backend=backend), layout="nchw")
        np.testing.assert_array_equal(np.asarray(yr), np.asarray(yb))
        assert ab.backend == backend
        assert np.isclose(float(ar.zero_frac), float(ab.zero_frac))


def test_engine_degenerate_decode_bs1_falls_back_to_reference():
    """S=1 decode-shaped maps must keep falling back to reference (a 1-row
    block has no skippable HBM tile) on every compressed backend."""
    x = jax.random.normal(K, (2, 1, 256))
    cfg = ZebraConfig(t_obj=0.5, mode="infer")
    yr, _ = zebra_site(x, cfg.replace(backend="reference"))
    for backend in ("stream", "fused"):
        yb, ab = zebra_site(x, cfg.replace(backend=backend))
        np.testing.assert_array_equal(np.asarray(yr), np.asarray(yb))
        assert ab.backend == "reference(degenerate-rows)"


# ---------------------------------------------------------------------------
# Satellite regression: the all-dead map (n_live == 0)
# ---------------------------------------------------------------------------

def test_all_dead_map_round_trips_to_zeros_with_index_bytes_only():
    bs, bc = 8, 128
    x = _blocky(K, 32, 256, bs, bc)
    t_huge = 1e9

    payload, bm, nl = zebra_mask_pack_op(x, t_huge, bs=bs, bc=bc)
    assert int(nl) == 0 and not np.any(np.asarray(bm))
    assert not np.any(np.asarray(payload))                # zero tail only

    # composed pack on an all-dead bitmap agrees
    y_m, bm_m = zebra_mask_op(x, t_huge, bs=bs, bc=bc)
    p_c, nl_c = zebra_pack_op(y_m, bm_m, bs=bs, bc=bc)
    assert int(nl_c) == 0 and not np.any(np.asarray(p_c))

    # unpack and both GEMMs reconstruct exact zeros
    assert not np.any(np.asarray(zebra_unpack_op(payload, bm, bs=bs, bc=bc)))
    w = jax.random.normal(jax.random.PRNGKey(2), (256, 64), jnp.float32)
    assert not np.any(np.asarray(zebra_spmm_op(x, w, bm, bs=bs, bc=bc)))
    assert not np.any(np.asarray(zebra_spmm_cs_op(payload, w, bm, bs=bs, bc=bc)))

    # engine: measured stream length is the packed index alone
    for backend, kw in (("stream", {}), ("fused", {"w": w})):
        y, aux = zebra_site(x, ZebraConfig(t_obj=t_huge, mode="infer",
                                           backend=backend), **kw)
        assert not np.any(np.asarray(y))
        assert float(aux.measured_bytes) == (bm.size + 7) // 8
        assert float(aux.zero_frac) == 1.0


# ---------------------------------------------------------------------------
# VMEM-budget/dtype-aware tile chooser
# ---------------------------------------------------------------------------

def test_tiles_for_respects_budget_blocks_and_dtype():
    cfg = ZebraConfig(vmem_budget_bytes=256 * 1024)
    M, Kd, bs, bc = 4096, 8192, 8, 128
    tm, tk = cfg.tiles_for(M, Kd, bs, bc, jnp.float32)
    assert tm % bs == 0 and tk % bc == 0
    assert 2 * tm * tk * 4 <= cfg.vmem_budget_bytes
    # bf16 halves the element size -> at least as large a tile area
    tm2, tk2 = cfg.tiles_for(M, Kd, bs, bc, jnp.bfloat16)
    assert tm2 * tk2 >= tm * tk and 2 * tm2 * tk2 * 2 <= cfg.vmem_budget_bytes
    # never below one block, even under an absurdly small budget
    tiny = ZebraConfig(vmem_budget_bytes=1)
    assert tiny.tiles_for(M, Kd, bs, bc, jnp.float32) == (bs, bc)
    # small maps are clamped to the map, block-aligned
    tm3, tk3 = cfg.tiles_for(16, 256, bs, bc, jnp.float32)
    assert tm3 <= 16 and tk3 <= 256 and tm3 % bs == 0 and tk3 % bc == 0
    # the chooser drives the pallas comparator backend (smoke)
    x = _blocky(K, 32, 256, bs, bc)
    zcfg = ZebraConfig(t_obj=0.5, mode="infer", backend="pallas",
                       vmem_budget_bytes=64 * 1024)
    yr, _ = zebra_site(x, zcfg.replace(backend="reference"))
    yp, _ = zebra_site(x, zcfg)
    np.testing.assert_array_equal(np.asarray(yr), np.asarray(yp))


def test_over_budget_maps_retile_not_degrade_same_stream():
    """The two-phase producer has no whole-payload VMEM residency: a
    small vmem_budget_bytes only *shrinks the supertiles* (comparator
    tiles, GEMM supertiles) — the map stays on the chosen backend with
    bitwise-identical output, identical measured bytes and the same
    launch count, never a multi-launch degrade."""
    bs, bc = 8, 128
    x = _blocky(K, 32, 256, bs, bc)                # 32 KiB map
    w = jax.random.normal(jax.random.PRNGKey(4), (256, 64), jnp.float32)
    big = ZebraConfig(t_obj=0.5, mode="infer")     # default budget
    small = big.replace(vmem_budget_bytes=16 * 1024)
    assert small.tiles_for(32, 256, bs, bc, jnp.float32) \
        != big.tiles_for(32, 256, bs, bc, jnp.float32)
    for backend, kw in (("stream", {}), ("fused", {"w": w})):
        y1, a1 = zebra_site(x, big.replace(backend=backend), **kw)
        y2, a2 = zebra_site(x, small.replace(backend=backend), **kw)
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
        assert float(a1.measured_bytes) == float(a2.measured_bytes)
        assert a2.backend == backend
        fn_big = lambda xx: zebra_site(xx, big.replace(backend=backend),
                                       **kw)[0]
        fn_small = lambda xx: zebra_site(xx, small.replace(backend=backend),
                                         **kw)[0]
        n_big = len(_pallas_eqns(jax.make_jaxpr(fn_big)(x).jaxpr))
        n_small = len(_pallas_eqns(jax.make_jaxpr(fn_small)(x).jaxpr))
        assert n_big == n_small <= 2, (backend, n_big, n_small)


# ---------------------------------------------------------------------------
# Structural contract: ≤ 2 launches, no dense (M, K) intermediate
# ---------------------------------------------------------------------------

# THE launch counter — shared with benchmarks/kernel_bench.py so the
# structural contract asserted here and the benched `launches` column
# count the same way.
from repro.utils import pallas_eqns as _pallas_eqns  # noqa: E402


def _shapes(eqn):
    return [tuple(v.aval.shape) for v in eqn.outvars]


@pytest.mark.parametrize("backend", ["stream", "fused"])
def test_engine_backends_two_launches_no_dense_intermediate(backend):
    """Acceptance: stream and fused each execute in ≤ 2 Pallas launches,
    and no launch before the last one emits the dense (M, K) map — the
    only thing between producer and consumer is the compressed stream."""
    B, S, D = 2, 32, 256
    M = B * S
    x = _blocky(K, M, D, 8, 128).reshape(B, S, D)
    w = jax.random.normal(jax.random.PRNGKey(3), (D, 64), jnp.float32)
    cfg = ZebraConfig(t_obj=0.5, mode="infer", backend=backend)

    if backend == "fused":
        fn = lambda xx: zebra_site(xx, cfg, w=w)[0]
    else:
        fn = lambda xx: zebra_site(xx, cfg)[0]
    eqns = _pallas_eqns(jax.make_jaxpr(fn)(x).jaxpr)
    assert 1 <= len(eqns) <= 2, f"{backend}: {len(eqns)} launches"
    for eqn in eqns[:-1]:
        assert (M, D) not in _shapes(eqn), (
            f"{backend}: producer launch materializes the dense map "
            f"{_shapes(eqn)}")
    if backend == "fused":               # consumer emits (M, N), never (M, K)
        assert (M, D) not in _shapes(eqns[-1])


def _grids(jaxpr):
    return [e.params["grid_mapping"].grid for e in _pallas_eqns(jaxpr)]


def test_supertiled_grids_shrink_by_supertile_factor():
    """Acceptance: the rearchitected kernels walk supertile-coarse grids.
    The producer's comparator pass covers the map in tiles_for tiles
    (not one step per block), and the GEMM grid is the per-block grid
    shrunk by the (stm/bs) * (stk/bc) supertile factor."""
    from repro.kernels.mask_pack import zebra_mask_pack
    from repro.kernels.spmm_cs import zebra_spmm_cs

    bs, bc = 8, 128
    M, Kd, N = 256, 1024, 512
    nm, nk = M // bs, Kd // bc
    x = _blocky(K, M, Kd, bs, bc)
    cfg = ZebraConfig(t_obj=0.5, mode="infer")

    # producer: comparator supertiles, NOT one grid step per block
    tm, tk = cfg.tiles_for(M, Kd, bs, bc, jnp.float32)
    fn = lambda xx: zebra_mask_pack(xx, t_obj=0.5, bs=bs, bc=bc,
                                    tm=tm, tk=tk)[0]
    grids = _grids(jax.make_jaxpr(fn)(x).jaxpr)
    assert len(grids) <= 2
    steps = [int(np.prod(g)) for g in grids]
    assert steps[0] == ((M + tm - 1) // tm) * ((Kd + tk - 1) // tk)
    assert all(s < nm * nk for s in steps), (grids, nm * nk)

    # consumer: (stm, stk) supertiles shrink the per-block GEMM grid
    payload, bm, _ = zebra_mask_pack(x, t_obj=0.5, bs=bs, bc=bc)
    stm, stk, bn = cfg.tiles_for(M, Kd, bs, bc, jnp.float32, kind="gemm",
                                 n=N)
    factor = (stm // bs) * (stk // bc)
    assert factor > 1
    w = jax.random.normal(jax.random.PRNGKey(3), (Kd, N), jnp.float32)
    fn = lambda p: zebra_spmm_cs(p, w, bm, bs=bs, bc=bc, bn=bn,
                                 stm=stm, stk=stk, scheduled=False)
    (grid,) = _grids(jax.make_jaxpr(fn)(payload).jaxpr)
    per_block = nm * ((N + bn - 1) // bn) * nk
    assert int(np.prod(grid)) * factor == per_block, (grid, factor)


def test_composed_kernels_use_more_launches():
    """The structural count is meaningful: the legacy composed pipeline
    (mask -> per-block pack) really traces more Pallas launches than the
    two-phase streaming path."""
    from repro.compress import transport_tokens
    from repro.kernels.pack import zebra_pack, zebra_unpack
    from repro.kernels.zebra_mask import zebra_mask

    x = _blocky(K, 32, 256, 8, 128)

    def composed(xx):
        y, bm = zebra_mask(xx, t_obj=0.5, bs=8, bc=128)
        p, _ = zebra_pack(y, bm, bs=8, bc=128)
        return zebra_unpack(p, bm, bs=8, bc=128)

    def streaming(xx):
        return transport_tokens(xx, 0.5, bs=8, bc=128)[0]

    n_composed = len(_pallas_eqns(jax.make_jaxpr(composed)(x).jaxpr))
    n_stream = len(_pallas_eqns(jax.make_jaxpr(streaming)(x).jaxpr))
    assert n_stream <= 2 < n_composed + 1, (n_stream, n_composed)
    assert n_stream < n_composed


def test_tpu_forms_match_interpret_forms_bitwise():
    """The payload-direct TPU realizations (dynamically slotted BlockSpec
    windows / the W-spec gather-pack kernel) must produce bit-identical
    results to the interpret realizations (XLA blocked gathers) that the
    CPU container actually runs."""
    from repro.kernels.mask_pack import zebra_mask_pack
    from repro.kernels.pack import zebra_unpack
    from repro.kernels.spmm_cs import zebra_spmm_cs

    bs, bc = 8, 128
    x = _blocky(K, 32, 256, bs, bc)
    w = jax.random.normal(jax.random.PRNGKey(5), (256, 64), jnp.float32)
    p1, b1, n1 = zebra_mask_pack(x, t_obj=0.5, gather_kernel=True)
    p2, b2, n2 = zebra_mask_pack(x, t_obj=0.5, gather_kernel=False)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    np.testing.assert_array_equal(np.asarray(b1), np.asarray(b2))
    assert int(n1) == int(n2)
    np.testing.assert_array_equal(
        np.asarray(zebra_spmm_cs(p1, w, b1, payload_windows=True)),
        np.asarray(zebra_spmm_cs(p1, w, b1, payload_windows=False)))
    np.testing.assert_array_equal(
        np.asarray(zebra_unpack(p1, b1, payload_windows=True)),
        np.asarray(zebra_unpack(p1, b1, payload_windows=False)))
    # the scheduled XLA form is the same contract at allclose tightness
    # (it sums partial products in a different order than the kernel
    # forms), and its dense/compressed consumers stay bitwise-equal
    from repro.kernels.zebra_spmm import zebra_spmm
    from repro.kernels.zebra_mask import zebra_mask
    y_sched = zebra_spmm_cs(p1, w, b1, scheduled=True)
    np.testing.assert_allclose(
        np.asarray(y_sched),
        np.asarray(zebra_spmm_cs(p1, w, b1, payload_windows=False)),
        rtol=1e-5, atol=1e-4)
    y_m, _ = zebra_mask(x, t_obj=0.5)
    np.testing.assert_array_equal(
        np.asarray(y_sched),
        np.asarray(zebra_spmm(y_m, w, b1, scheduled=True)))


# ---------------------------------------------------------------------------
# Consumer-order payload contract (the GEMM-consumable supertile order)
# ---------------------------------------------------------------------------

def test_payload_follows_consumer_order_and_stream_bytes_invariant():
    """The producer emits payload slots grouped by K-block column
    (columns ascending, block rows ascending within a column, zero
    tail), each column's live blocks one contiguous slot run — and the
    reorder is free: stream_bytes depends only on n_live, so it is
    identical to what the legacy row-major live-first order measured."""
    from repro.core.engine import stream_bytes
    from repro.kernels.mask_pack import zebra_mask_pack

    bs, bc = 8, 128
    M, Kd = 64, 512
    nm, nk = M // bs, Kd // bc
    x = _blocky(K, M, Kd, bs, bc)
    payload, bm, n_live = zebra_mask_pack(x, t_obj=0.5, bs=bs, bc=bc)
    keep = np.asarray(bm, np.int32)
    assert 0 < int(n_live) < nm * nk            # a mixed map, or no test

    xb = np.asarray(x).reshape(nm, bs, nk, bc)
    p = np.asarray(payload)
    slot = 0
    for k in range(nk):                          # columns ascending
        for r in range(nm):                      # rows ascending within
            if keep[r, k]:
                np.testing.assert_array_equal(p[slot], xb[r, :, k, :])
                slot += 1
    assert slot == int(n_live)
    assert not np.any(p[slot:])                  # zero tail

    # stream_bytes is order-invariant: any permutation of the live slots
    # (e.g. the legacy row-major live-first order) measures the same
    sb = stream_bytes(n_live, bs, bc, x.dtype, nm * nk)
    expected = int(n_live) * bs * bc * 4 + (nm * nk + 7) // 8
    assert int(sb) == expected


def test_scheduled_consumer_gates_dead_blocks():
    """Scheduled-form consumers never read dead blocks: an Inf planted in
    a dead block of the *raw* operand must not reach the output."""
    from repro.kernels.zebra_mask import zebra_mask
    from repro.kernels.zebra_spmm import zebra_spmm

    bs, bc = 8, 128
    x = _blocky(K, 64, 512, bs, bc)
    _, bm = zebra_mask(x, t_obj=2.0, bs=bs, bc=bc)
    keep = np.asarray(bm)
    dead = np.argwhere(keep == 0)
    assert dead.size and keep.any(), "need a mixed live/dead map"
    r, c = dead[0]
    x_poison = np.asarray(x).copy()
    x_poison[r * bs:(r + 1) * bs, c * bc:(c + 1) * bc] = np.inf
    w = jax.random.normal(jax.random.PRNGKey(7), (512, 64), jnp.float32)
    y = np.asarray(zebra_spmm(jnp.asarray(x_poison), w, bm, scheduled=True))
    assert np.isfinite(y).all()
    np.testing.assert_array_equal(
        y, np.asarray(zebra_spmm(x, w, bm, scheduled=True)))
