"""Single-pass Zebra streaming: zebra_mask_pack / zebra_spmm_cs parity vs
the composed pipelines, the all-dead (n_live == 0) edge case, the VMEM
tile chooser, and the structural ≤2-launch / no-dense-intermediate
contract of the stream and fused engine backends (asserted on the jaxpr).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ZebraConfig, zebra_site
from repro.kernels import (ref, zebra_mask_op, zebra_mask_pack_op,
                           zebra_pack_op, zebra_spmm_cs_op, zebra_spmm_op,
                           zebra_unpack_op)

K = jax.random.PRNGKey(0)


def _blocky(key, M, Kd, bs, bc, dtype=jnp.float32):
    x = jax.random.normal(key, (M, Kd), jnp.float32)
    scale = jax.random.uniform(jax.random.fold_in(key, 1),
                               (M // bs, Kd // bc))
    x = x * jnp.repeat(jnp.repeat(scale, bs, 0), bc, 1)
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# Bitwise parity: fused producer/consumer vs the composed pipelines
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,Kd,bs,bc", [
    (16, 128, 8, 128), (64, 512, 8, 128), (128, 256, 16, 64),
    (24, 384, 8, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mask_pack_matches_composed(M, Kd, bs, bc, dtype):
    x = _blocky(K, M, Kd, bs, bc, dtype)
    p_f, bm_f, nl_f = zebra_mask_pack_op(x, 0.5, bs=bs, bc=bc)
    y_c, bm_c = zebra_mask_op(x, 0.5, bs=bs, bc=bc)
    p_c, nl_c = zebra_pack_op(y_c, bm_c, bs=bs, bc=bc)
    np.testing.assert_array_equal(np.asarray(bm_f), np.asarray(bm_c))
    np.testing.assert_array_equal(np.asarray(p_f, np.float32),
                                  np.asarray(p_c, np.float32))
    assert int(nl_f) == int(nl_c)
    # and against the pure-jnp oracle
    p_r, bm_r, nl_r = ref.zebra_mask_pack_ref(x, 0.5, bs, bc)
    np.testing.assert_array_equal(np.asarray(p_f, np.float32),
                                  np.asarray(p_r, np.float32))
    assert int(nl_f) == int(nl_r)


@pytest.mark.parametrize("M,Kd,N", [(16, 256, 128), (64, 512, 96),
                                    (32, 384, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_spmm_cs_matches_dense_and_spmm(M, Kd, N, dtype):
    bs, bc = 8, 128
    x = _blocky(K, M, Kd, bs, bc, dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (Kd, N), jnp.float32).astype(dtype)
    payload, bm, _ = zebra_mask_pack_op(x, 0.5, bs=bs, bc=bc)
    y_cs = zebra_spmm_cs_op(payload, w, bm, bs=bs, bc=bc)
    # bitwise vs the dense-input block-skipping GEMM (same accumulation)
    y_mask, _ = zebra_mask_op(x, 0.5, bs=bs, bc=bc)
    np.testing.assert_array_equal(
        np.asarray(y_cs), np.asarray(zebra_spmm_op(y_mask, w, bm, bs=bs, bc=bc)))
    # close to the dense masked matmul oracle
    np.testing.assert_allclose(
        np.asarray(y_cs), np.asarray(ref.zebra_spmm_cs_ref(payload, w, bm, bs, bc)),
        rtol=2e-2 if dtype == jnp.bfloat16 else 1e-4, atol=1e-2)


def test_engine_stream_fused_parity_nchw_shrink_to_2():
    """Shrunken NCHW blocks (b=2) run the single-pass path bitwise equal
    to reference on both compressed backends."""
    B, C, H, W = 2, 3, 2, 2
    x = jax.nn.relu(jax.random.normal(K, (B, C, H, W)))
    cfg = ZebraConfig(t_obj=0.6, block_hw=4, mode="infer")   # shrinks to 2
    yr, ar = zebra_site(x, cfg.replace(backend="reference"), layout="nchw")
    for backend in ("stream", "fused"):
        yb, ab = zebra_site(x, cfg.replace(backend=backend), layout="nchw")
        np.testing.assert_array_equal(np.asarray(yr), np.asarray(yb))
        assert ab.backend == backend
        assert np.isclose(float(ar.zero_frac), float(ab.zero_frac))


def test_engine_degenerate_decode_bs1_falls_back_to_reference():
    """S=1 decode-shaped maps must keep falling back to reference (a 1-row
    block has no skippable HBM tile) on every compressed backend."""
    x = jax.random.normal(K, (2, 1, 256))
    cfg = ZebraConfig(t_obj=0.5, mode="infer")
    yr, _ = zebra_site(x, cfg.replace(backend="reference"))
    for backend in ("stream", "fused"):
        yb, ab = zebra_site(x, cfg.replace(backend=backend))
        np.testing.assert_array_equal(np.asarray(yr), np.asarray(yb))
        assert ab.backend == "reference(degenerate-rows)"


# ---------------------------------------------------------------------------
# Satellite regression: the all-dead map (n_live == 0)
# ---------------------------------------------------------------------------

def test_all_dead_map_round_trips_to_zeros_with_index_bytes_only():
    bs, bc = 8, 128
    x = _blocky(K, 32, 256, bs, bc)
    t_huge = 1e9

    payload, bm, nl = zebra_mask_pack_op(x, t_huge, bs=bs, bc=bc)
    assert int(nl) == 0 and not np.any(np.asarray(bm))
    assert not np.any(np.asarray(payload))                # zero tail only

    # composed pack on an all-dead bitmap agrees
    y_m, bm_m = zebra_mask_op(x, t_huge, bs=bs, bc=bc)
    p_c, nl_c = zebra_pack_op(y_m, bm_m, bs=bs, bc=bc)
    assert int(nl_c) == 0 and not np.any(np.asarray(p_c))

    # unpack and both GEMMs reconstruct exact zeros
    assert not np.any(np.asarray(zebra_unpack_op(payload, bm, bs=bs, bc=bc)))
    w = jax.random.normal(jax.random.PRNGKey(2), (256, 64), jnp.float32)
    assert not np.any(np.asarray(zebra_spmm_op(x, w, bm, bs=bs, bc=bc)))
    assert not np.any(np.asarray(zebra_spmm_cs_op(payload, w, bm, bs=bs, bc=bc)))

    # engine: measured stream length is the packed index alone
    for backend, kw in (("stream", {}), ("fused", {"w": w})):
        y, aux = zebra_site(x, ZebraConfig(t_obj=t_huge, mode="infer",
                                           backend=backend), **kw)
        assert not np.any(np.asarray(y))
        assert float(aux.measured_bytes) == (bm.size + 7) // 8
        assert float(aux.zero_frac) == 1.0


# ---------------------------------------------------------------------------
# VMEM-budget/dtype-aware tile chooser
# ---------------------------------------------------------------------------

def test_tiles_for_respects_budget_blocks_and_dtype():
    cfg = ZebraConfig(vmem_budget_bytes=256 * 1024)
    M, Kd, bs, bc = 4096, 8192, 8, 128
    tm, tk = cfg.tiles_for(M, Kd, bs, bc, jnp.float32)
    assert tm % bs == 0 and tk % bc == 0
    assert 2 * tm * tk * 4 <= cfg.vmem_budget_bytes
    # bf16 halves the element size -> at least as large a tile area
    tm2, tk2 = cfg.tiles_for(M, Kd, bs, bc, jnp.bfloat16)
    assert tm2 * tk2 >= tm * tk and 2 * tm2 * tk2 * 2 <= cfg.vmem_budget_bytes
    # never below one block, even under an absurdly small budget
    tiny = ZebraConfig(vmem_budget_bytes=1)
    assert tiny.tiles_for(M, Kd, bs, bc, jnp.float32) == (bs, bc)
    # small maps are clamped to the map, block-aligned
    tm3, tk3 = cfg.tiles_for(16, 256, bs, bc, jnp.float32)
    assert tm3 <= 16 and tk3 <= 256 and tm3 % bs == 0 and tk3 % bc == 0
    # the chooser drives the pallas comparator backend (smoke)
    x = _blocky(K, 32, 256, bs, bc)
    zcfg = ZebraConfig(t_obj=0.5, mode="infer", backend="pallas",
                       vmem_budget_bytes=64 * 1024)
    yr, _ = zebra_site(x, zcfg.replace(backend="reference"))
    yp, _ = zebra_site(x, zcfg)
    np.testing.assert_array_equal(np.asarray(yr), np.asarray(yp))


def test_over_budget_maps_degrade_to_tiled_pipeline_same_stream():
    """A map whose worst-case payload exceeds vmem_budget_bytes can't keep
    it VMEM-resident: stream/fused degrade to the tiled multi-launch
    pipeline — bitwise-identical output, identical measured bytes."""
    bs, bc = 8, 128
    x = _blocky(K, 32, 256, bs, bc)                # 32 KiB map
    w = jax.random.normal(jax.random.PRNGKey(4), (256, 64), jnp.float32)
    big = ZebraConfig(t_obj=0.5, mode="infer")     # default budget: fits
    small = big.replace(vmem_budget_bytes=16 * 1024)   # payload won't fit
    for backend, kw in (("stream", {}), ("fused", {"w": w})):
        y1, a1 = zebra_site(x, big.replace(backend=backend), **kw)
        y2, a2 = zebra_site(x, small.replace(backend=backend), **kw)
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
        assert float(a1.measured_bytes) == float(a2.measured_bytes)
        assert a2.backend == backend
    # and the fallback really is the 3-launch pipeline for stream
    fn = lambda xx: zebra_site(xx, small.replace(backend="stream"))[0]
    assert len(_pallas_eqns(jax.make_jaxpr(fn)(x).jaxpr)) == 3


# ---------------------------------------------------------------------------
# Structural contract: ≤ 2 launches, no dense (M, K) intermediate
# ---------------------------------------------------------------------------

def _subjaxprs(v):
    if isinstance(v, jax.core.ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, jax.core.Jaxpr):
        yield v
    elif isinstance(v, (tuple, list)):
        for x in v:
            yield from _subjaxprs(x)


def _pallas_eqns(jaxpr):
    """Every pallas_call equation in the jaxpr, in trace order."""
    out = []
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            out.append(eqn)
            continue                     # kernel bodies never nest launches
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                out.extend(_pallas_eqns(sub))
    return out


def _shapes(eqn):
    return [tuple(v.aval.shape) for v in eqn.outvars]


@pytest.mark.parametrize("backend", ["stream", "fused"])
def test_engine_backends_two_launches_no_dense_intermediate(backend):
    """Acceptance: stream and fused each execute in ≤ 2 Pallas launches,
    and no launch before the last one emits the dense (M, K) map — the
    only thing between producer and consumer is the compressed stream."""
    B, S, D = 2, 32, 256
    M = B * S
    x = _blocky(K, M, D, 8, 128).reshape(B, S, D)
    w = jax.random.normal(jax.random.PRNGKey(3), (D, 64), jnp.float32)
    cfg = ZebraConfig(t_obj=0.5, mode="infer", backend=backend)

    if backend == "fused":
        fn = lambda xx: zebra_site(xx, cfg, w=w)[0]
    else:
        fn = lambda xx: zebra_site(xx, cfg)[0]
    eqns = _pallas_eqns(jax.make_jaxpr(fn)(x).jaxpr)
    assert len(eqns) == 2, f"{backend}: {len(eqns)} launches"
    for eqn in eqns[:-1]:
        assert (M, D) not in _shapes(eqn), (
            f"{backend}: producer launch materializes the dense map "
            f"{_shapes(eqn)}")
    if backend == "fused":               # consumer emits (M, N), never (M, K)
        assert (M, D) not in _shapes(eqns[-1])


def test_composed_kernels_would_use_three_launches():
    """The structural count is meaningful: the legacy composed stream
    pipeline really traces 3 launches where the engine path traces 2."""
    from repro.compress import transport_tokens
    from repro.kernels.pack import zebra_pack, zebra_unpack
    from repro.kernels.zebra_mask import zebra_mask

    x = _blocky(K, 32, 256, 8, 128)

    def composed(xx):
        y, bm = zebra_mask(xx, t_obj=0.5, bs=8, bc=128)
        p, _ = zebra_pack(y, bm, bs=8, bc=128)
        return zebra_unpack(p, bm, bs=8, bc=128)

    assert len(_pallas_eqns(jax.make_jaxpr(composed)(x).jaxpr)) == 3
    # transport_tokens is now the 2-launch single-pass form
    fn = lambda xx: transport_tokens(xx, 0.5, bs=8, bc=128)[0]
    assert len(_pallas_eqns(jax.make_jaxpr(fn)(x).jaxpr)) == 2
