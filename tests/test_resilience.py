"""Resilient serving: SLOs, shedding, circuit breakers, crash recovery.

Host-side units for the PR 10 resilience layer — no model in the loop
(the end-to-end chaos runs live in tests/test_serve.py):

* the per-boundary circuit breaker state machine (trip threshold inside
  the sliding window, window decay, half-open probe pass/fail, the
  decayed probe schedule and its cap, close-after-consecutive-passes);
* the BreakerBoard clock/contextvar wiring, including the collectives'
  ``resolve_comms`` consulting the ambient board;
* the new shed fault classes (``DeadlineExceeded``/``Overload``)
  round-tripping through ``classify`` onto the ``shed`` policy, which
  the shared ``FailurePolicy`` logs but never counts;
* the seeded backoff jitter (bounded under ANY seed, distinct across
  seeds);
* scheduler SLO policy: deadline-aware admission, the bounded pending
  queue (fresh arrivals only — work-in-progress is never shed and never
  squeezes fresh arrivals out), the never/later/ok admission verdicts,
  and the snapshot/restore round trip crash recovery rides on;
* ``crash_tap`` firing only at its named tick;
* the ``gate_serve_chaos`` CI gate's red path (a doctored artifact must
  produce errors; the committed artifact must not).
"""
import importlib.util
import json
import os

import numpy as np
import pytest

from repro.distributed import collectives as coll
from repro.distributed.ctx import comm_context
from repro.ft import (BreakerBoard, BreakerConfig, CircuitBreaker,
                      DeadlineExceeded, FailurePolicy, Fault, FTConfig,
                      Overload, TransientStep, active_board, breaker_scope,
                      classify, crash_tap, inject, policy_for)
from repro.ft.breaker import CLOSED, HALF_OPEN, OPEN
from repro.ft.faults import SHED_POLICIES
from repro.serve import Request, Scheduler


def _prompt(n, seed=0):
    return np.random.default_rng(seed).integers(1, 512, size=n).astype(np.int32)


# ---------------------------------------------------------------------------
# circuit breaker state machine
# ---------------------------------------------------------------------------

def test_breaker_trips_after_threshold_in_window():
    br = CircuitBreaker("page", BreakerConfig(trip_after=3, window=8))
    br.record_failure(0)
    br.record_failure(1)
    assert br.state == CLOSED and br.trips == 0
    br.record_failure(2)
    assert br.state == OPEN and br.trips == 1
    assert br.failures_seen == 3


def test_breaker_window_decay_prevents_trip():
    """Failures spaced wider than the window never accumulate to a trip
    — a rare blip per epoch is per-item recovery's job, not the
    breaker's."""
    br = CircuitBreaker("page", BreakerConfig(trip_after=3, window=4))
    for t in (0, 10, 20, 30, 40):
        br.record_failure(t)
    assert br.state == CLOSED and br.trips == 0


def test_breaker_open_skips_then_probes_half_open():
    cfg = BreakerConfig(trip_after=1, probe_after=4)
    br = CircuitBreaker("page", cfg)
    br.record_failure(0)
    assert br.state == OPEN
    assert not br.allow(1) and not br.allow(3)
    assert br.skipped == 2
    assert br.allow(4)                         # first item at the deadline
    assert br.state == HALF_OPEN
    assert br.allow(4)                         # probing items stay allowed


def test_breaker_probe_fail_reopens_on_decayed_schedule():
    cfg = BreakerConfig(trip_after=1, probe_after=2, probe_backoff=2.0,
                        probe_cap=8)
    br = CircuitBreaker("page", cfg)
    br.record_failure(0)                       # open, probe at 2
    probe_ticks = []
    t = 0
    for _ in range(5):                         # probes at 2, 6, 14, 22, 30
        while not br.allow(t):
            t += 1
        probe_ticks.append(t)
        br.record_failure(t)                   # every probe fails
    # waits decay 2 -> 4 -> 8 -> capped at 8
    assert [b - a for a, b in zip(probe_ticks, probe_ticks[1:])] \
        == [4, 8, 8, 8]
    assert br.probe_fails == 5 and br.probes == 5
    assert br.state == OPEN and br.trips == 1  # reopens are not new trips


def test_breaker_closes_after_consecutive_passes():
    cfg = BreakerConfig(trip_after=1, probe_after=1, close_after=2)
    br = CircuitBreaker("page", cfg)
    br.record_failure(0)
    assert br.allow(1) and br.state == HALF_OPEN
    br.record_success(1)
    assert br.state == HALF_OPEN               # one pass is not enough
    br.record_success(1)
    assert br.state == CLOSED
    assert br.probe_passes == 2
    # a fail between passes resets the consecutive count
    br2 = CircuitBreaker("page", cfg)
    br2.record_failure(0)
    br2.allow(1)
    br2.record_success(1)
    br2.record_failure(1)                      # back to open
    assert br2.state == OPEN
    br2.allow(3)
    br2.record_success(3)
    assert br2.state == HALF_OPEN              # count restarted at 1


def test_breaker_label_and_snapshot():
    br = CircuitBreaker("page", BreakerConfig(trip_after=1))
    br.record_failure(0)
    assert br.label() == "page:open(trips=1,probes=0,skipped=0)"
    snap = br.snapshot()
    assert snap["site"] == "page" and snap["state"] == OPEN
    assert snap["trips"] == 1 and snap["failures_seen"] == 1


def test_breaker_board_clock_and_aggregates():
    board = BreakerBoard(BreakerConfig(trip_after=1, probe_after=2))
    board.advance(5)
    assert board.allow("page")                 # lazy site, closed
    board.record_failure("page")
    board.record_failure("ring")
    assert board.tripped_sites() == ["page", "ring"]
    assert board.trips == 2
    assert not board.allow("page")             # open, probe at 7
    board.advance(3)                           # monotone: max, never back
    assert board.now == 5
    board.advance(7)
    assert board.allow("page")                 # the half-open probe
    assert board.get("page").state == HALF_OPEN
    assert [l.split("(")[0] for l in board.labels()] \
        == ["page:half_open", "ring:open"]


def test_breaker_scope_contextvar():
    assert active_board() is None
    board = BreakerBoard()
    with breaker_scope(board):
        assert active_board() is board
        with breaker_scope(BreakerBoard()) as inner:
            assert active_board() is inner
        assert active_board() is board
    assert active_board() is None


def test_resolve_comms_breaker_open_degrades():
    """An open "ring" breaker on the ambient board turns the whole layer
    exchange dense — wholesale degradation above PR 8's per-hop
    recovery."""
    board = BreakerBoard(BreakerConfig(trip_after=1, probe_after=100))
    with comm_context("model", 4):
        ok = coll.resolve_comms("stream", rows=64, cols=512, bs=8, bc=128)
        assert ok == ("compressed", None)
        with breaker_scope(board):
            assert coll.resolve_comms("stream", rows=64, cols=512,
                                      bs=8, bc=128) == ("compressed", None)
            board.record_failure(coll.RING_SITE)
            assert coll.resolve_comms("stream", rows=64, cols=512,
                                      bs=8, bc=128) == ("dense",
                                                        "breaker-open")
            # capability/divisibility vetoes still rank first
            assert coll.resolve_comms("reference", rows=64, cols=512,
                                      bs=8, bc=128) == ("dense",
                                                        "comms-capability")
        # out of scope: the board no longer applies
        assert coll.resolve_comms("stream", rows=64, cols=512,
                                  bs=8, bc=128) == ("compressed", None)


# ---------------------------------------------------------------------------
# shed fault classes + FailurePolicy
# ---------------------------------------------------------------------------

def test_shed_classes_classify_round_trip():
    for exc_cls in (DeadlineExceeded, Overload):
        assert classify(exc_cls("x")) is exc_cls
        assert policy_for(exc_cls("x")) == "shed"
        assert policy_for(exc_cls("x")) in SHED_POLICIES
    assert classify(ValueError("not a fault")) is None  # unclassified
    assert policy_for(ValueError("not a fault")) is None


def test_failure_policy_shed_logged_never_counted():
    pol = FailurePolicy(FTConfig(max_failures=2))
    name = pol.record(Overload, 3, Overload("queue full"))
    assert name == "shed"
    assert pol.failures == 0                   # record never counts
    assert pol.failure_log[-1]["policy"] == "shed"
    assert pol.failure_log[-1]["step"] == 3
    # countable classes go through count() — shed classes never do (the
    # supervisor/engine skip count() for SHED_POLICIES)
    pol.record(TransientStep, 4, TransientStep("t"))
    assert pol.count() and pol.failures == 1
    assert pol.count() and pol.failures == 2
    assert not pol.count()                     # budget exhausted


def test_backoff_bounded_under_any_seed():
    for seed in range(6):
        cfg = FTConfig(backoff_base_s=0.05, backoff_cap_s=2.0,
                       backoff_jitter=0.25, jitter_seed=seed)
        pol = FailurePolicy(cfg)
        pol.failures = 50                      # deep into the cap regime
        for _ in range(8):
            d = pol.backoff()
            assert 0.0 <= d <= cfg.backoff_cap_s * (1 + cfg.backoff_jitter)


def test_backoff_jitter_streams_differ_by_seed():
    def stream(seed):
        pol = FailurePolicy(FTConfig(jitter_seed=seed))
        pol.failures = 10
        return [pol.backoff() for _ in range(4)]
    assert stream(0) == stream(0)              # deterministic per seed
    assert stream(0) != stream(1)              # decorrelated across seeds


# ---------------------------------------------------------------------------
# scheduler SLO policy
# ---------------------------------------------------------------------------

def test_deadline_anchors_to_original_arrival():
    r = Request(rid=0, prompt=_prompt(8), max_new=4, arrival=3,
                deadline_ticks=10)
    assert r.deadline == 13
    r.arrival = 99                             # preemption mutates arrival
    assert r.deadline == 13                    # ... the TTL anchor doesn't


def test_admit_sheds_unmeetable_deadline():
    reqs = [Request(rid=0, prompt=_prompt(8), max_new=4, deadline_ticks=2),
            Request(rid=1, prompt=_prompt(8), max_new=4, deadline_ticks=50)]
    s = Scheduler(reqs)
    got = s.admit(tick=0, free_slots=2, eta=lambda r: 10)
    assert [r.rid for r in got] == [1]
    assert reqs[0].status == "shed" and reqs[0].shed_reason == "deadline"
    assert s.n_shed == 1 and s.deadline_misses == 1
    # no deadline -> no check; eta default falls back to total_len
    s2 = Scheduler([Request(rid=2, prompt=_prompt(8), max_new=4)])
    assert [r.rid for r in s2.admit(tick=0, free_slots=1)] == [2]


def test_admit_verdicts_never_vs_later():
    reqs = [Request(rid=i, prompt=_prompt(8), max_new=4) for i in range(3)]
    s = Scheduler(reqs)
    verdicts = {0: "later", 1: "never", 2: "ok"}
    got = s.admit(tick=0, free_slots=3, fits=lambda r: verdicts[r.rid])
    assert [r.rid for r in got] == [2]
    assert reqs[1].status == "rejected"
    assert s.deferrals == 1
    # the deferred request kept its FCFS position at the queue head
    assert [r.rid for r in s.waiting] == [0]
    assert reqs[0].status == "waiting"
    # booleans still mean ok/never (PR 9 call sites)
    s2 = Scheduler([Request(rid=9, prompt=_prompt(8), max_new=4)])
    assert s2.admit(tick=0, free_slots=1, fits=lambda r: False) == []
    assert s2.completed[0].status == "rejected"


def test_shed_overflow_bounds_fresh_backlog_only():
    fresh = [Request(rid=i, prompt=_prompt(8), max_new=4, arrival=i)
             for i in range(5)]
    wip = Request(rid=10, prompt=_prompt(8), max_new=4, arrival=0)
    wip.pos = 6                                # paged progress: never shed
    future = Request(rid=11, prompt=_prompt(8), max_new=4, arrival=50)
    s = Scheduler(fresh + [wip, future], queue_bound=3)
    victims = s.shed_overflow(tick=10)
    # newest fresh beyond the bound go first; WIP and the not-yet-arrived
    # request are invisible to the bound
    assert [r.rid for r in victims] == [3, 4]
    assert all(r.status == "shed" and r.shed_reason == "overload"
               for r in victims)
    assert s.n_shed == 2 and s.deadline_misses == 0
    assert wip in s.waiting and future in s.waiting
    # queue_bound=0 disables the bound entirely
    s2 = Scheduler([Request(rid=i, prompt=_prompt(8), max_new=4)
                    for i in range(8)], queue_bound=0)
    assert s2.shed_overflow(tick=0) == []


def test_scheduler_snapshot_restore_round_trip():
    reqs = [Request(rid=i, prompt=_prompt(8), max_new=4) for i in range(3)]
    s = Scheduler(reqs, queue_bound=4)
    a, b, c = reqs
    s.admit(tick=0, free_slots=2)              # a, b running
    a.out.extend([7, 8]); a.pos = 10; a.next_tok = 8
    snap = s.snapshot()
    # mutate everything the snapshot covers
    a.out.append(9); a.pos = 11; a.status = "done"
    s.retire(a)
    s.shed(c, "overload")
    assert s.n_shed == 1 and len(s.completed) == 2
    s.restore(snap)
    assert a.out == [7, 8] and a.pos == 10 and a.status == "running"
    assert c.status == "waiting" and c.shed_reason == ""
    assert s.n_shed == 0 and s.completed == []
    assert [r.rid for r in s.waiting] == [c.rid]
    # the snapshot is a deep copy: restoring twice is idempotent
    a.out.append(99)
    s.restore(snap)
    assert a.out == [7, 8]


def test_requeue_front_preserves_arrival_and_ttl():
    r = Request(rid=0, prompt=_prompt(8), max_new=4, arrival=2,
                deadline_ticks=20)
    s = Scheduler([Request(rid=1, prompt=_prompt(8), max_new=4)])
    r.status = "running"; r.slot_steps = 5
    s.requeue_front(r)
    assert s.waiting[0] is r                   # ahead of the fresh request
    assert r.status == "waiting" and r.slot_steps == 0
    assert r.arrival == 2 and r.deadline == 22  # unlike preempt()


# ---------------------------------------------------------------------------
# crash tap
# ---------------------------------------------------------------------------

def test_crash_tap_fires_only_at_named_tick():
    with inject(Fault("crash", site="engine_tick", arg=3)) as plan:
        for t in (0, 1, 2):
            crash_tap(t)                       # wrong tick: no fire
        with pytest.raises(TransientStep, match="tick 3"):
            crash_tap(3)
        crash_tap(3)                           # times=1: exhausted
        crash_tap(4)
    assert plan.injected == [("crash", "engine_tick")]
    crash_tap(3)                               # no plan armed: no-op


# ---------------------------------------------------------------------------
# gate red path
# ---------------------------------------------------------------------------

def _load_gate():
    path = os.path.join(os.path.dirname(__file__), os.pardir,
                        "scripts", "bench_gate.py")
    spec = importlib.util.spec_from_file_location("bench_gate", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


GOOD_STORM = {
    "name": "serve_chaos/storm", "us_per_call": 1.0, "goodput_frac": 1.0,
    "token_parity": 1.0, "crash_recoveries": 1, "breaker_trips": 1,
    "breaker_trips_expected": 1, "breaker_recovered": 1.0,
    "shed_frac": 0.1, "deadline_miss_frac": 0.0, "faults_injected": 7,
}


def _write_chaos(tmp_path, storm):
    doc = {"bench": "serve_chaos", "schema_version": 1, "generated_unix": 0,
           "rows": [{"name": "serve_chaos/clean", "us_per_call": 1.0}, storm]}
    p = tmp_path / "BENCH_serve_chaos.json"
    p.write_text(json.dumps(doc))
    return str(p)


def test_gate_serve_chaos_green_and_red(tmp_path):
    gate = _load_gate()
    assert "BENCH_serve_chaos.json" in gate.FILES
    assert gate.gate_serve_chaos(_write_chaos(tmp_path, dict(GOOD_STORM))) \
        == []
    red = {
        "goodput_frac": 0.5,                   # storm collapsed throughput
        "token_parity": 0.0,                   # recovery corrupted tokens
        "crash_recoveries": 0,                 # crash never recovered
        "breaker_trips": 2,                    # != expected
        "breaker_recovered": 0.0,              # breaker never closed
        "shed_frac": 1.5,                      # not a fraction
    }
    for key, bad in red.items():
        doctored = dict(GOOD_STORM, **{key: bad})
        errs = gate.gate_serve_chaos(_write_chaos(tmp_path, doctored))
        assert errs and key in errs[0], (key, errs)
    # missing storm row / missing artifact
    doc = {"bench": "serve_chaos", "schema_version": 1, "generated_unix": 0,
           "rows": [{"name": "serve_chaos/clean", "us_per_call": 1.0}]}
    p = tmp_path / "BENCH_serve_chaos.json"
    p.write_text(json.dumps(doc))
    assert gate.gate_serve_chaos(str(p)) != []
    assert gate.gate_serve_chaos(str(tmp_path / "nope.json")) == []


def test_gate_serve_chaos_red_path_against_committed_artifact(tmp_path):
    """The committed artifact itself must pass — and a doctored copy of
    it must fail — so the red path is verified against the REAL schema,
    not a synthetic one."""
    gate = _load_gate()
    root = os.path.join(os.path.dirname(__file__), os.pardir)
    path = os.path.join(root, "BENCH_serve_chaos.json")
    assert os.path.exists(path), "BENCH_serve_chaos.json not committed"
    assert gate.gate_serve_chaos(path) == []
    with open(path) as f:
        doc = json.load(f)
    for row in doc["rows"]:
        if row["name"] == "serve_chaos/storm":
            row["goodput_frac"] = 0.5          # collapse the goodput
    p = tmp_path / "BENCH_serve_chaos.json"
    p.write_text(json.dumps(doc))
    errs = gate.gate_serve_chaos(str(p))
    assert errs and "goodput_frac" in errs[0]
