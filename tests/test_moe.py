"""MoE dispatch correctness: sort-based capacity dispatch vs naive loop."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm.config import LMConfig
from repro.models.lm.ffn import moe_apply, moe_init

K = jax.random.PRNGKey(0)

CFG = LMConfig(name="t", d_model=32, n_layers=1, d_ff=16, vocab=64,
               n_experts=4, top_k=2, capacity_factor=8.0,   # no drops
               zebra_enabled=False)


def naive_moe(p, x, cfg):
    B, S, d = x.shape
    T = B * S
    xt = np.asarray(x.reshape(T, d), np.float64)
    logits = xt @ np.asarray(p["router"], np.float64)
    e = np.exp(logits - logits.max(-1, keepdims=True))
    probs = e / e.sum(-1, keepdims=True)
    y = np.zeros_like(xt)
    for t in range(T):
        top = np.argsort(-probs[t])[: cfg.top_k]
        w = probs[t][top]
        w = w / w.sum()
        for wi, ei in zip(w, top):
            h = np.maximum(xt[t] @ np.asarray(p["w_gate"][ei]), 0)  # silu approx below
            hg = np.asarray(jax.nn.silu(jnp.asarray(xt[t] @ np.asarray(p["w_gate"][ei]))))
            hu = xt[t] @ np.asarray(p["w_up"][ei])
            y[t] += wi * ((hg * hu) @ np.asarray(p["w_down"][ei]))
    return y.reshape(B, S, d)


def test_moe_matches_naive_with_big_capacity():
    p = moe_init(K, CFG, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32)) * 0.5
    y, zaux, raux = moe_apply(p, x, CFG, "infer")
    y_ref = naive_moe(p, x, CFG)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-3, atol=2e-3)
    assert np.isfinite(float(raux))


def test_moe_capacity_drops_tokens_not_crash():
    cfg = CFG.replace(capacity_factor=0.25)   # force overflow drops
    p = moe_init(K, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, 32))
    y, _, _ = moe_apply(p, x, cfg, "infer")
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))


def test_moe_grad_flows_to_experts_and_router():
    p = moe_init(K, CFG, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 8, 32))

    def loss(p):
        y, _, raux = moe_apply(p, x, CFG, "train")
        return jnp.sum(y ** 2) + 0.01 * raux
    g = jax.grad(loss)(p)
    assert float(jnp.sum(jnp.abs(g["router"]))) > 0
    assert float(jnp.sum(jnp.abs(g["w_gate"]))) > 0


def test_router_aux_near_one_when_balanced():
    """Switch aux loss == E * sum(me * ce) -> ~1 for uniform routing."""
    cfg = CFG.replace(top_k=1)
    p = moe_init(K, cfg, jnp.float32)
    # uniform router -> balanced
    p = dict(p, router=jnp.zeros_like(p["router"]))
    x = jax.random.normal(jax.random.PRNGKey(4), (4, 64, 32))
    _, _, raux = moe_apply(p, x, cfg, "infer")
    assert 0.5 < float(raux) < 2.0
