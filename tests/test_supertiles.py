"""ZebraConfig.tiles_for supertile selection: GEMM/gather kinds, VMEM
budget boundaries, non-divisible shrink paths, and the fits-the-budget
regression for f32 and bf16."""
import jax.numpy as jnp
import pytest

from repro.core import ZebraConfig
from repro.kernels import supertile as st


def _gemm_cost(stm, stk, bn, item):
    return stm * stk * item + stk * bn * item + stm * bn * 4 + stm * bn * 4


@pytest.mark.parametrize("dtype,item", [(jnp.float32, 4), (jnp.bfloat16, 2)])
def test_gemm_supertile_fits_budget(dtype, item):
    """Regression: the chosen supertile's per-step working set (activation
    windows + weight window + fp32 accumulator/output) really fits
    vmem_budget_bytes, for both dtypes."""
    for budget in (64 * 1024, 256 * 1024, 8 * 1024 * 1024):
        cfg = ZebraConfig(vmem_budget_bytes=budget)
        M, K, N, bs, bc = 256, 1024, 512, 8, 128
        stm, stk, bn = cfg.tiles_for(M, K, bs, bc, dtype, kind="gemm", n=N)
        assert stm % bs == 0 and stk % bc == 0
        assert M % stm == 0 and K % stk == 0          # divisor-constrained
        assert _gemm_cost(stm, stk, bn, item) <= budget or \
            (stm, stk) == (bs, bc)                    # never below one block


def test_gemm_supertile_budget_boundary():
    """Right at the boundary the chooser steps down; one byte above, it
    keeps the bigger supertile."""
    M, K, N, bs, bc = 256, 1024, 512, 8, 128
    item = 4
    big = ZebraConfig(vmem_budget_bytes=8 * 1024 * 1024)
    stm, stk, bn = big.tiles_for(M, K, bs, bc, jnp.float32, kind="gemm", n=N)
    cost = _gemm_cost(stm, stk, bn, item)
    at = ZebraConfig(vmem_budget_bytes=cost)
    assert at.tiles_for(M, K, bs, bc, jnp.float32, kind="gemm", n=N) \
        == (stm, stk, bn)
    below = ZebraConfig(vmem_budget_bytes=cost - 1)
    stm2, stk2, bn2 = below.tiles_for(M, K, bs, bc, jnp.float32,
                                      kind="gemm", n=N)
    assert (stm2 // bs) * (stk2 // bc) < (stm // bs) * (stk // bc) \
        or bn2 < bn


def test_gemm_supertile_bf16_at_least_f32_area():
    cfg = ZebraConfig(vmem_budget_bytes=128 * 1024)
    M, K, N, bs, bc = 512, 2048, 512, 8, 128
    f32 = cfg.tiles_for(M, K, bs, bc, jnp.float32, kind="gemm", n=N)
    bf16 = cfg.tiles_for(M, K, bs, bc, jnp.bfloat16, kind="gemm", n=N)
    assert bf16[0] * bf16[1] >= f32[0] * f32[1]


def test_gemm_supertile_non_divisible_block_counts_shrink():
    """Maps whose block counts are not powers of two take divisor
    supertiles (never ragged windows): nm=6 -> R=3, nk=5 -> C=5."""
    cfg = ZebraConfig()
    bs, bc = 8, 128
    M, K = 6 * bs, 5 * bc
    stm, stk, _ = cfg.tiles_for(M, K, bs, bc, jnp.float32, kind="gemm", n=64)
    assert M % stm == 0 and K % stk == 0
    assert stm == 3 * bs                  # largest divisor of 6 under cap 4
    assert stk == 5 * bc                  # 5 <= cap 8 and divides
    # prime block counts above the caps degenerate to one block per side
    stm_p, stk_p, _ = cfg.tiles_for(7 * bs, 13 * bc, bs, bc, jnp.float32,
                                    kind="gemm", n=64)
    assert stm_p == bs and stk_p == bc


def test_gemm_supertile_caps_bound_per_step_windows():
    """The compressed consumer carries one payload window per block of
    the supertile — the chooser must respect the module caps."""
    cfg = ZebraConfig(vmem_budget_bytes=64 * 1024 * 1024)   # effectively inf
    stm, stk, _ = cfg.tiles_for(4096, 8192, 8, 128, jnp.float32,
                                kind="gemm", n=4096)
    assert stm // 8 <= st.MAX_ROW_BLOCKS
    assert stk // 128 <= st.MAX_COL_BLOCKS


def test_gather_supertile_fits_and_divides():
    cfg = ZebraConfig(vmem_budget_bytes=96 * 1024)
    M, K, bs, bc = 256, 1024, 8, 128
    stm, stk = cfg.tiles_for(M, K, bs, bc, jnp.float32, kind="gather")
    assert M % stm == 0 and K % stk == 0
    assert 2 * stm * stk * 4 <= cfg.vmem_budget_bytes
    tiny = ZebraConfig(vmem_budget_bytes=1)
    assert tiny.tiles_for(M, K, bs, bc, jnp.float32, kind="gather") == (bs, bc)


def test_pack_window_divides_block_count():
    assert st.pack_window(256) == 16
    assert st.pack_window(21) == 7
    assert st.pack_window(13) == 13       # <= cap and divides itself
    assert st.pack_window(17) == 1        # prime above cap
    assert st.pack_window(1) == 1


def test_pack_window_respects_vmem_budget():
    """The pack pass holds 2*W*bs*bc*itemsize bytes per step — a small
    budget must shrink W below the fixed cap (and never below 1)."""
    bs, bc, item = 8, 128, 4
    per_slot = 2 * bs * bc * item                     # 8 KiB per W
    assert st.pack_window(256, bs, bc, item, budget=4 * per_slot) == 4
    assert st.pack_window(256, bs, bc, item, budget=1) == 1
    # W stays a divisor under the budget cap: cap 6 -> largest divisor 4
    assert st.pack_window(256, bs, bc, item, budget=6 * per_slot) == 4


def test_tiles_for_unknown_kind_and_missing_n_raise():
    cfg = ZebraConfig()
    with pytest.raises(ValueError):
        cfg.tiles_for(64, 256, 8, 128, jnp.float32, kind="nope")
    with pytest.raises(ValueError):
        cfg.tiles_for(64, 256, 8, 128, jnp.float32, kind="gemm")


def test_explicit_ragged_supertile_raises():
    """Explicit stm/stk that don't divide the block grid must raise —
    GM = nm // R truncation would silently leave output rows unwritten."""
    import jax
    from repro.kernels import zebra_mask_pack_op, zebra_spmm_cs_op, \
        zebra_spmm_op, zebra_mask_op
    bs, bc = 8, 128
    x = jnp.ones((48, 256), jnp.float32)           # nm=6, nk=2
    w = jnp.ones((256, 64), jnp.float32)
    _, bm = zebra_mask_op(x, 0.5, bs=bs, bc=bc)
    payload, bmf, _ = zebra_mask_pack_op(x, 0.5, bs=bs, bc=bc)
    with pytest.raises(ValueError, match="divide"):
        zebra_spmm_op(x, w, bm, bs=bs, bc=bc, stm=32)      # R=4 !| nm=6
    with pytest.raises(ValueError, match="divide"):
        zebra_spmm_cs_op(payload, w, bmf, bs=bs, bc=bc, stm=32)
    with pytest.raises(ValueError, match="block"):
        zebra_spmm_op(x, w, bm, bs=bs, bc=bc, stm=12)      # not bs-aligned


def test_gemm_plan_cache_hit_miss_and_ladder():
    """The cached autotuning chooser: same key -> cache hit, a zero_frac
    hint in a new 1/16 bucket -> miss, same bucket -> hit; the hint
    tightens the capacity ladder without touching the Pallas supertile;
    tiles_for(kind='gemm') routes through the same cache."""
    st.plan_cache_clear()
    args = (256, 1024, 512, 8, 128, 4)
    p1 = st.gemm_plan(*args)
    assert st.plan_cache_info() == {"hits": 0, "misses": 1, "size": 1}
    assert st.gemm_plan(*args) is p1
    assert st.plan_cache_info()["hits"] == 1

    p_hint = st.gemm_plan(*args, zero_frac=0.64)     # new bucket -> miss
    assert st.plan_cache_info()["misses"] == 2
    assert st.gemm_plan(*args, zero_frac=0.63) is p_hint   # same 1/16 bucket
    assert st.plan_cache_info()["hits"] == 2

    # the hint only tightens the ladder — kernel-form supertile unchanged
    assert (p_hint.stm, p_hint.stk, p_hint.bn) == (p1.stm, p1.stk, p1.bn)
    nm = 256 // 8
    for plan in (p1, p_hint):
        assert plan.caps == tuple(sorted(set(plan.caps)))  # sorted, unique
        assert plan.caps[-1] == nm                 # all-live fallback rung
        assert all(1 <= c <= nm for c in plan.caps)
    # rungs inserted near the expected live count (~0.36 * 32 ~ 12)
    expected = (1 - 0.64) * nm
    assert any(expected <= c <= expected + 2 * max(1, nm // 16)
               for c in p_hint.caps)

    # ZebraConfig.tiles_for(kind="gemm") is the same cached chooser
    cfg = ZebraConfig()                            # default budget == chooser's
    hits = st.plan_cache_info()["hits"]
    assert cfg.tiles_for(256, 1024, 8, 128, jnp.float32, kind="gemm",
                         n=512) == (p1.stm, p1.stk, p1.bn)
    assert st.plan_cache_info()["hits"] == hits + 1


def test_vmem_bounded_backend_degrades_over_budget():
    """A registered backend declaring vmem_bounded really is gated by the
    engine: maps over vmem_budget_bytes degrade to reference with the
    explicit 'vmem-bounded' reason (the built-ins self-tile and never
    hit it)."""
    from repro.core.backends import BackendSpec
    from repro.core.engine import _resolve_backend
    bounded = BackendSpec("bounded-test", trainable=False,
                          emits_stream=False, consumes_w=False,
                          vmem_bounded=True)
    assert _resolve_backend(bounded, mode="infer", tnet=None,
                            degenerate=False, over_budget=True) \
        == ("reference", "vmem-bounded")
    assert _resolve_backend(bounded, mode="infer", tnet=None,
                            degenerate=False, over_budget=False) \
        == ("bounded-test", None)
    # built-in stream self-tiles: vmem_bounded False, stays on backend
    from repro.core.backends import backend_spec
    assert not backend_spec("stream").vmem_bounded
    assert not backend_spec("fused").vmem_bounded


def test_unpack_xla_form_gates_nonfinite_dead_slots():
    """Regression: the interpret-form expander must jnp.where-gate dead
    blocks, not multiply — a dead block's revolving-door slot aliases a
    live block, and Inf * 0 would leak NaN where the kernel writes 0."""
    import numpy as np
    from repro.kernels import zebra_mask_op, zebra_pack_op, zebra_unpack_op
    bs, bc = 8, 128
    x = jnp.zeros((16, 128), jnp.float32).at[0, 0].set(jnp.inf)  # 1 live,
    y, bm = zebra_mask_op(x, 0.5, bs=bs, bc=bc)                  # 1 dead
    payload, _ = zebra_pack_op(y, bm, bs=bs, bc=bc)
    out = np.asarray(zebra_unpack_op(payload, bm, bs=bs, bc=bc))
    assert np.isinf(out[0, 0])
    assert not np.any(out[bs:])                    # dead block: exact zeros
    assert not np.any(np.isnan(out))
