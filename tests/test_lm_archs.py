"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
shape + finiteness asserts; plus prefill/decode == teacher-forcing
consistency for representative archs of each family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get as get_cfg, reduced
from repro.models.lm import LM

K = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = reduced(arch)
    m = LM(cfg)
    params = m.init(K)
    B, S = 2, 64
    toks = jax.random.randint(K, (B, S + 1), 0, cfg.vocab)
    ef = (jax.random.normal(K, (B, cfg.enc_seq, cfg.d_model))
          if cfg.encoder_layers else None)
    logits, aux = m.forward(params, toks[:, :-1], "train", ef)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    loss, metrics = m.loss(params, toks, "train", ef)
    assert np.isfinite(float(loss))
    g = jax.grad(lambda p: m.loss(p, toks, "train", ef)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree_util.tree_leaves(g))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The full configs carry the exact assigned dims."""
    spec = {
        "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "mamba2-2.7b": (64, 2560, 0, 0, 0, 50280),
    }[arch]
    cfg = get_cfg(arch)
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == spec, (arch, got, spec)


def test_moe_configs():
    l4 = get_cfg("llama4-scout-17b-a16e")
    assert (l4.n_experts, l4.top_k) == (16, 1)
    gr = get_cfg("granite-moe-1b-a400m")
    assert (gr.n_experts, gr.top_k) == (32, 8)
    mb = get_cfg("mamba2-2.7b")
    assert mb.ssm_state == 128 and mb.layer_pattern == ("ssm",)
    g3 = get_cfg("gemma3-4b")
    assert g3.layer_pattern.count("local") == 5 and g3.layer_pattern.count("global") == 1
    rg = get_cfg("recurrentgemma-2b")
    assert rg.layer_pattern == ("rglru", "rglru", "local")


@pytest.mark.parametrize("arch", ["command-r-35b", "gemma3-4b", "mamba2-2.7b",
                                  "recurrentgemma-2b", "granite-moe-1b-a400m"])
def test_prefill_decode_matches_forward(arch):
    """Greedy decode continuation must equal teacher-forced forward logits:
    prefill(t[:s]) + decode steps reproduce forward(t) at each position."""
    # fp32 compute: recurrent-state archs accumulate bf16 rounding over
    # decode steps (verified ~7e-6 in fp32 vs ~0.06 in bf16 — numeric, not
    # algorithmic); zebra off for bitwise comparability.
    cfg = reduced(arch).replace(zebra_enabled=False, compute_dtype="float32")
    m = LM(cfg)
    params = m.init(K)
    B, S, S0 = 1, 64, 32
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab)
    full_logits, _ = m.forward(params, toks, "infer")
    logits0, state, _ = m.prefill(params, toks[:, :S0], cache_len=S)
    np.testing.assert_allclose(np.asarray(logits0),
                               np.asarray(full_logits[:, S0 - 1]),
                               rtol=5e-2, atol=5e-2)
    for t in range(S0, S):
        logits_t, state = m.decode_step(params, toks[:, t:t + 1], state,
                                        jnp.int32(t))
        np.testing.assert_allclose(np.asarray(logits_t),
                                   np.asarray(full_logits[:, t]),
                                   rtol=5e-2, atol=5e-2)


def test_whisper_enc_dec_prefill_decode():
    cfg = reduced("whisper-medium").replace(zebra_enabled=False)
    m = LM(cfg)
    params = m.init(K)
    B, S, S0 = 1, 32, 16
    toks = jax.random.randint(K, (B, S), 0, cfg.vocab)
    ef = jax.random.normal(K, (B, cfg.enc_seq, cfg.d_model)) * 0.1
    full_logits, _ = m.forward(params, toks, "infer", ef)
    logits0, state, _ = m.prefill(params, toks[:, :S0], cache_len=S, enc_feats=ef)
    np.testing.assert_allclose(np.asarray(logits0),
                               np.asarray(full_logits[:, S0 - 1]),
                               rtol=5e-2, atol=5e-2)
    logits_t, state = m.decode_step(params, toks[:, S0:S0 + 1], state,
                                    jnp.int32(S0))
    np.testing.assert_allclose(np.asarray(logits_t),
                               np.asarray(full_logits[:, S0]),
                               rtol=5e-2, atol=5e-2)


def test_param_counts_sane():
    """param_counts drives MODEL_FLOPS — crosscheck against actual trees."""
    for arch in ("gemma3-4b", "granite-moe-1b-a400m"):
        cfg = reduced(arch).replace(zebra_enabled=False)
        m = LM(cfg)
        params = m.init(K)
        actual = sum(int(np.prod(x.shape))
                     for x in jax.tree_util.tree_leaves(params))
        est = cfg.param_counts()["total"]
        assert abs(actual - est) / actual < 0.1, (arch, actual, est)
