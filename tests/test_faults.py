"""Chaos tests: the (ingest boundary x fault class) matrix.

Every cell asserts THREE things: the injected fault was *detected*
(``integrity.failures()`` / a raised ``CorruptStream`` — output parity
alone cannot distinguish "detected and recovered" from "fault never
bit"), the pipeline *recovered* instead of failing, and the recovered
output matches the unfaulted run (bitwise where the backend contract is
bitwise — stream unpack, all_gather — tolerance only for the fused GEMM,
whose recovery recomputes the matmul in a different accumulation order).

Boundaries: engine producer->consumer (in-graph), serve's concrete
prefill->decode handoff (host-side), checkpoint restore (on-disk),
ring collectives (8-device subprocess), step supervisor (policy table).
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compress import integrity
from repro.core.engine import zebra_site
from repro.core.zebra import ZebraConfig
from repro.ft import (CorruptStream, DeviceLoss, Fault, FTConfig, PoisonBatch,
                      StepSupervisor, TransientStep, classify, corrupt_file,
                      corrupt_map, crashing_step, inject, policy_for)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# Wire contract unit
# ---------------------------------------------------------------------------

def test_validation_level_unknown():
    with pytest.raises(ValueError, match="unknown validation level"):
        ZebraConfig(validation="paranoid")


def _toy_stream(seed=0, nb=8, bs=4, bc=8, n_live=5):
    rng = np.random.default_rng(seed)
    payload = np.zeros((nb, bs, bc), np.float32)
    payload[:n_live] = rng.normal(size=(n_live, bs, bc)) + 2.0  # nonzero
    bitmap = np.zeros((2, 4), np.int8)
    bitmap.reshape(-1)[:n_live] = 1
    return jnp.asarray(payload), jnp.asarray(bitmap), jnp.int32(n_live)


def test_checksum_ignores_dead_tail():
    """Producers that zero the worst-case tail and producers that leave
    garbage there must hash identically — only live slots are signed."""
    payload, bitmap, n_live = _toy_stream()
    garbage = np.array(payload)
    garbage[int(n_live):] = 7.25
    c0 = integrity.stream_checksum(payload, bitmap, n_live)
    c1 = integrity.stream_checksum(jnp.asarray(garbage), bitmap, n_live)
    assert int(c0) == int(c1)
    # ...but a live-slot change must move the fold
    live_edit = np.array(payload)
    live_edit[0, 0, 0] += 1.0
    assert int(integrity.stream_checksum(jnp.asarray(live_edit), bitmap,
                                         n_live)) != int(c0)


def test_validate_payload_names_invariant():
    payload, bitmap, n_live = _toy_stream()
    with pytest.raises(CorruptStream, match="popcount"):
        integrity.validate_payload(payload, bitmap, int(n_live) + 1,
                                   level="structural")
    nanp = np.array(payload)
    nanp[2, 1, 1] = np.nan
    with pytest.raises(CorruptStream, match="non-finite"):
        integrity.validate_payload(nanp, bitmap, n_live, level="structural")
    trunc = np.array(payload)
    trunc[int(n_live) - 1] = 0.0
    with pytest.raises(CorruptStream, match="all-zero"):
        integrity.validate_payload(trunc, bitmap, n_live, level="structural")
    # off level checks nothing
    integrity.validate_payload(nanp, bitmap, n_live, level="off")


# ---------------------------------------------------------------------------
# Failure taxonomy
# ---------------------------------------------------------------------------

def test_classify_and_policies():
    assert policy_for(CorruptStream("x")) == "recompute-dense"
    assert policy_for(TransientStep("x")) == "restore-retry"
    assert policy_for(PoisonBatch("x")) == "skip-batch"
    assert policy_for(DeviceLoss("x")) == "remesh"
    # status-marker matching for errors raised outside the taxonomy
    assert classify(RuntimeError("worker preempted")) is TransientStep
    assert classify(OSError("connection reset by peer")) is TransientStep
    assert classify(FloatingPointError("overflow")) is PoisonBatch
    # unrecognized errors are bugs, not faults
    assert classify(ValueError("bad argument")) is None
    assert classify(KeyError("w")) is None
    assert classify(KeyboardInterrupt()) is None
    assert policy_for(AssertionError()) is None


# ---------------------------------------------------------------------------
# Engine boundary (in-graph check + lax.cond recompute-from-dense)
# ---------------------------------------------------------------------------

_ENG = ZebraConfig(t_obj=0.8, block_seq=8, block_ch=128, mode="infer",
                   interpret=True)


def _eng_x():
    return jax.random.normal(jax.random.PRNGKey(0), (2, 32, 256), jnp.float32)


@pytest.mark.parametrize("kind,level", [
    ("bitflip", "structural"), ("truncate", "structural"),
    ("nan", "structural"), ("count", "structural"),
    ("value", "checksum"),
])
def test_engine_stream_detect_recover_bitwise(kind, level):
    x = _eng_x()
    cfg = _ENG.replace(backend="stream", validation=level)
    y_clean, _ = zebra_site(x, cfg, site="m")
    integrity.clear_failures()
    with inject(Fault(kind=kind, site="engine:m", arg=3)) as plan:
        y_f, _ = zebra_site(x, cfg, site="m")
        jax.block_until_ready(y_f)
    assert plan.injected == [(kind, "engine:m")]
    assert integrity.failures() == ["engine:m"], "detection must fire"
    np.testing.assert_array_equal(np.asarray(y_f), np.asarray(y_clean))


@pytest.mark.parametrize("kind,level", [
    ("bitflip", "structural"), ("value", "checksum"),
])
def test_engine_fused_detect_recover(kind, level):
    x = _eng_x()
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 64), jnp.float32)
    cfg = _ENG.replace(backend="fused", validation=level)
    y_clean, _ = zebra_site(x, cfg, site="f", w=w)
    integrity.clear_failures()
    with inject(Fault(kind=kind, site="engine:f")) as plan:
        y_f, _ = zebra_site(x, cfg, site="f", w=w)
        jax.block_until_ready(y_f)
    assert plan.injected == [(kind, "engine:f")]
    assert integrity.failures() == ["engine:f"]
    # fused recovery re-runs the GEMM in reference accumulation order
    np.testing.assert_allclose(np.asarray(y_f), np.asarray(y_clean),
                               atol=1e-4, rtol=1e-4)


def test_engine_value_invisible_at_structural():
    """The level boundary, stated as a test: a finite nonzero value flip
    passes every structural invariant — only the checksum sees it."""
    x = _eng_x()
    cfg = _ENG.replace(backend="stream", validation="structural")
    integrity.clear_failures()
    with inject(Fault(kind="value", site="engine:m")):
        y_f, _ = zebra_site(x, cfg, site="m")
        jax.block_until_ready(y_f)
    assert integrity.failures() == []


def test_engine_validation_off_identity():
    """validation="off" output is byte-identical to the pre-validation
    pipeline, and taps trace to nothing without an armed plan."""
    x = _eng_x()
    y_off, aux_off = zebra_site(x, _ENG.replace(backend="stream"), site="m")
    y_on, aux_on = zebra_site(
        x, _ENG.replace(backend="stream", validation="structural"), site="m")
    np.testing.assert_array_equal(np.asarray(y_off), np.asarray(y_on))
    assert int(aux_off.measured_bytes) == int(aux_on.measured_bytes)


def test_engine_detection_under_jit():
    """The whole validated pipeline jits; the recovery branch's
    debug.callback fires at RUN time only on faulted executions."""
    x = _eng_x()
    cfg = _ENG.replace(backend="stream", validation="structural")
    with inject(Fault(kind="bitflip", site="engine:j", times=-1)):
        f = jax.jit(lambda v: zebra_site(v, cfg, site="j")[0])
        integrity.clear_failures()
        y = jax.block_until_ready(f(x))
        assert integrity.failures() == ["engine:j"]
        integrity.clear_failures()
        jax.block_until_ready(f(x))          # cached trace, fault re-bites
        assert integrity.failures() == ["engine:j"]
    y_clean, _ = zebra_site(x, cfg.replace(validation="off"), site="j")
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_clean))


# ---------------------------------------------------------------------------
# Serve boundary (concrete CompressedMap handoff, per-leaf dense fallback)
# ---------------------------------------------------------------------------

def _cache_tree():
    k1 = jax.random.normal(jax.random.PRNGKey(2), (64, 256), jnp.float32)
    k2 = jax.random.normal(jax.random.PRNGKey(3), (64, 256), jnp.float32)
    zero = np.ones((8, 2), bool)
    zero[1::2] = False                        # kill half the blocks
    mask = jnp.repeat(jnp.repeat(jnp.asarray(zero), 8, 0), 128, 1)
    return {"a": {"k": k1 * mask}, "b": {"v": k2 * mask}}


@pytest.mark.parametrize("kind,level", [
    ("bitflip", "structural"), ("truncate", "structural"),
    ("nan", "structural"), ("count", "structural"), ("value", "checksum"),
])
def test_serve_handoff_detect_recover(kind, level):
    from repro.compress import compress_tree, decompress_tree
    from repro.launch.serve import validate_state_ingest
    dense = _cache_tree()
    ctree = compress_tree(dense, bs=8, bc=128,
                          checksum=(level == "checksum"))
    with inject(Fault(kind=kind, site="serve", arg=1)) as plan:
        recovered, n_bad = validate_state_ingest(ctree, dense, level)
    assert plan.injected == [(kind, "serve")]
    assert n_bad == 1, "exactly the corrupted leaf recovers dense"
    out = decompress_tree(recovered)
    for key_path in (("a", "k"), ("b", "v")):
        want = dense[key_path[0]][key_path[1]]
        got = out[key_path[0]][key_path[1]]
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_serve_handoff_clean_passthrough():
    from repro.compress import CompressedMap, compress_tree
    from repro.launch.serve import validate_state_ingest
    dense = _cache_tree()
    ctree = compress_tree(dense, bs=8, bc=128, checksum=True)
    out, n_bad = validate_state_ingest(ctree, dense, "checksum")
    assert n_bad == 0
    leaves = jax.tree_util.tree_leaves(
        out, is_leaf=lambda l: isinstance(l, CompressedMap))
    assert all(isinstance(l, CompressedMap) for l in leaves)


def test_corrupt_map_each_kind_raises():
    from repro.compress import compress
    from repro.compress.integrity import attach_checksum, validate_map
    x = np.asarray(_cache_tree()["a"]["k"])
    cm = attach_checksum(compress(jnp.asarray(x), bs=8, bc=128,
                                  use_kernel=False))
    validate_map(cm, level="checksum")        # clean passes
    for kind in ("bitflip", "truncate", "nan", "count", "value"):
        bad = corrupt_map(cm, kind, arg=2)
        with pytest.raises(CorruptStream):
            validate_map(bad, level="checksum", site=kind)


# ---------------------------------------------------------------------------
# Checkpoint boundary (CRC manifest + newest -> older fallback)
# ---------------------------------------------------------------------------

def _save_steps(ckpt, steps):
    for s in steps:
        state = {"w": jnp.full((16, 16), float(s)), "s": jnp.int32(s)}
        ckpt.save(s, state, {"loader_step": s})
    ckpt.wait()
    return state


def test_ckpt_corrupt_newest_falls_back(tmp_path):
    from repro.checkpoint import CheckpointManager
    ckpt = CheckpointManager(str(tmp_path), keep_last=3)
    like = _save_steps(ckpt, [2, 4, 6])
    corrupt_file(os.path.join(str(tmp_path), "step_6", "shard_0.npz"))
    step, tree, extra = ckpt.restore(like)
    assert step == 4, "corrupt newest must fall back to the older step"
    assert float(np.asarray(tree["w"])[0, 0]) == 4.0
    assert extra["loader_step"] == 4


def test_ckpt_explicit_step_never_falls_back(tmp_path):
    from repro.checkpoint import CheckpointManager
    ckpt = CheckpointManager(str(tmp_path), keep_last=3)
    like = _save_steps(ckpt, [2, 4])
    corrupt_file(os.path.join(str(tmp_path), "step_4", "shard_0.npz"))
    # the flip is caught either by the zip member CRC on read or by the
    # manifest leaf CRC — both surface as CorruptStream naming the leaf
    with pytest.raises(CorruptStream, match="CRC mismatch|unreadable"):
        ckpt.restore(like, step=4)


def test_ckpt_whole_chain_corrupt_raises(tmp_path):
    from repro.checkpoint import CheckpointManager
    ckpt = CheckpointManager(str(tmp_path), keep_last=3)
    like = _save_steps(ckpt, [2, 4])
    for s in (2, 4):
        corrupt_file(os.path.join(str(tmp_path), f"step_{s}", "shard_0.npz"))
    with pytest.raises(CorruptStream, match="no restorable checkpoint"):
        ckpt.restore(like)


def test_ckpt_truncated_manifest_falls_back(tmp_path):
    from repro.checkpoint import CheckpointManager
    ckpt = CheckpointManager(str(tmp_path), keep_last=3)
    like = _save_steps(ckpt, [2, 4])
    mpath = os.path.join(str(tmp_path), "step_4", "manifest.json")
    with open(mpath, "r+") as f:
        f.truncate(10)                       # killed mid-write
    step, tree, _ = ckpt.restore(like)
    assert step == 2


def test_ckpt_pre_checksum_manifest_restores(tmp_path):
    """Manifests written before the CRC scheme (no ``checksums`` key)
    restore unchanged — no forced re-save of old checkpoints."""
    from repro.checkpoint import CheckpointManager
    ckpt = CheckpointManager(str(tmp_path), keep_last=3)
    like = _save_steps(ckpt, [2])
    mpath = os.path.join(str(tmp_path), "step_2", "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    del manifest["checksums"]
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    step, tree, _ = ckpt.restore(like)
    assert step == 2 and float(np.asarray(tree["w"])[0, 0]) == 2.0


def test_ckpt_acts_restore_validates(tmp_path):
    """A flipped on-disk index bit would silently relocate every later
    payload block; restore_acts' structural check names it instead."""
    from repro.checkpoint import CheckpointManager
    ckpt = CheckpointManager(str(tmp_path), keep_last=2)
    acts = {"h": np.asarray(_cache_tree()["a"]["k"])}
    ckpt.save_acts(3, acts, compressed=True, bs=8, bc=128)
    out = ckpt.restore_acts(3)               # structural validation default
    np.testing.assert_array_equal(out["h"], acts["h"])
    path = os.path.join(str(tmp_path), "acts_3.npz")
    data = dict(np.load(path).items())       # tamper the stored index: one
    idx = np.array(data["h/index"])          # flipped bit != n_live popcount
    idx[0] ^= 1
    data["h/index"] = idx
    np.savez(path, **data)
    with pytest.raises(CorruptStream, match="popcount"):
        ckpt.restore_acts(3)
    assert "h" in ckpt.restore_acts(3, validation="off")  # opt-out preserved


# ---------------------------------------------------------------------------
# Supervisor policies
# ---------------------------------------------------------------------------

def _counting_iter():
    class It:
        i = 0
        def __next__(self):
            self.i += 1
            return jnp.full((4,), float(self.i))
        def restore(self, step):
            self.i = int(step)
    return It()


def _plain_step(state, batch):
    return ({"w": state["w"] + batch.mean(), "step": state["step"] + 1},
            {"loss": jnp.float32(1.0)})


def test_supervisor_failure_decay(tmp_path):
    """One transient blip must not count against max_failures forever."""
    cfg = FTConfig(ckpt_dir=str(tmp_path), ckpt_every=2, max_failures=2,
                   failure_decay_steps=3, backoff_base_s=0.0)
    sup = StepSupervisor(cfg)
    step_fn = crashing_step(_plain_step, crash_at=5)
    state = {"w": jnp.float32(0.0), "step": jnp.int32(0)}
    it = _counting_iter()
    _, step = sup.run(state, step_fn, it, steps=12,
                      loader_state_fn=lambda: it.i)
    assert step == 12
    assert sup.failures == 0, "sustained success must decay the counter"
    assert len(sup.failure_log) == 1
    assert sup.failure_log[0]["policy"] == "restore-retry"


def test_supervisor_unclassified_reraises(tmp_path):
    cfg = FTConfig(ckpt_dir=str(tmp_path), ckpt_every=2)
    sup = StepSupervisor(cfg)
    step_fn = crashing_step(_plain_step, crash_at=4,
                            exc=lambda: ValueError("typo in the model"))
    state = {"w": jnp.float32(0.0), "step": jnp.int32(0)}
    with pytest.raises(ValueError, match="typo"):
        sup.run(state, step_fn, _counting_iter(), steps=8)
    assert sup.failures == 0, "bugs are not counted as faults"


def test_supervisor_poison_skips_batch(tmp_path):
    cfg = FTConfig(ckpt_dir=str(tmp_path), ckpt_every=100,
                   max_poison_skips=2)
    sup = StepSupervisor(cfg)
    def step_fn(state, batch):
        new = {"w": state["w"] + 1.0, "step": state["step"] + 1}
        loss = jnp.where(jnp.isclose(batch.mean(), 4.0), jnp.nan, 1.0)
        return new, {"loss": jnp.float32(loss)}
    state = {"w": jnp.float32(0.0), "step": jnp.int32(0)}
    final, step = sup.run(state, step_fn, _counting_iter(), steps=8)
    assert step == 8
    assert len(sup.skipped_batches) == 1
    assert sup.failures == 0, "a poison batch is not a restore-class failure"
    # the poisoned update was discarded: 7 applied updates, not 8
    assert float(final["w"]) == 7.0


def test_supervisor_all_poison_gives_up(tmp_path):
    cfg = FTConfig(ckpt_dir=str(tmp_path), max_poison_skips=2)
    sup = StepSupervisor(cfg)
    def step_fn(state, batch):
        return state, {"loss": jnp.float32(jnp.nan)}
    with pytest.raises(PoisonBatch):
        sup.run({"w": jnp.float32(0.0)}, step_fn, _counting_iter(), steps=8)
    assert len(sup.skipped_batches) == cfg.max_poison_skips + 1


def test_supervisor_device_loss_hook(tmp_path):
    cfg = FTConfig(ckpt_dir=str(tmp_path), ckpt_every=100)
    sup = StepSupervisor(cfg)
    step_fn = crashing_step(_plain_step, crash_at=3,
                            exc=lambda: DeviceLoss("lost a host"))
    calls = []
    def remesh(state):
        calls.append(1)
        return state
    state = {"w": jnp.float32(0.0), "step": jnp.int32(0)}
    _, step = sup.run(state, step_fn, _counting_iter(), steps=6,
                      on_device_loss=remesh)
    assert step == 6 and calls == [1]
    assert sup.failure_log[0]["policy"] == "remesh"


def test_straggler_enters_window():
    """The flagged dt must join the trailing window so a sustained
    slowdown re-baselines instead of flagging forever."""
    sup = StepSupervisor(FTConfig(straggler_window=10, straggler_zscore=3.0))
    for _ in range(10):
        sup.check_straggler(0.1)
    assert sup.check_straggler(5.0)
    assert sup.times[-1] == 5.0
    # window poisoned toward the new regime: repeating the slow dt soon
    # stops being an outlier
    flags = [sup.check_straggler(5.0) for _ in range(10)]
    assert not flags[-1]


def test_backoff_monotone_and_bounded(tmp_path):
    cfg = FTConfig(ckpt_dir=str(tmp_path), backoff_base_s=0.1,
                   backoff_cap_s=0.4, backoff_jitter=0.25)
    sup = StepSupervisor(cfg)
    lows, highs = [], []
    for k in (1, 2, 3, 4):
        sup.failures = k
        base = min(0.1 * 2 ** (k - 1), 0.4)
        lows.append(base * 0.75)
        highs.append(base * 1.25)
        d = sup._backoff()
        assert lows[-1] <= d <= highs[-1]


# ---------------------------------------------------------------------------
# Ring collectives boundary (8-device subprocess)
# ---------------------------------------------------------------------------

_RING_SCRIPT = r"""
import os, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np, jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P
from repro.distributed import collectives as coll
from repro.compress import integrity
from repro.ft import inject, Fault

mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
M, K, BS, BC = 64, 256, 8, 128
NM, NK = M // BS, K // BC
rng = np.random.default_rng(3)
sh = rng.normal(size=(4, M, K)).astype(np.float32)
keep = rng.random((4, NM, NK)) < 0.4
sh = sh * np.repeat(np.repeat(keep, BS, 1), BC, 2)
sh[2] = 0.0                                  # all-dead shard edge case
X = jnp.asarray(sh.reshape(4 * M, K))
out = {}

sm = lambda f, outs: jax.jit(coll.shard_map_compat(
    f, mesh, in_specs=(P("model", None),), out_specs=outs))

def mk_ag(level):
    def ag(x):
        y, link = coll.zebra_all_gather(x, "model", bs=BS, bc=BC, tiled=True,
                                        validation=level, site="t")
        return y, lax.psum(link.moved, "model")
    return sm(ag, (P(), P()))

y_ref = sm(lambda x: lax.all_gather(x, "model", axis=0, tiled=True), P())(X)
y0, moved0 = mk_ag("structural")(X)
out["clean"] = {"parity": bool((np.asarray(y0) == np.asarray(y_ref)).all()),
                "moved": int(moved0)}

for level in ("structural", "checksum"):
    for kind, arg in (("drop_hop", 2), ("drop_hop", 3)):
        integrity.clear_failures()
        with inject(Fault(kind=kind, site="ring:t", arg=arg)) as plan:
            y2, moved2 = mk_ag(level)(X)
            jax.block_until_ready(y2)
        out[f"ag_{kind}{arg}_{level}"] = {
            "injected": len(plan.injected), "detected": len(integrity.failures()),
            "parity": bool((np.asarray(y2) == np.asarray(y_ref)).all()),
            "retry_bytes": int(moved2) > int(moved0)}

def mk_ps(level):
    def ps(x):
        y, union, link = coll.zebra_psum_stream(x, "model", bs=BS, bc=BC,
                                                validation=level, site="p")
        return y, lax.psum(link.moved, "model")
    return sm(ps, (P("model", None), P()))

yp_ref = sm(lambda x: lax.psum(x, "model"), P("model", None))(X)
yp0, _ = mk_ps("checksum")(X)
out["psum_clean"] = {"close": bool(np.allclose(np.asarray(yp0),
                                               np.asarray(yp_ref), atol=1e-4))}
integrity.clear_failures()
with inject(Fault(kind="drop_hop", site="ring:p", arg=1)) as plan:
    yp2, _ = mk_ps("checksum")(X)
    jax.block_until_ready(yp2)
out["psum_drop"] = {
    "injected": len(plan.injected), "detected": len(integrity.failures()),
    "parity": bool((np.asarray(yp2) == np.asarray(yp_ref)).all())}

# bitmap-union edge: one shard dead -> union is the union of the others
def un(x):
    y, union, link = coll.zebra_psum_stream(x, "model", bs=BS, bc=BC,
                                            validation="structural")
    return union, lax.psum(link.moved, "model")
union, _ = sm(un, (P(), P()))(X)
want_union = (np.abs(sh).reshape(4, NM, BS, NK, BC).max((2, 4)) > 0).any(0)
out["union_edge"] = {"match": bool((np.asarray(union).astype(bool)
                                    == want_union).all())}
print("RESULT " + json.dumps(out))
"""


def test_ring_chaos_8dev():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", _RING_SCRIPT], env=env,
                       cwd=REPO, capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-4000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    assert out["clean"]["parity"], "clean validated gather must stay bitwise"
    for key in ("ag_drop_hop2_structural", "ag_drop_hop3_structural",
                "ag_drop_hop2_checksum", "ag_drop_hop3_checksum"):
        cell = out[key]
        assert cell["injected"] == 1, key
        assert cell["detected"] >= 1, f"{key}: fault not detected"
        assert cell["parity"], f"{key}: recovery not bitwise"
        assert cell["retry_bytes"], f"{key}: dense retry must be accounted"
    assert out["psum_clean"]["close"]
    assert out["psum_drop"]["detected"] >= 1
    assert out["psum_drop"]["parity"], \
        "psum recovery falls back to dense lax.psum (bitwise to reference)"
    assert out["union_edge"]["match"]
