"""Compressed collectives (distributed/collectives.py).

Main-process tests (1 device): the consumer-order pack against the
argsort oracle, capability resolution + registry declarations, the
SiteAux/LayerAux per-link byte plumbing (incl. the >16 MiB carry), and
the meter's LinkRecord reconciliation.

Subprocess tests (8 forced host devices, like test_dryrun_subprocess):
bitwise all-gather parity against ``lax.all_gather`` at two zero
fractions plus an all-dead shard, exact link-byte accounting, the
payload-form psum/reduce-scatter parity, the shared
``psum_exact_bytes`` overflow regression past 16 MiB, and the ffn /
KV layer exchanges end to end under ``comm_context``.
"""
import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.compress.meter import BandwidthMeter
from repro.compress.stream import nonzero_bitmap
from repro.core.backends import BackendSpec, backend_spec, register_backend
from repro.core.engine import MB_BASE, LayerAux, SiteAux, merge_site_aux
from repro.distributed import collectives as coll
from repro.distributed.ctx import comm_context
from repro.kernels.ref import zebra_pack_ref

BS, BC = 8, 128


def _masked_map(rng, m, k, zero_frac):
    keep = (rng.random((m // BS, k // BC)) > zero_frac).astype(np.float32)
    x = rng.standard_normal((m, k)).astype(np.float32)
    return x * np.repeat(np.repeat(keep, BS, 0), BC, 1)


# ---------------------------------------------------------------------------
# consumer-order pack
# ---------------------------------------------------------------------------

def test_pack_consumer_order_matches_oracle():
    rng = np.random.default_rng(0)
    x = jnp.asarray(_masked_map(rng, 64, 512, 0.6))
    bm = nonzero_bitmap(x, BS, BC)
    payload, n_live = coll._pack_consumer_order(x, bm, BS, BC)
    ref_payload, ref_live = zebra_pack_ref(x, bm, BS, BC)
    assert int(n_live) == int(ref_live)
    np.testing.assert_array_equal(np.asarray(payload), np.asarray(ref_payload))


# ---------------------------------------------------------------------------
# capability resolution + registry
# ---------------------------------------------------------------------------

def test_resolve_comms_no_context_is_noop():
    assert coll.resolve_comms("stream", rows=64, cols=512, bs=BS, bc=BC) \
        == (None, None)


def test_resolve_comms_degrade_reasons():
    with comm_context("model", 1):
        assert coll.resolve_comms("stream", rows=64, cols=512, bs=BS, bc=BC) \
            == ("dense", "single-device")
    with comm_context("model", 4):
        assert coll.resolve_comms("stream", rows=64, cols=512, bs=BS, bc=BC) \
            == ("compressed", None)
        assert coll.resolve_comms("reference", rows=64, cols=512,
                                  bs=BS, bc=BC) == ("dense",
                                                    "comms-capability")
        assert coll.resolve_comms("pallas", rows=64, cols=512,
                                  bs=BS, bc=BC) == ("dense",
                                                    "comms-capability")
        assert coll.resolve_comms("stream", rows=63, cols=512,
                                  bs=BS, bc=BC) == ("dense", "non-divisible")


def test_registry_comms_declarations():
    assert backend_spec("stream").comms == "compressed"
    assert backend_spec("fused").comms == "compressed"
    assert backend_spec("reference").comms is None
    assert backend_spec("pallas").comms is None


def test_registry_rejects_bad_comms():
    with pytest.raises(ValueError, match="unknown comms mode"):
        register_backend(BackendSpec(
            "bad_comms", trainable=False, emits_stream=True, consumes_w=False,
            vmem_bounded=False, payload_order="consumer", comms="zip"))
    with pytest.raises(ValueError, match="requires\\s+emits_stream"):
        register_backend(BackendSpec(
            "bad_comms2", trainable=False, emits_stream=False,
            consumes_w=False, vmem_bounded=False, comms="compressed"))


# ---------------------------------------------------------------------------
# per-link aux plumbing
# ---------------------------------------------------------------------------

def test_attach_link_and_degrade_label():
    sa = SiteAux.empty(backend="stream")
    sa = coll.attach_link(sa, coll.LinkBytes(jnp.int32(100), jnp.int32(400)))
    assert int(sa.ici_bytes) == 100 and int(sa.ici_dense_bytes) == 400
    assert sa.backend == "stream"
    sa = coll.attach_link(sa, coll.dense_link(50, 3), reason="non-divisible")
    assert int(sa.ici_bytes) == 200 and int(sa.ici_dense_bytes) == 500
    assert sa.backend == "stream+dense-comms(non-divisible)"


def test_merge_site_aux_sums_ici_legs():
    a = SiteAux.empty(backend="stream")
    a = coll.attach_link(a, coll.LinkBytes(jnp.int32(10), jnp.int32(40)))
    b = SiteAux.empty(backend="stream")
    b = coll.attach_link(b, coll.LinkBytes(jnp.int32(5), jnp.int32(60)))
    m = merge_site_aux(a, b)
    assert int(m.ici_bytes) == 15 and int(m.ici_dense_bytes) == 100


def test_layer_aux_ici_pair_carries_past_16mib():
    # 3 layers x 7 MiB per link crosses MB_BASE: the f32 display value
    # would round, the (hi, lo) pair must stay exact
    per = 7 * 2 ** 20 + 1
    sa = coll.attach_link(SiteAux.empty("stream"),
                          coll.LinkBytes(jnp.int32(per), jnp.int32(4 * per)))
    acc = LayerAux.zero()
    for _ in range(3):
        acc = acc + LayerAux.of_site(sa)
    moved, dense = acc.ici_bytes_exact()
    assert moved == 3 * per and dense == 12 * per
    assert moved > MB_BASE       # the pair actually crossed the carry line


# ---------------------------------------------------------------------------
# meter LinkRecord
# ---------------------------------------------------------------------------

def test_meter_record_link_reconciles():
    m = BandwidthMeter()
    # 3 inbound maps of (256, 1024) f32 blocks, 300 live blocks total
    r = m.record_link("layer_out", "model", m=256, k=1024, bs=BS, bc=BC,
                      dtype_bits=32, n_live=300, n_maps=3)
    nb = (256 // BS) * (1024 // BC)
    assert r.measured_bytes == 300 * BS * BC * 4 + 3 * ((nb + 7) // 8)
    assert 0 < r.zero_frac < 1
    out = m.reconcile()
    assert "link:layer_out@model" in out["deltas"]
    assert m.ici_bytes("model") == r.measured_bytes
    assert m.ici_bytes("data") == 0
    assert m.ici_dense_bytes() == 3 * 256 * 1024 * 4
    assert m.ici_per_axis() == {"model": (r.measured_bytes, r.dense_bytes)}


def test_meter_record_link_bad_bytes_fail_reconcile():
    m = BandwidthMeter()
    r = m.record_link("layer_out", "model", m=256, k=1024, bs=BS, bc=BC,
                      dtype_bits=32, n_live=300, n_maps=3)
    r.payload_bytes += 4096            # corrupt: off-model extra bytes
    with pytest.raises(AssertionError, match="index-padding bound"):
        m.reconcile()


# ---------------------------------------------------------------------------
# 8-device subprocess: parity + exact byte accounting + layer exchanges
# ---------------------------------------------------------------------------

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, functools
import jax, jax.numpy as jnp, numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P
from repro.distributed import collectives as coll
from repro.distributed.ctx import comm_context
from repro.launch.mesh import _make_mesh

BS, BC = 8, 128
M, K = 64, 512
NM, NK = M // BS, K // BC
mesh = _make_mesh((2, 4), ("data", "model"))
out = {}

def shards_at(zf, n, seed, dead=None):
    rng = np.random.default_rng(seed)
    keep = (rng.random((n, NM, NK)) > zf).astype(np.float32)
    x = rng.integers(-8, 9, size=(n, M, K)).astype(np.float32)
    x = x * np.repeat(np.repeat(keep, BS, 1), BC, 2)
    if dead is not None:
        x[dead] = 0.0
    return x

def stream(lv):
    return int(lv) * BS * BC * 4 + (NM * NK + 7) // 8

sm = lambda f, outs: jax.jit(coll.shard_map_compat(
    f, mesh, in_specs=(P("model", None),), out_specs=outs))

# --- all_gather parity at two zero fractions + an all-dead shard ---
for tag, zf, dead in (("zf64", 0.64, None), ("zf90", 0.9, None),
                      ("dead", 0.64, 2)):
    sh = shards_at(zf, 4, seed=3)
    if dead is not None:
        sh[dead] = 0.0
    X = jnp.asarray(sh.reshape(4 * M, K))
    def ag(x):
        y, link = coll.zebra_all_gather(x, "model", bs=BS, bc=BC, tiled=True)
        return (y, lax.psum(link.moved, "model"),
                lax.psum(link.dense, "model"))
    y, moved, dense = sm(ag, (P(), P(), P()))(X)
    y_ref = sm(lambda x: lax.all_gather(x, "model", axis=0, tiled=True),
               P())(X)
    live = [int((np.abs(sh[s]).reshape(NM, BS, NK, BC).max((1, 3)) > 0).sum())
            for s in range(4)]
    out[tag] = {
        "parity": bool((np.asarray(y) == np.asarray(y_ref)).all())
                  and bool((np.asarray(y) == sh.reshape(4 * M, K)).all()),
        "moved": int(moved), "dense": int(dense),
        "pred": 3 * sum(stream(lv) for lv in live)}

# --- psum_stream + reduce_scatter parity (integer data: bitwise) ---
sh = shards_at(0.64, 4, seed=5)
X = jnp.asarray(sh.reshape(4 * M, K))
def ps(x):
    y, union, link = coll.zebra_psum_stream(x, "model", bs=BS, bc=BC)
    return y, lax.psum(link.moved, "model")
y, moved = sm(ps, (P("model", None), P()))(X)
y_ref = sm(lambda x: lax.psum(x, "model"), P("model", None))(X)
union = (np.abs(sh).reshape(4, NM, BS, NK, BC).max((2, 4)) > 0).any(0)
out["psum"] = {"parity": bool((np.asarray(y) == np.asarray(y_ref)).all()),
               "moved": int(moved),
               "pred": 4 * 3 * stream(int(union.sum()))}

def rs(x):
    y, link = coll.zebra_reduce_scatter(x, "model", bs=BS, bc=BC)
    return y, lax.psum(link.moved, "model")
y, moved = sm(rs, (P("model", None), P()))(X)
y_ref = sm(lambda x: lax.psum_scatter(x, "model", scatter_dimension=0,
                                      tiled=True), P("model", None))(X)
Ml = M // 4
cl = [int(union.reshape(4, Ml // BS, NK)[c].sum()) for c in range(4)]
cs = lambda lv: lv * BS * BC * 4 + ((Ml // BS) * NK + 7) // 8
out["rs"] = {"parity": bool((np.asarray(y) == np.asarray(y_ref)).all()),
             "moved": int(moved), "pred": 3 * sum(cs(lv) for lv in cl)}

# --- psum_exact_bytes: total past int32 (the 2**16-leg split) ---
def pe(b):
    hi, lo = coll.psum_exact_bytes(b[0], ("data", "model"))
    return hi, lo
bts = np.arange(8, dtype=np.int64) * 7 + 300_000_001     # sum ~2.4e9 > 2**31
hi, lo = jax.jit(coll.shard_map_compat(
    pe, mesh, in_specs=(P(("data", "model")),), out_specs=(P(), P())))(
        jnp.asarray(bts.astype(np.int32)))
out["psum_bytes"] = {"total": int(hi) * 16777216 + int(lo),
                     "pred": int(bts.sum())}

# --- layer exchanges end to end under comm_context ---
from repro.models.lm.config import LMConfig
from repro.models.lm.ffn import ffn_layer_out_exchange
from repro.models.lm.attention import gather_kv_shards
from repro.core.zebra import ZebraConfig

cfg = LMConfig(d_model=512, zebra_backend="stream",
               zebra_sites=("ffn_hidden", "layer_out"))
B, S = 2, 32
rng = np.random.default_rng(9)
Y = jnp.asarray(rng.standard_normal((B, 4 * S, 512)).astype(np.float32))

def ffn_ex(y):
    with comm_context("model", 4):
        yf, sa = ffn_layer_out_exchange(y, cfg, "infer")
    return (yf, jnp.int32(sa.backend == "stream"),
            lax.psum(jnp.asarray(sa.ici_bytes).astype(jnp.int32), "model"))
yf, comp_ok, moved = jax.jit(coll.shard_map_compat(
    ffn_ex, mesh, in_specs=(P(None, "model", None),),
    out_specs=(P(), P(), P())))(Y)
# parity oracle: mask each shard like the site does, then dense gather
def ffn_dense(y):
    with comm_context("model", 4):
        zc = ZebraConfig(enabled=True, t_obj=cfg.zebra_t_obj, mode="infer",
                         backend="stream", use_tnet=False)
        from repro.core.engine import zebra_site
        yz, _ = zebra_site(y, zc, site="layer_out")
        return lax.all_gather(yz, "model", axis=1, tiled=True)
yf_ref = jax.jit(coll.shard_map_compat(
    ffn_dense, mesh, in_specs=(P(None, "model", None),), out_specs=P()))(Y)
out["ffn"] = {"parity": bool((np.asarray(yf) == np.asarray(yf_ref)).all()),
              "compressed": bool(comp_ok), "moved": int(moved)}

# degraded exchange: reference backend -> dense path + labeled reason
cfg_ref = LMConfig(d_model=512, zebra_backend="reference",
                   zebra_sites=("ffn_hidden", "layer_out"))
def ffn_deg(y):
    with comm_context("model", 4):
        yf, sa = ffn_layer_out_exchange(y, cfg_ref, "infer")
    return yf, jnp.int32("dense-comms(comms-capability)" in sa.backend)
yd, lbl = jax.jit(coll.shard_map_compat(
    ffn_deg, mesh, in_specs=(P(None, "model", None),),
    out_specs=(P(), P())))(Y)
out["ffn_degrade"] = {"labeled": bool(int(lbl)),
                      "same_shape": list(yd.shape) == list(yf.shape)}

# KV gather
zc_kv = ZebraConfig(enabled=False, backend="stream")
kv = jnp.asarray(rng.standard_normal((B, 4 * S, 4, 128)).astype(np.float32))
def kv_ex(k, v):
    with comm_context("model", 4):
        kf, vf, auxes = gather_kv_shards(k, v, zc_kv)
    return kf, vf, lax.psum(
        jnp.asarray(auxes[0].ici_bytes).astype(jnp.int32), "model")
kf, vf, moved = jax.jit(coll.shard_map_compat(
    kv_ex, mesh, in_specs=(P(None, "model", None, None),) * 2,
    out_specs=(P(), P(), P())))(kv, kv + 1)
out["kv"] = {"k_parity": bool((np.asarray(kf) == np.asarray(kv)).all()),
             "v_parity": bool((np.asarray(vf) == np.asarray(kv + 1)).all()),
             "moved": int(moved)}

print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_collectives_on_8_devices():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)),
                       timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][0]
    out = json.loads(line[len("RESULT "):])

    for tag in ("zf64", "zf90", "dead"):
        assert out[tag]["parity"], tag
        assert out[tag]["moved"] == out[tag]["pred"], (tag, out[tag])
    # compressed beats dense at the paper's operating point
    assert out["zf64"]["moved"] < out["zf64"]["dense"]

    assert out["psum"]["parity"]
    assert out["psum"]["moved"] == out["psum"]["pred"]
    assert out["rs"]["parity"]
    assert out["rs"]["moved"] == out["rs"]["pred"]

    # the shared exact-byte psum stays exact past int32 totals
    assert out["psum_bytes"]["total"] == out["psum_bytes"]["pred"]
    assert out["psum_bytes"]["total"] > 2 ** 31

    assert out["ffn"]["parity"] and out["ffn"]["compressed"]
    assert out["ffn"]["moved"] > 0
    assert out["ffn_degrade"]["labeled"] and out["ffn_degrade"]["same_shape"]
    assert out["kv"]["k_parity"] and out["kv"]["v_parity"]
    assert out["kv"]["moved"] > 0
