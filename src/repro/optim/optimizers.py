"""Optimizers (pytree-functional, shardable: state mirrors param sharding).

The paper trains with "standard SGD optimizer with learning rate step decay
from 0.1 to 0.001" + weight decay; the LM side uses AdamW.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from ..utils import PyTree, global_norm


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[..., tuple[PyTree, PyTree]]   # (grads, state, params, step)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def clip_by_global_norm(grads: PyTree, max_norm: float) -> tuple[PyTree, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


def sgd(lr_fn: Callable[[jax.Array], jax.Array], momentum: float = 0.9,
        weight_decay: float = 5e-4, nesterov: bool = False) -> Optimizer:
    def init(params):
        return {"mu": jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(grads, state, params, step):
        lr = lr_fn(step)

        def upd(g, mu, p):
            g = g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
            mu_n = momentum * mu + g
            d = (g + momentum * mu_n) if nesterov else mu_n
            return -lr * d, mu_n

        flat = jax.tree_util.tree_map(upd, grads, state["mu"], params)
        updates = jax.tree_util.tree_map(lambda t: t[0], flat,
                                         is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree_util.tree_map(lambda t: t[1], flat,
                                    is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"mu": mu}

    return Optimizer(init, update)


def adamw(lr_fn: Callable[[jax.Array], jax.Array], b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"m": jax.tree_util.tree_map(z, params),
                "v": jax.tree_util.tree_map(z, params)}

    def update(grads, state, params, step):
        lr = lr_fn(step)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - jnp.power(b1, t)
        c2 = 1.0 - jnp.power(b2, t)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m_n = b1 * m + (1 - b1) * g
            v_n = b2 * v + (1 - b2) * jnp.square(g)
            upd = m_n / c1 / (jnp.sqrt(v_n / c2) + eps)
            return -lr * (upd + weight_decay * p.astype(jnp.float32)), m_n, v_n

        flat = jax.tree_util.tree_map(upd, grads, state["m"], state["v"], params)
        pick = lambda i: jax.tree_util.tree_map(
            lambda t: t[i], flat, is_leaf=lambda x: isinstance(x, tuple))
        return pick(0), {"m": pick(1), "v": pick(2)}

    return Optimizer(init, update)
