"""Gradient compression for cross-pod all-reduce (distributed-optimization
trick, DESIGN.md §5).

Two modes:
  * bf16: cast gradients to bfloat16 before the all-reduce (2x wire bytes),
    accumulate in fp32 — the standard large-scale trick; error-free enough
    in practice and stateless.
  * int8 + error feedback: per-tensor max-abs scaling to int8 (4x), with the
    quantization residual carried to the next step (1-bit-Adam-style error
    feedback) so the compression bias vanishes over time.

Use: wrap the grads *before* jax.lax.pmean / psum / the implicit jit
all-reduce; under jit+NamedSharding the cast shrinks the reduce-scatter /
all-gather payload the SPMD partitioner emits.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..utils import PyTree


class CompressionState(NamedTuple):
    error: PyTree | None     # residual carried between steps (int8 mode)


def init_state(params: PyTree, mode: str = "bf16") -> CompressionState:
    if mode == "int8":
        err = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return CompressionState(error=err)
    return CompressionState(error=None)


def compressed_gradients(grads: PyTree, state: CompressionState, mode: str = "bf16"
                         ) -> tuple[PyTree, CompressionState]:
    """Returns (wire-format grads decoded back to fp32, new state).

    The encode->decode round trip is applied *before* the collective so the
    collective payload is the compressed dtype; XLA moves the converts
    across the all-reduce when profitable.
    """
    if mode == "none":
        return grads, state
    if mode == "bf16":
        dec = jax.tree_util.tree_map(
            lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads)
        return dec, state

    if mode == "int8":
        def enc_dec(g, e):
            g = g.astype(jnp.float32) + e            # add carried residual
            scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
            q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
            dec = q.astype(jnp.float32) * scale
            return dec, g - dec                       # new residual
        flat = jax.tree_util.tree_map(enc_dec, grads, state.error)
        dec = jax.tree_util.tree_map(lambda t: t[0], flat,
                                     is_leaf=lambda x: isinstance(x, tuple))
        err = jax.tree_util.tree_map(lambda t: t[1], flat,
                                     is_leaf=lambda x: isinstance(x, tuple))
        return dec, CompressionState(error=err)
    raise ValueError(f"unknown compression mode {mode!r}")
