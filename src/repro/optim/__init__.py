from .optimizers import (  # noqa: F401
    Optimizer,
    sgd,
    adamw,
    apply_updates,
    clip_by_global_norm,
)
from .schedule import step_decay, cosine, warmup_cosine, constant  # noqa: F401
from .compress import compressed_gradients, CompressionState  # noqa: F401
