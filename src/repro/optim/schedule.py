"""LR schedules. Paper: "standard SGD with learning rate step decay from
0.1 to 0.001"."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.float32(lr)


def step_decay(base_lr: float = 0.1, boundaries=(0.5, 0.75), total_steps: int = 1000,
               factor: float = 0.1):
    """0.1 -> 0.01 -> 0.001 at the given fraction boundaries (paper setting)."""
    bs = [int(b * total_steps) for b in boundaries]

    def fn(step):
        lr = jnp.float32(base_lr)
        for b in bs:
            lr = jnp.where(step >= b, lr * factor, lr)
        return lr
    return fn


def cosine(base_lr: float, total_steps: int, min_frac: float = 0.0):
    def fn(step):
        t = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        return jnp.float32(base_lr) * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return fn


def warmup_cosine(base_lr: float, warmup: int, total_steps: int, min_frac: float = 0.1):
    cos = cosine(base_lr, max(total_steps - warmup, 1), min_frac)

    def fn(step):
        w = jnp.float32(base_lr) * jnp.clip(step / max(warmup, 1), 0.0, 1.0)
        return jnp.where(step < warmup, w, cos(step - warmup))
    return fn
