"""The static prefetch schedule of the Zebra consumers — consumer-order
slot maps built ONCE from the bitmap's prefix sums, shared by the
producer (payload emission order), the expander and both GEMM consumers.

Payload order contract (the "GEMM-consumable supertile order"): payload
slots are grouped by K-block **column**, columns ascending, live blocks
ascending by block row within each column, all live slots contiguous in
``[0, n_live)``, zero tail after. Formally, with ``keep`` the (nm, nk)
bitmap::

    counts[k]  = sum_r keep[r, k]            live blocks in column k
    offsets[k] = sum_{k' < k} counts[k']     column k's first payload slot
    slot[r, k] = offsets[k] + |{r' < r : keep[r', k]}|

Why this order wins: a GEMM consumer walks the K dimension column by
column — every ``(bs, bc)`` block in payload column-run ``k`` multiplies
the SAME ``(bc, N)`` weight panel ``w[k*bc:(k+1)*bc]``. Column-grouped
slots make each column's operand one contiguous payload range
(``offsets[k] : offsets[k] + counts[k]``), so the hot path does **zero
dynamic-window gathers**: the fetch plan below (``rows``) is computed
once from the prefix sums before the GEMM, not per supertile step. The
old row-major live-first order forced the consumer to re-derive a
revolving-door fetch window per (supertile, K-step) — that per-step
address generation is exactly what cost more than the skipped FLOPs
(``speedup_vs_ref 0.14`` in the pre-fix trajectory).

``stream_bytes`` is unchanged by the reorder: the stream length depends
only on ``n_live`` (payload slots) + the 1-bit/block index, never on
slot order — pinned by tests/test_mask_pack.py.

Scheduled consume (the interpret/XLA realization of the consumer
contract): per column the live blocks are compacted to a static
**capacity** ``cap >= max(counts)`` chosen from the cached autotuning
chooser's ladder (``kernels.supertile.gemm_plan``), giving a dense
``(nk, cap*bs, bc) x (nk, bc, N)`` batched GEMM over ~``n_live/ (nk *
cap)`` of the dense work; the output rows are assembled with a one-hot
**selection matmul** instead of a scatter-add (XLA CPU scatters run at
~4 GB/s; the equivalent tiny GEMM is ~2x faster). The runtime capacity
picks a ladder branch via ``lax.switch`` — only the selected branch
executes. Both consumers feed the literal same ``_consume_at_cap`` with
identical gated operands, so ``zebra_spmm == zebra_spmm_cs`` stays
bitwise by construction.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class PrefetchSchedule(NamedTuple):
    """The static prefetch schedule: every array is a pure function of the
    bitmap's prefix sums, built once per consumer call (and CSE'd with
    the producer's identical scan when both live in one jit).

    keep     (nm, nk) int32 keep flags
    counts   (nk,)    live blocks per K-block column
    offsets  (nk,)    exclusive prefix sum of counts — column k's slot run
                      starts at offsets[k]
    slot     (nm, nk) block -> payload slot (consumer order)
    rows     (nk, nm) fetch plan: rows[k, i] = block row of the i-th live
                      block in column k; ``nm`` pads past counts[k]
    """
    keep: jax.Array
    counts: jax.Array
    offsets: jax.Array
    slot: jax.Array
    rows: jax.Array


def consumer_schedule(bitmap: jax.Array) -> PrefetchSchedule:
    """Build the static prefetch schedule from the bitmap prefix sums."""
    nm, nk = bitmap.shape
    keep = bitmap.astype(jnp.int32)
    counts = keep.sum(axis=0)
    offsets = (jnp.cumsum(counts) - counts).astype(jnp.int32)
    colrank = (jnp.cumsum(keep, axis=0) - keep).astype(jnp.int32)
    slot = offsets[None, :] + colrank
    kk = jnp.broadcast_to(jnp.arange(nk, dtype=jnp.int32)[None, :], (nm, nk))
    rr = jnp.broadcast_to(jnp.arange(nm, dtype=jnp.int32)[:, None], (nm, nk))
    # scatter each live block's row into its column rank; dead blocks aim
    # at column nm and are dropped — the pad value stays nm
    ctgt = jnp.where(keep != 0, colrank, nm)
    rows = jnp.full((nk, nm), nm, jnp.int32).at[
        kk.reshape(-1), ctgt.reshape(-1)].set(rr.reshape(-1), mode="drop")
    return PrefetchSchedule(keep=keep, counts=counts.astype(jnp.int32),
                            offsets=offsets, slot=slot, rows=rows)


def slot_map(bitmap: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Flat (row-major block index g = r*nk + k) keep flags and the
    consumer-order block -> payload-slot map — the address form the
    Pallas kernel realizations scalar-prefetch (pack / unpack /
    payload-window GEMM all index their windows through this ONE map).

    A dead block's slot aliases the next live slot of its column (its
    exclusive column rank), which keeps the TPU pack kernel's
    "live write wins" revolving-door rule intact under the k-outer grid
    order and keeps every value <= n_live <= nb - 1 whenever a dead
    block exists."""
    sched = consumer_schedule(bitmap)
    return (sched.keep.reshape(-1), sched.slot.reshape(-1).astype(jnp.int32))


# ---------------------------------------------------------------------------
# Scheduled consume — the XLA realization of the consumer contract
# ---------------------------------------------------------------------------

def _consume_at_cap(A: jax.Array, rows_c: jax.Array, w: jax.Array,
                    nm: int, bs: int) -> jax.Array:
    """THE scheduled GEMM core shared by both consumers: A (nk, cap, bs,
    bc) is the compacted, keep-gated operand (invalid slots exact +0);
    rows_c (nk, cap) its fetch plan (pad nm). Batched per-column panel
    GEMM, then one-hot selection-matmul assembly of the output rows."""
    nk, cap, _, bc = A.shape
    N = w.shape[1]
    part = jax.lax.dot_general(
        A.reshape(nk, cap * bs, bc), w.reshape(nk, bc, N),
        (((2,), (1,)), ((0,), (0,))), preferred_element_type=jnp.float32)
    # selection matmul: P[s, r] = 1 iff compacted slot s holds block row r;
    # pad rows target column nm of the (nm + 1)-wide one-hot and are
    # sliced away — no scatter-add on the hot path
    P = jnp.zeros((nk * cap, nm + 1), jnp.float32).at[
        jnp.arange(nk * cap), rows_c.reshape(-1)].set(1.0, mode="drop")
    y = jax.lax.dot_general(P[:, :nm], part.reshape(nk * cap, bs * N),
                            (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    return y.reshape(nm * bs, N)


def _gather_from_x(x: jax.Array, sched: PrefetchSchedule, cap: int,
                   nm: int, nk: int, bs: int, bc: int) -> tuple:
    """Compact the live blocks straight from the dense operand: only the
    fetch plan's live block rows are ever read, so dead-block values
    (raw, unmasked x) cannot leak."""
    rows_c = sched.rows[:, :cap]
    valid = rows_c < nm
    rsafe = jnp.where(valid, rows_c, 0)
    x4 = x.reshape(nm, bs, nk, bc)
    kcol = jnp.arange(nk, dtype=jnp.int32)[:, None]
    A = x4[rsafe, :, kcol, :]                        # (nk, cap, bs, bc)
    A = jnp.where(valid[:, :, None, None], A, jnp.zeros((), x.dtype))
    return A, rows_c


def _gather_from_payload(payload: jax.Array, sched: PrefetchSchedule,
                         cap: int, nm: int, nk: int) -> tuple:
    """Compact from the consumer-ordered payload: column k's operand is
    the contiguous slot run offsets[k] : offsets[k] + counts[k] — the
    zero-dynamic-gather property the payload order exists for."""
    rows_c = sched.rows[:, :cap]
    valid = rows_c < nm
    slots = sched.offsets[:, None] + jnp.arange(cap, dtype=jnp.int32)[None, :]
    A = payload[jnp.where(valid, slots, 0)]          # (nk, cap, bs, bc)
    A = jnp.where(valid[:, :, None, None], A, jnp.zeros((), payload.dtype))
    return A, rows_c


def scheduled_consume(operand: jax.Array, w: jax.Array,
                      sched: PrefetchSchedule, caps: tuple[int, ...], *,
                      from_payload: bool, nm: int, nk: int, bs: int, bc: int
                      ) -> jax.Array:
    """Run the scheduled GEMM at the smallest ladder capacity covering
    ``max(counts)`` — a ``lax.switch`` over the chooser's capacity
    ladder; XLA executes only the selected branch, so the work scales
    with the realized sparsity while shapes stay static."""
    caps = tuple(min(int(c), nm) for c in caps)
    if not caps or caps[-1] != nm:
        caps = tuple(c for c in caps if c < nm) + (nm,)

    gather = (_gather_from_payload if from_payload else
              functools.partial(_gather_from_x, bs=bs, bc=bc))

    def branch(cap: int) -> Callable:
        def run(op, ws, sc):
            A, rows_c = gather(op, sc, cap, nm, nk)
            return _consume_at_cap(A, rows_c, ws, nm, bs)
        return run

    if len(caps) == 1:
        return branch(caps[0])(operand, w, sched)
    idx = jnp.searchsorted(jnp.asarray(caps, jnp.int32),
                           jnp.max(sched.counts))
    return jax.lax.switch(idx, [branch(c) for c in caps],
                          operand, w, sched)
