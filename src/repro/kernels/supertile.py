"""Supertile choosers — grid coarseness policy for the Zebra kernel layer,
plus the cached autotuning GEMM plan chooser (``gemm_plan``).

The fast path lives or dies on *grid coarseness*: a Pallas grid that
steps one ``(8, 128)`` Zebra block at a time pays the per-step machinery
(index-map evaluation, window DMA, accumulator revisit) once per block,
which is exactly the regime where the compressed path loses to the dense
matmul it is supposed to beat. Every kernel in this package therefore
works on **supertiles** — ``(stm, stk)`` windows spanning an integer
number of Zebra blocks — and this module is the one place the supertile
shapes are chosen, so the dense-input GEMM (``zebra_spmm``), the
compressed-stream GEMM (``zebra_spmm_cs``) and the payload expander
(``zebra_unpack``) can never disagree about tiling (their bitwise parity
depends on identical accumulation partitioning).

Policy:

* supertile sides are block-aligned **divisors** of the map sides, so
  grids never produce ragged edge windows (a comparator tile may be
  padded by XLA; a payload gather window may not);
* the number of blocks per supertile is capped (``R`` block rows x
  ``C`` block cols) because the compressed consumers fetch one payload
  window *per block* of the supertile — the cap bounds the per-step
  BlockSpec count;
* everything fits ``vmem_budget_bytes`` (``ZebraConfig.tiles_for``
  threads its budget through; standalone kernel calls use
  ``DEFAULT_VMEM_BUDGET``), accounting for the operand windows the
  kernel actually holds per step.

GEMM plans are **cached and sparsity-aware**: ``gemm_plan`` keys on
(shape, dtype size, budget, bucketed zero_frac) and returns both the
Pallas supertile ``(stm, stk, bn)`` (kernel form) and the **capacity
ladder** the scheduled XLA consumers switch over (``kernels.schedule``).
The ladder adapts to the expected sparsity — rungs are inserted around
the expected live-blocks-per-column so the paper's ~64%-zeros operating
point lands on a tight capacity instead of a worst-case one — which is
what replaced the old fixed VMEM-budget-only ``tiles_for`` guess.
"""
from __future__ import annotations

import math
from typing import NamedTuple

DEFAULT_VMEM_BUDGET = 8 * 1024 * 1024   # ~half a 16 MB TPU core

# Per-supertile block caps: R block rows x C block cols. The compressed
# consumers carry R*C payload BlockSpecs per grid step, so R*C is also
# the per-step window count — 32 windows of (8, 128) f32 is 128 KiB.
MAX_ROW_BLOCKS = 4
MAX_COL_BLOCKS = 8

# The parallel pack phase writes W payload slots per grid step, reading
# W independently-addressed (bs, bc) source windows.
MAX_PACK_WINDOW = 16


def validate_supertile(M: int, K: int, bs: int, bc: int, stm: int,
                       stk: int) -> None:
    """Explicit (stm, stk) must be block-aligned divisors of the map —
    the grid computes GM = (M/bs) // R and would silently drop trailing
    output rows/columns otherwise."""
    if stm % bs or stk % bc:
        raise ValueError(f"supertile ({stm},{stk}) must divide by block "
                         f"({bs},{bc})")
    if (M // bs) % (stm // bs) or (K // bc) % (stk // bc):
        raise ValueError(
            f"supertile ({stm},{stk}) must divide the ({M},{K}) map's "
            f"block grid ({M // bs}x{K // bc}) — ragged supertiles would "
            f"leave output windows unwritten")


def largest_divisor(n: int, cap: int) -> int:
    """Largest divisor of ``n`` that is <= cap (>= 1)."""
    for d in range(min(n, cap), 1, -1):
        if n % d == 0:
            return d
    return 1


def _divisors_desc(n: int, cap: int) -> list[int]:
    return [d for d in range(min(n, cap), 0, -1) if n % d == 0]


def comparator_tiles(M: int, K: int, bs: int, bc: int, itemsize: int,
                     budget: int = DEFAULT_VMEM_BUDGET) -> tuple[int, int]:
    """Comparator tile (tm, tk) for the bitmap/masking passes: the pass
    holds an input tile and an output tile in VMEM (2 * tm * tk *
    itemsize; the bitmap tile is negligible), so take the widest
    block-aligned tk that leaves at least one block row in budget, then
    the tallest block-aligned tm that fits — bf16 maps get twice the
    f32 tile. Never below one (bs, bc) block; XLA pads sub-tile maps."""
    budget = max(int(budget), 2 * bs * bc * itemsize)
    tk = max(min(K, (budget // (2 * bs * itemsize) // bc) * bc), bc)
    tm = max(min(M, (budget // (2 * tk * itemsize) // bs) * bs), bs)
    return tm, tk


def gemm_supertiles(M: int, K: int, N: int, bs: int, bc: int,
                    itemsize: int, budget: int = DEFAULT_VMEM_BUDGET
                    ) -> tuple[int, int, int]:
    """GEMM supertile ``(stm, stk, bn)`` for a (M, K) x (K, N) product
    with (bs, bc) Zebra blocks.

    Per grid step the GEMM holds: the activation supertile (stm, stk) —
    dense window or R*C payload windows, same bytes — the weight window
    (stk, bn), the fp32 accumulator and the output window (stm, bn).
    The chooser takes the largest block-count divisors under the caps
    that fit ``budget``, shrinking bn last (it trades grid steps in N,
    not supertile coarseness). Never shrinks below one (bs, bc) block.
    """
    nm, nk = M // bs, K // bc
    floor_bn = min(N, 8)
    bns, b = [], min(256, N)
    while b > floor_bn:
        bns.append(b)
        b //= 2
    bns.append(floor_bn)
    # supertile coarseness first (it is the grid-shrink lever), bn last;
    # the fixed visit order makes the choice monotone in itemsize, so a
    # bf16 map never gets a smaller supertile than the f32 map.
    for R in _divisors_desc(nm, MAX_ROW_BLOCKS):
        for C in _divisors_desc(nk, MAX_COL_BLOCKS):
            for bn in bns:
                stm, stk = R * bs, C * bc
                cost = (stm * stk * itemsize          # activation windows
                        + stk * bn * itemsize         # weight window
                        + stm * bn * 4                # fp32 accumulator
                        + stm * bn * 4)               # fp32 output window
                if cost <= budget:
                    return stm, stk, bn
    return bs, bc, floor_bn


def gather_supertiles(M: int, K: int, bs: int, bc: int, itemsize: int,
                      budget: int = DEFAULT_VMEM_BUDGET) -> tuple[int, int]:
    """Supertile ``(stm, stk)`` for the payload expander (zebra_unpack):
    per step it holds R*C payload windows plus the dense (stm, stk)
    output window. Never shrinks below one block."""
    nm, nk = M // bs, K // bc
    for R in _divisors_desc(nm, MAX_ROW_BLOCKS):
        for C in _divisors_desc(nk, MAX_COL_BLOCKS):
            stm, stk = R * bs, C * bc
            if 2 * stm * stk * itemsize <= budget:
                return stm, stk
    return bs, bc


def pack_window(n_blocks: int, bs: int = 8, bc: int = 128,
                itemsize: int = 4, budget: int = DEFAULT_VMEM_BUDGET) -> int:
    """Payload slots written per grid step by the parallel pack phase —
    the largest divisor of the block count (a divisor so the slot
    windows tile the payload exactly) under both the window cap and the
    VMEM budget: each step holds W (bs, bc) source windows plus the
    (W, bs, bc) output window, 2*W*bs*bc*itemsize bytes."""
    cap = min(MAX_PACK_WINDOW,
              max(int(budget) // (2 * bs * bc * itemsize), 1))
    return largest_divisor(max(n_blocks, 1), cap)


# ---------------------------------------------------------------------------
# The cached autotuning GEMM plan chooser
# ---------------------------------------------------------------------------

class GemmPlan(NamedTuple):
    """One GEMM consumer plan: the Pallas supertile (kernel form) plus
    the capacity ladder of the scheduled XLA form (``kernels.schedule``).
    Hashable/static — safe to thread through jit static args."""
    stm: int
    stk: int
    bn: int
    caps: tuple[int, ...]


_PLAN_CACHE: dict[tuple, GemmPlan] = {}
_PLAN_STATS = {"hits": 0, "misses": 0}

# zero_frac cache granularity: hints within the same 1/16 bucket share a
# plan, so a jittering runtime estimate cannot blow the cache up
_ZF_BUCKETS = 16


def _zf_bucket(zero_frac: float | None) -> int | None:
    if zero_frac is None:
        return None
    return round(min(max(float(zero_frac), 0.0), 1.0) * _ZF_BUCKETS)


def capacity_ladder(nm: int, zero_frac: float | None = None
                    ) -> tuple[int, ...]:
    """Per-column capacity ladder for the scheduled consumers: quantized
    fractions of the block-row count, always ending at ``nm`` (the
    all-live fallback rung). With a sparsity hint, finer rungs are
    inserted just above the expected live blocks per column — the
    autotuning part: at the paper's ~64% zeros a 32-row map gets rungs
    at 12/14/16 instead of jumping straight to 16."""
    fracs = (0.25, 0.3125, 0.375, 0.4375, 0.5, 0.625, 0.75, 1.0)
    caps = {max(1, math.ceil(f * nm)) for f in fracs}
    if zero_frac is not None:
        expected = (1.0 - min(max(float(zero_frac), 0.0), 1.0)) * nm
        step = max(1, nm // _ZF_BUCKETS)
        for d in (0, 1, 2):
            caps.add(min(nm, max(1, math.ceil(expected) + d * step)))
    caps.add(nm)
    return tuple(sorted(c for c in caps if c <= nm))


def gemm_plan(M: int, K: int, N: int, bs: int, bc: int, itemsize: int,
              budget: int = DEFAULT_VMEM_BUDGET,
              zero_frac: float | None = None) -> GemmPlan:
    """The ONE cached GEMM plan chooser. Keyed on (shape, blocks, dtype
    size, budget, bucketed zero_frac): repeated launches of the same
    site shape hit the cache, and a sparsity hint tightens the capacity
    ladder without changing the Pallas supertile (so kernel-form
    numerics never depend on the hint)."""
    key = (M, K, N, bs, bc, itemsize, int(budget), _zf_bucket(zero_frac))
    plan = _PLAN_CACHE.get(key)
    if plan is not None:
        _PLAN_STATS["hits"] += 1
        return plan
    _PLAN_STATS["misses"] += 1
    stm, stk, bn = gemm_supertiles(M, K, N, bs, bc, itemsize, int(budget))
    plan = GemmPlan(stm=stm, stk=stk, bn=bn,
                    caps=capacity_ladder(M // bs, zero_frac))
    _PLAN_CACHE[key] = plan
    return plan


def plan_cache_info() -> dict[str, int]:
    """(hits, misses, size) of the plan cache — the chooser-cache test
    and benches read this."""
    return {"hits": _PLAN_STATS["hits"], "misses": _PLAN_STATS["misses"],
            "size": len(_PLAN_CACHE)}


def plan_cache_clear() -> None:
    _PLAN_CACHE.clear()
    _PLAN_STATS["hits"] = _PLAN_STATS["misses"] = 0
