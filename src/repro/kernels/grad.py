"""Training semantics for the Pallas Zebra kernels (``jax.custom_vjp``).

The paper trains the block mask and then reaps the bandwidth win at
inference; dynamic feature-map pruning (Liang et al. 2018) and
zero-activation prediction (Shomron et al. 2019) both show the
train-time gating function must match the deployed masking *exactly*.
``zebra_kernel_trainable`` makes that possible on the kernel backends:
the forward is the existing kernel launch (``zebra_mask`` for the
pallas backend, the ``zebra_mask_pack -> zebra_unpack`` stream pair for
the stream backend — the deployed comparator, bit for bit), and the
backward implements the constant-threshold gradient modes of
``core.zebra._apply_gate``:

``hard``  (paper)  dx = g · broadcast(bitmap) — the mask is a 0/1 gate
                   under stop_gradient; only surviving blocks carry the
                   task gradient.
``ste``            dx = g — straight-through identity, so pruned blocks
                   can recover.
``soft``           dx = g · broadcast(sigmoid((blockmax − T_obj)/τ)) —
                   the backward is rescaled by the sigmoid surrogate
                   while the value stays the deployed hard mask.

All three are numerically equal to the reference (pure-jnp) backend in
constant-threshold train mode, so ``jax.grad`` through a pallas/stream
site matches reference bitwise in f32. Sites with a threshold net
(per-sample learned thresholds) are *not* kernel-trainable — the engine
resolves them to reference via the capability registry
(``core.backends``).

Payload order: the stream variant's forward emits and re-expands the
payload in the consumer order of ``kernels.schedule`` (column-grouped
slots). The pipeline here is order-transparent — pack and unpack
address the stream through the same ``slot_map``, so the round trip
(and therefore every gradient mode) is unchanged by the reorder.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .mask_pack import zebra_mask_pack
from .pack import zebra_unpack
from .zebra_mask import zebra_mask


class KernelStatics(NamedTuple):
    """Hashable static config for one trainable kernel launch.

    ``variant`` picks the forward: ``"mask"`` (one comparator launch,
    dense masked map out) or ``"stream"`` (the two-phase parallel
    ``zebra_mask_pack`` producer -> ``zebra_unpack``, only the
    compressed stream in between). ``(tm, tk)`` is the comparator
    supertile and ``(gtm, gtk)`` the expander's gather supertile, both
    from ``ZebraConfig.tiles_for`` — every pass tiles under the config
    budget, so no map is ever too big for the producer (the old
    whole-payload-resident design needed a ``fits_vmem`` degrade; the
    two-phase producer does not).
    """
    variant: str
    t_obj: float
    bs: int
    bc: int
    tm: int
    tk: int
    gtm: int
    gtk: int
    pw: int                     # pack-pass slot window (budget-capped)
    grad_mode: str
    soft_temp: float
    interpret: bool


def _expand2d(blocks: jax.Array, bs: int, bc: int) -> jax.Array:
    """(Mb, Kb) per-block values -> (M, K) elementwise broadcast."""
    return jnp.repeat(jnp.repeat(blocks, bs, axis=0), bc, axis=1)


def _mask_forward(x2: jax.Array, s: KernelStatics):
    y2, bitmap = zebra_mask(x2, t_obj=s.t_obj, bs=s.bs, bc=s.bc,
                            tm=s.tm, tk=s.tk, interpret=s.interpret)
    return y2, bitmap, jnp.int32(0)


def _stream_forward(x2: jax.Array, s: KernelStatics):
    payload, bitmap, n_live = zebra_mask_pack(
        x2, t_obj=s.t_obj, bs=s.bs, bc=s.bc, tm=s.tm, tk=s.tk,
        window=s.pw, interpret=s.interpret)
    y2 = zebra_unpack(payload, bitmap, bs=s.bs, bc=s.bc, stm=s.gtm,
                      stk=s.gtk, interpret=s.interpret)
    return y2, bitmap, n_live


_FORWARD_VARIANTS = {"mask": _mask_forward, "stream": _stream_forward}


def register_forward_variant(name: str, fn) -> None:
    """Add a forward pipeline for a new trainable backend: ``fn(x2,
    statics) -> (y2, bitmap, n_live)``. The backend's BackendSpec names it
    via ``grad_variant``; the custom_vjp backward (gradient modes) is
    shared."""
    _FORWARD_VARIANTS[name] = fn


def has_forward_variant(name: str) -> bool:
    return name in _FORWARD_VARIANTS


def launch_forward(x2: jax.Array, s: KernelStatics):
    """The ONE forward kernel pipeline shared by train (custom_vjp fwd)
    and infer (engine dispatch) — train and infer cannot drift apart.
    Returns (y2, bitmap, n_live); n_live is 0 for the mask variant."""
    try:
        fwd = _FORWARD_VARIANTS[s.variant]
    except KeyError:
        raise ValueError(
            f"unknown trainable kernel variant {s.variant!r}; expected one "
            f"of {tuple(_FORWARD_VARIANTS)} (register_forward_variant)"
        ) from None
    return fwd(x2, s)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def zebra_kernel_trainable(x2: jax.Array, statics: KernelStatics):
    """Kernel-launched Zebra site with training semantics.

    x2 (M, K) -> (masked y2 (M, K), keep bitmap int8, n_live int32).
    Forward is the real kernel dispatch; ``jax.grad`` takes the
    ``statics.grad_mode`` backward (see module docstring). The bitmap
    and n_live outputs are non-differentiable observables.
    """
    return launch_forward(x2, statics)


def _fwd(x2, statics):
    out = launch_forward(x2, statics)
    if statics.grad_mode == "soft":
        res = x2                       # recompute blockmax for the surrogate
    elif statics.grad_mode == "ste":
        res = None
    else:                              # hard (paper default)
        res = out[1]
    return out, res


def _bwd(statics, res, cts):
    gy = cts[0]
    if statics.grad_mode == "ste":
        return (gy,)
    if statics.grad_mode == "soft":
        x2 = res
        M, K = x2.shape
        xb = x2.reshape(M // statics.bs, statics.bs,
                        K // statics.bc, statics.bc)
        blockmax = jnp.max(jnp.abs(xb), axis=(1, 3))
        thr = jnp.asarray(statics.t_obj, blockmax.dtype)
        gate = jax.nn.sigmoid((blockmax - thr) / statics.soft_temp)
        return (gy * _expand2d(gate, statics.bs, statics.bc).astype(gy.dtype),)
    mask = _expand2d(res, statics.bs, statics.bc).astype(gy.dtype)
    return (gy * mask,)


zebra_kernel_trainable.defvjp(_fwd, _bwd)
