"""Pallas TPU kernel: supertiled GEMM over the *compressed* Zebra stream.

``zebra_spmm_cs`` computes ``y = mask(x) @ w`` from the ``(payload,
bitmap)`` stream that ``zebra_mask_pack`` produced. The payload follows
the consumer order of ``kernels.schedule`` (column-grouped), so each K
column's operand is ONE contiguous slot run — no dynamic-window gathers
on the hot path. The consumer has three executable realizations of the
one contract:

* **scheduled form** (``scheduled=True``; the default when
  ``interpret=True``): the static prefetch schedule slices each
  column's contiguous slot run at a ladder capacity from the cached
  ``supertile.gemm_plan`` chooser and runs the batched panel GEMM +
  selection-matmul assembly of ``kernels.schedule`` — the realization
  that beats the dense matmul at the paper's operating point. It is
  bitwise-equal to ``zebra_spmm``'s scheduled form by construction:
  both feed the literal same ``_consume_at_cap`` with identical gated
  operands (live block values are untouched by masking, so compacting
  from the payload and from the dense map give the same arrays).
* **TPU form** (``payload_windows=True``; default when
  ``interpret=False``): the grid steps over ``(stm, stk)`` supertiles
  and every ``(bs, bc)`` block of the supertile is fetched straight
  from its consumer-order payload slot through its own
  scalar-prefetch-indexed BlockSpec — ``R·C`` windows per step. A dead
  block's window replays the prefix-sum slot (the in-bounds
  revolving-door re-use) and is zero-gated in-kernel. Accumulation
  order, supertile shapes and the in-kernel panel assembly are
  *identical* to ``zebra_spmm``'s kernel form (shared
  ``gemm_supertile_body``), so the two kernel forms are bitwise-equal.
* **expand form** (``scheduled=False, payload_windows=False``): the
  slot map drives one XLA blocked gather that expands the payload back
  to the dense operand, which then feeds the same supertiled Pallas
  GEMM — kept as the bitwise cross-check of the TPU form on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..utils import cdiv
from .schedule import consumer_schedule, scheduled_consume
from .supertile import gemm_plan, validate_supertile
from .zebra_spmm import (gemm_supertile_body, launch_supertile_gemm,
                         seg_live)


def _spmm_cs_kernel(smap_ref, keep_ref, seg_ref, *refs, R: int, C: int,
                    bs: int, bc: int, nk: int, GK: int):
    """Payload-window flavor: blocks come from the R*C dynamically
    slotted payload windows; the step itself IS gemm_supertile_body, so
    the bitwise parity with zebra_spmm is structural, not copy-pasted."""
    del smap_ref                        # consumed by the BlockSpec index maps
    p_refs, w_ref, y_ref, acc_ref = \
        refs[:R * C], refs[R * C], refs[R * C + 1], refs[R * C + 2]
    gemm_supertile_body(
        keep_ref, seg_ref,
        lambda r, j: p_refs[r * C + j][...][0],
        w_ref, y_ref, acc_ref, R=R, C=C, bc=bc, nk=nk, GK=GK)


def _payload_window_launch(payload, w, keep, smap, *, bs, bc, stm, stk, bn,
                           nm, nk, interpret):
    """The payload-direct TPU form: R*C dynamically-slotted payload
    windows per supertile step."""
    K, N = w.shape
    R, C = stm // bs, stk // bc
    GM, GN, GK = nm // R, cdiv(N, bn), nk // C
    # only seg: the payload form addresses its fetches through smap, so
    # the dense form's revolving-door kmap would be computed then thrown
    # away here
    seg = seg_live(keep, nm, nk, R, C).reshape(-1).astype(jnp.int32)

    def _p_idx(i, jn, kc, smap, keep, seg, *, r, j):
        return (smap[(i * R + r) * nk + kc * C + j], 0, 0)

    kernel = functools.partial(_spmm_cs_kernel, R=R, C=C, bs=bs, bc=bc,
                               nk=nk, GK=GK)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(GM, GN, GK),
            in_specs=[pl.BlockSpec((1, bs, bc),
                                   functools.partial(_p_idx, r=r, j=j))
                      for r in range(R) for j in range(C)] +
                     [pl.BlockSpec((stk, bn),
                                   lambda i, jn, kc, smap, keep, seg:
                                   (kc, jn))],
            out_specs=pl.BlockSpec(
                (stm, bn), lambda i, jn, kc, smap, keep, seg: (i, jn)),
            scratch_shapes=[pltpu.VMEM((stm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((nm * bs, N), jnp.float32),
        interpret=interpret,
    )(smap, keep, seg, *([payload] * (R * C)), w)


@functools.partial(jax.jit, static_argnames=("bs", "bc", "bn", "stm", "stk",
                                             "caps", "zero_frac_hint",
                                             "scheduled", "payload_windows",
                                             "interpret"))
def zebra_spmm_cs(payload: jax.Array, w: jax.Array, bitmap: jax.Array, *,
                  bs: int = 8, bc: int = 128, bn: int | None = None,
                  stm: int | None = None, stk: int | None = None,
                  caps: tuple[int, ...] | None = None,
                  zero_frac_hint: float | None = None,
                  scheduled: bool | None = None,
                  payload_windows: bool | None = None,
                  interpret: bool = True) -> jax.Array:
    """(n_blocks, bs, bc) payload x (K, N) weight -> (M, N) fp32.

    ``bitmap`` is the (M//bs, K//bc) keep map; payload slots follow the
    consumer order of ``kernels.schedule`` (``zebra_mask_pack``'s
    emission order). Plans default from the same cached chooser as
    ``zebra_spmm`` — the two must tile alike for their bitwise parity
    to hold. ``scheduled=None`` picks the scheduled XLA form iff
    ``interpret``; ``payload_windows`` selects between the two Pallas
    kernel-form realizations when ``scheduled`` is off.
    """
    nm, nk = bitmap.shape
    K, N = w.shape
    if K != nk * bc:
        raise ValueError(f"w rows {K} != bitmap cols {nk} * bc {bc}")
    if payload.shape != (nm * nk, bs, bc):
        raise ValueError(f"payload {payload.shape} != ({nm * nk}, {bs}, {bc})")
    M = nm * bs
    plan = gemm_plan(M, K, N, bs, bc, jnp.dtype(payload.dtype).itemsize,
                     zero_frac=zero_frac_hint)
    stm, stk, bn = stm or plan.stm, stk or plan.stk, min(bn or plan.bn, N)
    validate_supertile(M, K, bs, bc, stm, stk)
    if scheduled is None:
        # explicit payload_windows (either value) asks for a kernel-form
        # realization; otherwise interpret picks the scheduled XLA form
        scheduled = interpret and payload_windows is None
    if scheduled:
        sched = consumer_schedule(bitmap)
        return scheduled_consume(payload, w, sched, caps or plan.caps,
                                 from_payload=True, nm=nm, nk=nk,
                                 bs=bs, bc=bc)
    if payload_windows is None:
        payload_windows = not interpret
    sched = consumer_schedule(bitmap)
    keep = sched.keep.reshape(-1)
    smap = sched.slot.reshape(-1).astype(jnp.int32)      # block -> slot

    if payload_windows:
        return _payload_window_launch(payload, w, keep, smap, bs=bs, bc=bc,
                                      stm=stm, stk=stk, bn=bn, nm=nm, nk=nk,
                                      interpret=interpret)

    # interpret form: one XLA blocked gather (pack.expand_payload, shared
    # with zebra_unpack) expands the stream back to the dense operand;
    # the supertiled GEMM kernel (shared with zebra_spmm) re-gates every
    # block by keep, so slot-replayed blocks never leak.
    from .pack import expand_payload
    x2 = expand_payload(payload, keep, smap, nm, nk, bs, bc)
    return launch_supertile_gemm(x2, w, keep, bs=bs, bc=bc, stm=stm, stk=stk,
                                 bn=bn, interpret=interpret)
