"""Pallas TPU kernel: supertiled GEMM over the *compressed* Zebra stream.

``zebra_spmm_cs`` computes ``y = mask(x) @ w`` from the ``(payload,
bitmap)`` stream that ``zebra_mask_pack`` produced. The bitmap's
exclusive prefix sum is the block -> payload-slot map; accumulation
order, supertile shapes and the in-kernel panel assembly are *identical*
to ``zebra_spmm`` (the dense-input consumer), so the result is
bitwise-equal to it — which is itself the reference masking + matmul.

Like the producer, the consumer has two executable realizations of the
one contract, selected by ``payload_windows`` (default: the TPU form
when ``interpret=False``):

* **TPU form** (``payload_windows=True``): the grid steps over
  ``(stm, stk)`` supertiles and every ``(bs, bc)`` block of the
  supertile is fetched straight from its compacted payload slot through
  its own scalar-prefetch-indexed BlockSpec — ``R·C`` windows per step.
  A dead block's window replays the prefix-sum slot (the in-bounds
  revolving-door re-use) and is zero-gated in-kernel, so dead K-blocks
  cost no *new* HBM traffic and the dense map is never reconstructed.
* **interpret form** (CPU containers): the same slot map drives one XLA
  blocked gather that expands the payload back to the dense operand,
  which then feeds the *same* supertiled GEMM kernel as ``zebra_spmm``
  with plain aligned windows. Pallas's interpreter charges ~100 us per
  dynamically-indexed window fetch and duplicates multi-spec operands
  in the grid carry, so the gather is the faster realization of the
  identical dataflow on CPU; numerics are unchanged because the kernel
  re-gates every block by its keep flag either way.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..utils import cdiv
from .supertile import gemm_supertiles, validate_supertile
from .zebra_spmm import (gemm_supertile_body, launch_supertile_gemm,
                         seg_live)


def _spmm_cs_kernel(smap_ref, keep_ref, seg_ref, *refs, R: int, C: int,
                    bs: int, bc: int, nk: int, GK: int):
    """Payload-window flavor: blocks come from the R*C dynamically
    slotted payload windows; the step itself IS gemm_supertile_body, so
    the bitwise parity with zebra_spmm is structural, not copy-pasted."""
    del smap_ref                        # consumed by the BlockSpec index maps
    p_refs, w_ref, y_ref, acc_ref = \
        refs[:R * C], refs[R * C], refs[R * C + 1], refs[R * C + 2]
    gemm_supertile_body(
        keep_ref, seg_ref,
        lambda r, j: p_refs[r * C + j][...][0],
        w_ref, y_ref, acc_ref, R=R, C=C, bc=bc, nk=nk, GK=GK)


def _payload_window_launch(payload, w, keep, smap, *, bs, bc, stm, stk, bn,
                           nm, nk, interpret):
    """The payload-direct TPU form: R*C dynamically-slotted payload
    windows per supertile step."""
    K, N = w.shape
    R, C = stm // bs, stk // bc
    GM, GN, GK = nm // R, cdiv(N, bn), nk // C
    # only seg: the payload form addresses its fetches through smap, so
    # the dense form's revolving-door kmap would be computed then thrown
    # away here
    seg = seg_live(keep, nm, nk, R, C).reshape(-1).astype(jnp.int32)

    def _p_idx(i, jn, kc, smap, keep, seg, *, r, j):
        return (smap[(i * R + r) * nk + kc * C + j], 0, 0)

    kernel = functools.partial(_spmm_cs_kernel, R=R, C=C, bs=bs, bc=bc,
                               nk=nk, GK=GK)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(GM, GN, GK),
            in_specs=[pl.BlockSpec((1, bs, bc),
                                   functools.partial(_p_idx, r=r, j=j))
                      for r in range(R) for j in range(C)] +
                     [pl.BlockSpec((stk, bn),
                                   lambda i, jn, kc, smap, keep, seg:
                                   (kc, jn))],
            out_specs=pl.BlockSpec(
                (stm, bn), lambda i, jn, kc, smap, keep, seg: (i, jn)),
            scratch_shapes=[pltpu.VMEM((stm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((nm * bs, N), jnp.float32),
        interpret=interpret,
    )(smap, keep, seg, *([payload] * (R * C)), w)


@functools.partial(jax.jit, static_argnames=("bs", "bc", "bn", "stm", "stk",
                                             "payload_windows", "interpret"))
def zebra_spmm_cs(payload: jax.Array, w: jax.Array, bitmap: jax.Array, *,
                  bs: int = 8, bc: int = 128, bn: int | None = None,
                  stm: int | None = None, stk: int | None = None,
                  payload_windows: bool | None = None,
                  interpret: bool = True) -> jax.Array:
    """(n_blocks, bs, bc) payload x (K, N) weight -> (M, N) fp32.

    ``bitmap`` is the (M//bs, K//bc) keep map; payload slots follow
    ``zebra_mask_pack``'s row-major live-first order. Supertiles default
    to the same chooser as ``zebra_spmm`` — the two must tile alike for
    their bitwise parity to hold.
    """
    nm, nk = bitmap.shape
    K, N = w.shape
    if K != nk * bc:
        raise ValueError(f"w rows {K} != bitmap cols {nk} * bc {bc}")
    if payload.shape != (nm * nk, bs, bc):
        raise ValueError(f"payload {payload.shape} != ({nm * nk}, {bs}, {bc})")
    M = nm * bs
    dstm, dstk, dbn = gemm_supertiles(M, K, N, bs, bc,
                                      jnp.dtype(payload.dtype).itemsize)
    stm, stk, bn = stm or dstm, stk or dstk, min(bn or dbn, N)
    validate_supertile(M, K, bs, bc, stm, stk)
    if payload_windows is None:
        payload_windows = not interpret
    keep = bitmap.reshape(-1).astype(jnp.int32)
    smap = (jnp.cumsum(keep) - keep).astype(jnp.int32)   # block -> slot

    if payload_windows:
        return _payload_window_launch(payload, w, keep, smap, bs=bs, bc=bc,
                                      stm=stm, stk=stk, bn=bn, nm=nm, nk=nk,
                                      interpret=interpret)

    # interpret form: one XLA blocked gather (pack.expand_payload, shared
    # with zebra_unpack) expands the stream back to the dense operand;
    # the supertiled GEMM kernel (shared with zebra_spmm) re-gates every
    # block by keep, so slot-replayed blocks never leak.
    from .pack import expand_payload
    x2 = expand_payload(payload, keep, smap, nm, nk, bs, bc)
    return launch_supertile_gemm(x2, w, keep, bs=bs, bc=bc, stm=stm, stk=stk,
                                 bn=bn, interpret=interpret)
