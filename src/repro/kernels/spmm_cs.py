"""Pallas TPU kernel: GEMM over the *compressed* Zebra stream.

``zebra_spmm_cs`` computes ``y = mask(x) @ w`` reading its activations
straight from the ``(payload, bitmap)`` stream that ``zebra_mask_pack``
produced — the dense masked map is never reconstructed. The bitmap's
exclusive prefix sum (scalar-prefetched in SMEM) is the block -> payload
slot index map, so a live K-block's tile is fetched from its compacted
payload slot and a dead K-block is never fetched at all: the BlockSpec
replays the prefix-sum slot (which for a dead block equals the *next*
live block's slot — an in-bounds revolving-door reuse) and ``pl.when``
drops its contribution.

Accumulation order and tile shapes are identical to ``zebra_spmm`` (K
innermost, fp32 VMEM accumulator, one (bs, bc) activation block per K
step), so the result is bitwise-equal to the dense-input kernel — which
is itself bitwise-equal to ``reference`` masking + dense matmul.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..utils import cdiv


def _spmm_cs_kernel(smap_ref, keep_ref, p_ref, w_ref, y_ref, acc_ref, *,
                    nk: int):
    i, k = pl.program_id(0), pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    live = keep_ref[i * nk + k] != 0

    @pl.when(live)
    def _acc():
        acc_ref[...] += jnp.dot(p_ref[...][0], w_ref[...],
                                preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        y_ref[...] = acc_ref[...].astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bs", "bc", "bn", "interpret"))
def zebra_spmm_cs(payload: jax.Array, w: jax.Array, bitmap: jax.Array, *,
                  bs: int = 8, bc: int = 128, bn: int = 256,
                  interpret: bool = True) -> jax.Array:
    """(n_blocks, bs, bc) payload x (K, N) weight -> (M, N) fp32.

    ``bitmap`` is the (M//bs, K//bc) keep map; payload slots follow
    ``zebra_mask_pack``'s row-major live-first order.
    """
    nm, nk = bitmap.shape
    K, N = w.shape
    if K != nk * bc:
        raise ValueError(f"w rows {K} != bitmap cols {nk} * bc {bc}")
    if payload.shape != (nm * nk, bs, bc):
        raise ValueError(f"payload {payload.shape} != ({nm * nk}, {bs}, {bc})")
    bn = min(bn, N)
    nn = cdiv(N, bn)
    keep = bitmap.reshape(-1).astype(jnp.int32)
    smap = (jnp.cumsum(keep) - keep).astype(jnp.int32)   # block -> slot

    out = pl.pallas_call(
        functools.partial(_spmm_cs_kernel, nk=nk),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(nm, nn, nk),
            in_specs=[
                pl.BlockSpec((1, bs, bc),
                             lambda i, j, k, smap, keep: (smap[i * nk + k], 0, 0)),
                pl.BlockSpec((bc, bn), lambda i, j, k, smap, keep: (k, j)),
            ],
            out_specs=pl.BlockSpec((bs, bn), lambda i, j, k, smap, keep: (i, j)),
            scratch_shapes=[pltpu.VMEM((bs, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((nm * bs, N), jnp.float32),
        interpret=interpret,
    )(smap, keep, payload, w)
    return out
