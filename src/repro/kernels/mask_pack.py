"""Pallas TPU kernel: single-pass Zebra streaming producer.

``zebra_mask_pack`` fuses the comparator (``zebra_mask``) and the payload
compaction (``zebra_pack``) into ONE grid pass over the activation map:
each ``(bs, bc)`` block is loaded into VMEM exactly once, its max is
compared against ``t_obj``, and — if it survives — the block is written
straight into the next payload slot. The dense masked map is *never
materialized*: the only things that leave the kernel are the compressed
``(payload, bitmap, n_live)`` stream, which is exactly what the paper's
accelerator puts on DRAM (Eq. 2/3).

Compaction uses an *online* exclusive prefix sum: the TPU grid is
sequential (row-major, last axis fastest — the same row-major block order
as ``zebra_pack``'s scatter), so a running counter in SMEM scratch is at
every step equal to the exclusive prefix sum of the keep flags that
``pack.py`` scalar-prefetches — without needing the bitmap before launch,
which is what makes the pass single. Dead blocks write nothing; the
payload tail past ``n_live`` is zeroed up front, so the stream is
deterministic and bitwise-identical to ``zebra_pack(zebra_mask(x))``
(live blocks are untouched by masking, so packing *raw* live blocks is
already packing masked ones).

The payload output block is the whole ``(n_blocks, bs, bc)`` buffer with a
constant index map — it stays resident for the entire grid (written back
to HBM once at the end), so the map's worst-case payload must fit in
VMEM. The engine gates dispatch on ``ZebraConfig.vmem_budget_bytes``
(``core.engine._producer_fits_vmem``) and degrades over-budget maps to
the tiled multi-launch pipeline whose comparator tiles come from
``ZebraConfig.tiles_for``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mask_pack_kernel(x_ref, p_ref, bm_ref, nl_ref, count_ref, *,
                      t_obj: float):
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _init():
        count_ref[0] = 0
        p_ref[...] = jnp.zeros_like(p_ref)

    blk = x_ref[...]                                       # (bs, bc)
    live = jnp.max(jnp.abs(blk)) >= jnp.asarray(t_obj, blk.dtype)
    bm_ref[0, 0] = live.astype(jnp.int8)
    slot = count_ref[0]                  # == excl. prefix sum of keep flags

    @pl.when(live)
    def _write():
        p_ref[pl.ds(slot, 1)] = blk[None]
        count_ref[0] = slot + 1

    nl_ref[0] = count_ref[0]


@functools.partial(jax.jit, static_argnames=("t_obj", "bs", "bc", "interpret"))
def zebra_mask_pack(x: jax.Array, *, t_obj: float, bs: int = 8, bc: int = 128,
                    interpret: bool = True
                    ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-pass comparator + compaction over an (M, K) map.

    Returns ``(payload (n_blocks, bs, bc) — live blocks first in row-major
    block order, zero tail; bitmap (M//bs, K//bc) int8; n_live () int32)``.
    Bitwise-identical to ``zebra_pack(*zebra_mask(x))`` in one launch.
    """
    M, K = x.shape
    if M % bs or K % bc:
        raise ValueError(f"(M={M}, K={K}) must divide by block ({bs},{bc})")
    nm, nk = M // bs, K // bc
    nb = nm * nk
    payload, bitmap, n_live = pl.pallas_call(
        functools.partial(_mask_pack_kernel, t_obj=t_obj),
        grid=(nm, nk),
        in_specs=[pl.BlockSpec((bs, bc), lambda i, j: (i, j))],
        out_specs=[
            # whole payload resident across the grid: constant index map,
            # written back once; enables the in-kernel dynamic-slot store.
            pl.BlockSpec((nb, bs, bc), lambda i, j: (0, 0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, bs, bc), x.dtype),
            jax.ShapeDtypeStruct((nm, nk), jnp.int8),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(x)
    return payload, bitmap, n_live[0]
