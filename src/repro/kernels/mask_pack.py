"""Pallas TPU kernels: two-phase parallel Zebra streaming producer.

``zebra_mask_pack`` turns a raw ``(M, K)`` activation map into the
compressed ``(payload, bitmap, n_live)`` stream — the exact bytes the
paper's accelerator puts on DRAM (Eq. 2/3) — without ever materializing
the dense masked map, in **two fully parallel Pallas passes** bridged by
a tiny XLA exclusive scan:

1. **Comparator pass** (grid over ``tiles_for`` supertiles): each step
   loads its own ``(tm, tk)`` tile, computes per-``(bs, bc)``-block
   maxima and emits the keep bitmap for its tile. Nothing else leaves
   the pass; steps share no state and can run in any order.
2. **Exclusive scan** (XLA, not a launch): the ``kernels.schedule``
   prefix sums over the keep flags are simultaneously the per-column
   live counts, the per-column payload offsets and every block's
   consumer-order slot index ``dmap[g]`` (column-grouped — the
   GEMM-consumable order the consumers read contiguously); a scatter of
   ``g`` into ``dmap[g]`` inverts it into ``src[slot] -> block``.
3. **Pack pass** (grid over payload slot windows): each step *gathers*
   the ``W`` source blocks for its own window of payload slots through
   ``W`` independently-addressed BlockSpecs (``src`` rides in
   scalar-prefetch SMEM) and zeroes the tail past ``n_live``. Every
   step writes only its own ``(W, bs, bc)`` slot range.

Like the consumers, the pack pass has two executable realizations of the
one contract, selected by ``gather_kernel`` (default: the Pallas form
when ``interpret=False``): on CPU containers the identical gather runs
as one XLA blocked take (``xb[src]``) instead, because the Pallas
interpreter charges ~100 us per dynamically-indexed window fetch and
duplicates the ``W`` source operands in its grid carry — the XLA take is
the faster realization of the same dataflow, bit for bit.

Why two-phase beats the online counter: the single-pass design kept a
running SMEM counter as an *online* exclusive prefix sum, which (a)
serialized the whole grid — every step observed the counter state of
all previous steps, so nothing could overlap — and (b) forced the
entire worst-case ``(n_blocks, bs, bc)`` payload to stay VMEM-resident
across the grid (the only way a sequential step could store to slot
``counter``), capping map size at ``vmem_budget_bytes`` and degrading
larger maps to a 3-launch pipeline. Hoisting the prefix sum out of the
kernel into one XLA cumsum removes both: the comparator and pack passes
touch only their own tiles (no cross-step ordering dependence, no
whole-payload residency, any map size), at the cost of reading ``x``
twice — cheap, because the second read is exactly as parallel as the
first. The scatter "write each supertile's live blocks to its slot
range" is realized as the equivalent aligned *gather* (each slot window
pulls its source blocks via the inverted slot map), because Pallas
output windows are shape-aligned while live-run offsets are not.

Still ≤ 2 launches; the stream is bitwise-identical to
``zebra_pack(*zebra_mask(x))`` (live blocks are untouched by masking,
so packing *raw* live blocks is already packing masked ones, and the
zero tail is written explicitly).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..utils import cdiv
from .schedule import slot_map
from .supertile import comparator_tiles, pack_window


def _bitmap_kernel(x_ref, bm_ref, *, t_obj: float, bs: int, bc: int):
    x = x_ref[...]
    TM, TK = x.shape
    xb = x.reshape(TM // bs, bs, TK // bc, bc)
    blockmax = jnp.max(jnp.abs(xb), axis=(1, 3))                  # (tm, tk)
    bm_ref[...] = (blockmax >= jnp.asarray(t_obj, blockmax.dtype)
                   ).astype(jnp.int8)


def _gather_pack_kernel(src_ref, nl_ref, *refs, window: int):
    del src_ref                          # consumed by the BlockSpec index maps
    x_refs, out_ref = refs[:window], refs[window]
    s = pl.program_id(0)
    n_live = nl_ref[0]
    parts = []
    for w in range(window):
        blk = x_refs[w][...]                                      # (bs, bc)
        live = (s * window + w) < n_live
        parts.append(jnp.where(live, blk, jnp.zeros_like(blk))[None])
    out_ref[...] = parts[0] if window == 1 else jnp.concatenate(parts, 0)


@functools.partial(jax.jit, static_argnames=("t_obj", "bs", "bc", "tm", "tk",
                                             "window", "gather_kernel",
                                             "interpret"))
def zebra_mask_pack(x: jax.Array, *, t_obj: float, bs: int = 8, bc: int = 128,
                    tm: int | None = None, tk: int | None = None,
                    window: int | None = None,
                    gather_kernel: bool | None = None, interpret: bool = True
                    ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Two-phase comparator + compaction over an (M, K) map.

    Returns ``(payload (n_blocks, bs, bc) — live blocks first in the
    consumer order of kernels.schedule (column-grouped), zero tail;
    bitmap (M//bs, K//bc) int8; n_live () int32)``.
    Bitwise-identical to ``zebra_pack(*zebra_mask(x))`` in ≤ 2 launches.

    ``tm``/``tk`` size the comparator pass's supertile (defaults to the
    module budget chooser); ``window`` is the pack pass's payload slots
    per grid step (defaults to the largest divisor of the block count
    under the cap).
    """
    M, K = x.shape
    if M % bs or K % bc:
        raise ValueError(f"(M={M}, K={K}) must divide by block ({bs},{bc})")
    nm, nk = M // bs, K // bc
    nb = nm * nk
    item = jnp.dtype(x.dtype).itemsize
    # standalone calls take the default-budget choosers; the engine passes
    # ZebraConfig-budgeted tiles and pack window explicitly (same formulas)
    dtm, dtk = comparator_tiles(M, K, bs, bc, item)
    tm, tk = tm or dtm, tk or dtk
    if tm % bs or tk % bc:
        raise ValueError(f"tile ({tm},{tk}) must divide by block ({bs},{bc})")
    W = window or pack_window(nb, bs, bc, item)
    if nb % W:
        raise ValueError(f"pack window {W} must divide n_blocks {nb}")
    if gather_kernel is None:
        gather_kernel = not interpret

    # -- phase 1: parallel comparator, bitmap only --------------------------
    bitmap = pl.pallas_call(
        functools.partial(_bitmap_kernel, t_obj=t_obj, bs=bs, bc=bc),
        grid=(cdiv(M, tm), cdiv(K, tk)),
        in_specs=[pl.BlockSpec((tm, tk), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((tm // bs, tk // bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nm, nk), jnp.int8),
        interpret=interpret,
    )(x)

    # -- phase 2a: ONE exclusive scan = counts, offsets and slot map --------
    # the consumer-order slot map (kernels.schedule): column-grouped, so
    # the downstream GEMM reads each K column as one contiguous slot run
    keep, dmap = slot_map(bitmap)
    n_live = jnp.sum(keep).astype(jnp.int32)
    g = jnp.arange(nb, dtype=jnp.int32)
    # invert: src[slot] = block index of the slot's live block (0 for tail,
    # which the pack kernel zeroes via slot >= n_live)
    src = jnp.zeros((nb,), jnp.int32).at[
        jnp.where(keep != 0, dmap, nb)].set(g, mode="drop")

    # -- phase 2b: parallel gather-pack over payload slot windows -----------
    if not gather_kernel:
        # interpret form: the identical gather as one XLA two-index take
        # straight off the 4-D block view — no transposed block copy of
        # the whole map on the producer hot path
        x4 = x.reshape(nm, bs, nk, bc)
        payload = jnp.where((g < n_live)[:, None, None],
                            x4[src // nk, :, src % nk, :],
                            jnp.zeros((), x.dtype))
        return payload, bitmap, n_live

    def _src_idx(s, src, nl, *, w):
        gidx = src[s * W + w]
        return (gidx // nk, gidx % nk)

    payload = pl.pallas_call(
        functools.partial(_gather_pack_kernel, window=W),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(nb // W,),
            in_specs=[pl.BlockSpec((bs, bc), functools.partial(_src_idx, w=w))
                      for w in range(W)],
            out_specs=pl.BlockSpec((W, bs, bc), lambda s, src, nl: (s, 0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((nb, bs, bc), x.dtype),
        interpret=interpret,
    )(src, n_live[None], *([x] * W))
    return payload, bitmap, n_live
