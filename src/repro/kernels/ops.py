"""Jit'd public wrappers around the Pallas kernels.

On this CPU container the kernels run with ``interpret=True`` (Pallas
executes the kernel body in Python for correctness); on TPU pass
``interpret=False``. ``zebra_ffn_hidden`` is the fused "Zebra site +
downstream matmul" used by the LM stack when ``use_kernel=True``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .mask_pack import zebra_mask_pack
from .pack import zebra_pack, zebra_unpack
from .spmm_cs import zebra_spmm_cs
from .zebra_mask import zebra_mask
from .zebra_spmm import zebra_spmm
from . import ref


def zebra_mask_op(x: jax.Array, t_obj: float, bs: int = 8, bc: int = 128,
                  interpret: bool = True):
    """(..., M, K) tolerant wrapper; flattens leading dims onto M."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    y, bm = zebra_mask(x2, t_obj=t_obj, bs=bs, bc=bc, interpret=interpret)
    return y.reshape(shape), bm


def zebra_spmm_op(x: jax.Array, w: jax.Array, bitmap: jax.Array,
                  bs: int = 8, bc: int = 128, stm: int | None = None,
                  stk: int | None = None,
                  caps: tuple[int, ...] | None = None,
                  zero_frac_hint: float | None = None,
                  scheduled: bool | None = None, interpret: bool = True):
    return zebra_spmm(x, w, bitmap, bs=bs, bc=bc, stm=stm, stk=stk,
                      caps=caps, zero_frac_hint=zero_frac_hint,
                      scheduled=scheduled, interpret=interpret)


def zebra_pack_op(x: jax.Array, bitmap: jax.Array, bs: int = 8, bc: int = 128,
                  interpret: bool = True):
    """Compact live blocks of a masked (M, K) map -> (payload, n_live)."""
    return zebra_pack(x, bitmap, bs=bs, bc=bc, interpret=interpret)


def zebra_unpack_op(payload: jax.Array, bitmap: jax.Array, bs: int = 8,
                    bc: int = 128, interpret: bool = True):
    return zebra_unpack(payload, bitmap, bs=bs, bc=bc, interpret=interpret)


def zebra_mask_pack_op(x: jax.Array, t_obj: float, bs: int = 8, bc: int = 128,
                       tm: int | None = None, tk: int | None = None,
                       interpret: bool = True):
    """Two-phase parallel producer: (M, K) -> (payload, bitmap, n_live)."""
    return zebra_mask_pack(x, t_obj=t_obj, bs=bs, bc=bc, tm=tm, tk=tk,
                           interpret=interpret)


def zebra_spmm_cs_op(payload: jax.Array, w: jax.Array, bitmap: jax.Array,
                     bs: int = 8, bc: int = 128, stm: int | None = None,
                     stk: int | None = None,
                     caps: tuple[int, ...] | None = None,
                     zero_frac_hint: float | None = None,
                     scheduled: bool | None = None, interpret: bool = True):
    """Compressed-stream consumer: payload x (K, N) -> (M, N) fp32."""
    return zebra_spmm_cs(payload, w, bitmap, bs=bs, bc=bc, stm=stm, stk=stk,
                         caps=caps, zero_frac_hint=zero_frac_hint,
                         scheduled=scheduled, interpret=interpret)


def zebra_ffn_hidden(x: jax.Array, w_out: jax.Array, t_obj: float,
                     bs: int = 8, bc: int = 128, interpret: bool = True):
    """Fused: h' = zebra(h); y = h' @ W_out, skipping dead blocks.

    Streaming form: the two-phase mask_pack producer emits the
    compressed stream (no dense masked intermediate) and the supertiled
    GEMM consumes the payload."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    payload, bm, _ = zebra_mask_pack(x2, t_obj=t_obj, bs=bs, bc=bc,
                                     interpret=interpret)
    y = zebra_spmm_cs(payload, w_out, bm, bs=bs, bc=bc, interpret=interpret)
    return y.reshape(*shape[:-1], w_out.shape[-1]), bm
