"""Pallas TPU kernels for the paper's compute hot-spot: the Zebra
comparator (zebra_mask) and the block-skipping GEMM (zebra_spmm)."""
from .ops import zebra_mask_op, zebra_spmm_op, zebra_ffn_hidden  # noqa: F401
from . import ref  # noqa: F401
