"""Pallas TPU kernels for the paper's compute hot-spot: the Zebra
comparator (zebra_mask), the block-skipping GEMM (zebra_spmm), and the
compressed-transport pack/unpack pair (zebra_pack / zebra_unpack)."""
from .ops import (zebra_mask_op, zebra_spmm_op, zebra_ffn_hidden,  # noqa: F401
                  zebra_pack_op, zebra_unpack_op)
from . import ref  # noqa: F401
