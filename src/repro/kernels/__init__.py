"""Pallas TPU kernels for the paper's compute hot-spot: the Zebra
comparator (zebra_mask), the supertiled block-skipping GEMM
(zebra_spmm), the compressed-transport pack/unpack pair (zebra_pack /
zebra_unpack), and the two-phase streaming pair (zebra_mask_pack /
zebra_spmm_cs) that produces and consumes the (payload, bitmap) stream
without ever materializing the dense masked map. Supertile shapes come
from kernels.supertile (via ZebraConfig.tiles_for) — one tiling policy
for every launch."""
from .ops import (zebra_mask_op, zebra_spmm_op, zebra_ffn_hidden,  # noqa: F401
                  zebra_mask_pack_op, zebra_spmm_cs_op,
                  zebra_pack_op, zebra_unpack_op)
from .grad import KernelStatics, zebra_kernel_trainable  # noqa: F401
from . import ref  # noqa: F401
