"""Pallas TPU kernel: block-sparse activation x dense weight GEMM that
*skips* Zebra zero blocks — harvesting the bandwidth sparsity as MXU time
(beyond-paper; the paper's ASIC gets the skip for free, DESIGN.md §7).

    y[M, N] = (x ⊙ blockmask)[M, K] @ w[K, N]

Grid (M/bm, N/bn, K/bk) with bm == bs (one Zebra block row per M-tile) and
bk == bc (one Zebra block col per K-tile), K innermost so each (i, j)
accumulates into a VMEM scratch accumulator in fp32.

Skip machinery: the keep-bitmap rides in scalar-prefetch SMEM. Dead blocks
(a) contribute nothing — `pl.when` guards the dot; and (b) cost no HBM
traffic — the x-BlockSpec index_map replays the *previous live* K-index via
a precomputed `kmap`, so the pruned tile is never fetched (revolving-door
indexing, the standard Pallas block-sparse trick).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..utils import cdiv


def _spmm_kernel(kmap_ref, keep_ref, x_ref, w_ref, y_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    i = pl.program_id(0)
    live = keep_ref[i * nk + k] != 0

    @pl.when(live)
    def _acc():
        acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                                preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        y_ref[...] = acc_ref[...].astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bs", "bc", "bn", "interpret"))
def zebra_spmm(x: jax.Array, w: jax.Array, bitmap: jax.Array, *,
               bs: int = 8, bc: int = 128, bn: int = 256,
               interpret: bool = True) -> jax.Array:
    """(M,K) x (K,N) with (M//bs, K//bc) keep-bitmap -> (M,N) fp32."""
    M, K = x.shape
    K2, N = w.shape
    assert K2 == K and bitmap.shape == (M // bs, K // bc), (bitmap.shape, M, K)
    bn = min(bn, N)
    nm, nn, nk = M // bs, cdiv(N, bn), K // bc
    keep = bitmap.reshape(-1).astype(jnp.int32)

    # revolving-door index map: dead block -> index of the last live block
    # (or 0) so the fetch is a VMEM no-op re-use, not a new HBM read.
    def build_kmap(keep_flat):
        keep2 = keep_flat.reshape(nm, nk)
        idx = jnp.arange(nk)[None, :] * (keep2 != 0)
        kmap = jax.lax.associative_scan(jnp.maximum, idx, axis=1)
        return kmap.reshape(-1).astype(jnp.int32)

    kmap = build_kmap(keep)

    grid = (nm, nn, nk)
    kernel = functools.partial(_spmm_kernel, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bs, bc),
                             lambda i, j, k, kmap, keep: (i, kmap[i * nk + k])),
                pl.BlockSpec((bc, bn), lambda i, j, k, kmap, keep: (k, j)),
            ],
            out_specs=pl.BlockSpec((bs, bn), lambda i, j, k, kmap, keep: (i, j)),
            scratch_shapes=[pltpu.VMEM((bs, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(kmap, keep, x, w)
    return out
