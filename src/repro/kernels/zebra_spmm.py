"""Pallas TPU kernel: supertiled block-sparse activation x dense weight GEMM
that *skips* Zebra zero blocks — harvesting the bandwidth sparsity as MXU
time (beyond-paper; the paper's ASIC gets the skip for free, DESIGN.md §7).

    y[M, N] = (x ⊙ blockmask)[M, K] @ w[K, N]

Grid coarseness is the whole game: the old kernel stepped one ``(bs, bc)``
Zebra block per grid step (grid ``(M/bs, N/bn, K/bc)``), paying the
per-step machinery once per block. This version steps one **supertile** —
an ``(stm, stk) = (R·bs, C·bc)`` multi-block window chosen by
``ZebraConfig.tiles_for(..., kind="gemm")`` under ``vmem_budget_bytes`` —
so the grid shrinks by the supertile factor ``R·C`` while each step makes
``C`` MXU-shaped dot calls over ``(stm, bc)`` column panels.

Skip machinery, now at two granularities:

* **supertile**: a per-supertile any-live flag rides in scalar-prefetch
  SMEM; a fully dead supertile skips all of its dots in ONE ``pl.when``
  (dead work dropped in coarse chunks), and the x-window index map
  replays the last any-live supertile column (revolving-door), so the
  pruned supertile is never fetched from HBM;
* **block**: within a live supertile, each ``(bs, bc)`` block is gated by
  its keep flag (``jnp.where`` to exact +0) before entering the column
  panel — dead blocks contribute exact zeros whatever the raw ``x``
  holds, and the panel assembly is *identical code* to the
  compressed-stream consumer (``zebra_spmm_cs``), which is what makes
  the two bitwise-equal.

Accumulation: fp32 VMEM scratch, K innermost, ``C`` sequential panel
dots per step in ascending K order — the same per-row summation order
for every legal supertile choice, so retiling does not move the result.

Two executable realizations of the one contract, selected by
``scheduled`` (default: the scheduled XLA form when ``interpret=True``):

* **scheduled form** (CPU containers / XLA): the static prefetch
  schedule of ``kernels.schedule`` compacts each K column's live blocks
  to a ladder capacity from the cached ``supertile.gemm_plan`` chooser
  and runs one batched panel GEMM + selection-matmul assembly — the
  realization that actually beats the dense matmul at the paper's
  operating point (BENCH_kernels.json ``speedup_vs_dense``). Bitwise
  equal to ``zebra_spmm_cs``'s scheduled form by construction (same
  ``_consume_at_cap``, identical gated operands).
* **kernel form** (``scheduled=False``, the TPU form): the supertiled
  Pallas GEMM below, bitwise-equal to ``zebra_spmm_cs``'s
  payload-window form via the shared ``gemm_supertile_body``.

The two forms sum partial products in different orders (sequential
panel accumulate vs batched GEMM + selection matmul), so cross-form
parity is allclose-tight, not bitwise; *within* each form the dense and
compressed consumers are bitwise-equal, which is the contract the
acceptance tests pin.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..utils import cdiv
from .schedule import consumer_schedule, scheduled_consume
from .supertile import gemm_plan, validate_supertile


def gemm_supertile_body(keep_ref, seg_ref, get_block, w_ref, y_ref, acc_ref,
                        *, R: int, C: int, bc: int, nk: int, GK: int):
    """THE supertile GEMM step, shared by the dense and compressed
    consumers — their bitwise parity rests on this body being literally
    the same code, with only the block accessor differing.

    One (stm, bn) output window: accumulate C column-panel dots of the
    (stm, stk) activation supertile in ascending K order, gating each
    (bs, bc) block by its keep flag (exact +0 for dead blocks, whatever
    the fetched window holds). A fully dead supertile skips all C dots
    in one pl.when. ``get_block(r, j)`` returns the (bs, bc) block of
    the supertile's r-th block row / j-th block column."""
    i, kc = pl.program_id(0), pl.program_id(2)

    @pl.when(kc == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(seg_ref[i * GK + kc] != 0)
    def _acc():
        ws = w_ref[...]
        for j in range(C):
            cols = []
            for r in range(R):
                live = keep_ref[(i * R + r) * nk + kc * C + j] != 0
                blk = get_block(r, j)
                cols.append(jnp.where(live, blk, jnp.zeros_like(blk)))
            xj = cols[0] if R == 1 else jnp.concatenate(cols, 0)
            acc_ref[...] += jnp.dot(xj, ws[j * bc:(j + 1) * bc, :],
                                    preferred_element_type=jnp.float32)

    @pl.when(kc == GK - 1)
    def _flush():
        y_ref[...] = acc_ref[...].astype(y_ref.dtype)


def _dense_gemm_kernel(keep_ref, seg_ref, kmap_ref, x_ref, w_ref, y_ref,
                       acc_ref, *, R: int, C: int, bs: int, bc: int,
                       nk: int, GK: int):
    """Dense-operand flavor: blocks come from the (stm, stk) x window.
    ``kmap_ref`` (the revolving-door fetch map) is consumed by the
    BlockSpec index maps, not the body."""
    del kmap_ref
    gemm_supertile_body(
        keep_ref, seg_ref,
        lambda r, j: x_ref[r * bs:(r + 1) * bs, j * bc:(j + 1) * bc],
        w_ref, y_ref, acc_ref, R=R, C=C, bc=bc, nk=nk, GK=GK)


def seg_live(keep: jax.Array, nm: int, nk: int, R: int, C: int) -> jax.Array:
    """Per-supertile any-live flags, (GM, GK) shaped."""
    GM, GK = nm // R, nk // C
    return keep.reshape(GM, R, GK, C).sum(axis=(1, 3)) > 0


def seg_live_and_kmap(keep: jax.Array, nm: int, nk: int, R: int, C: int
                      ) -> tuple[jax.Array, jax.Array]:
    """Per-supertile any-live flags (GM*GK,) and the revolving-door map:
    for each (supertile row, supertile col), the last any-live supertile
    column <= it (or 0), so a dead supertile's fetch is a VMEM re-use."""
    seg = seg_live(keep, nm, nk, R, C)
    GK = seg.shape[1]
    idx = jnp.arange(GK, dtype=jnp.int32)[None, :] * seg
    kmap = jax.lax.associative_scan(jnp.maximum, idx, axis=1)
    return seg.reshape(-1).astype(jnp.int32), kmap.reshape(-1).astype(jnp.int32)


def launch_supertile_gemm(x2: jax.Array, w: jax.Array, keep: jax.Array, *,
                          bs: int, bc: int, stm: int, stk: int, bn: int,
                          interpret: bool) -> jax.Array:
    """Launch the supertiled GEMM over a dense (M, K) activation operand
    (raw or blocked-expanded — dead blocks are keep-gated in-kernel)."""
    M, K = x2.shape
    N = w.shape[1]
    nm, nk = M // bs, K // bc
    R, C = stm // bs, stk // bc
    GM, GN, GK = nm // R, cdiv(N, bn), nk // C
    seg, kmap = seg_live_and_kmap(keep, nm, nk, R, C)
    kernel = functools.partial(_dense_gemm_kernel, R=R, C=C, bs=bs, bc=bc,
                               nk=nk, GK=GK)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(GM, GN, GK),
            in_specs=[
                pl.BlockSpec((stm, stk),
                             lambda i, jn, kc, keep, seg, kmap:
                             (i, kmap[i * GK + kc])),
                pl.BlockSpec((stk, bn),
                             lambda i, jn, kc, keep, seg, kmap: (kc, jn)),
            ],
            out_specs=pl.BlockSpec(
                (stm, bn), lambda i, jn, kc, keep, seg, kmap: (i, jn)),
            scratch_shapes=[pltpu.VMEM((stm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(keep, seg, kmap, x2, w)


@functools.partial(jax.jit, static_argnames=("bs", "bc", "bn", "stm", "stk",
                                             "caps", "zero_frac_hint",
                                             "scheduled", "interpret"))
def zebra_spmm(x: jax.Array, w: jax.Array, bitmap: jax.Array, *,
               bs: int = 8, bc: int = 128, bn: int | None = None,
               stm: int | None = None, stk: int | None = None,
               caps: tuple[int, ...] | None = None,
               zero_frac_hint: float | None = None,
               scheduled: bool | None = None,
               interpret: bool = True) -> jax.Array:
    """(M,K) x (K,N) with (M//bs, K//bc) keep-bitmap -> (M,N) fp32.

    ``stm``/``stk``/``bn`` size the kernel-form GEMM supertile and
    ``caps`` the scheduled form's capacity ladder — both default from
    the cached ``supertile.gemm_plan`` chooser (``zero_frac_hint``
    tightens the ladder; the engine threads its config hint through).
    ``scheduled=None`` picks the scheduled XLA form iff ``interpret``."""
    M, K = x.shape
    K2, N = w.shape
    assert K2 == K and bitmap.shape == (M // bs, K // bc), (bitmap.shape, M, K)
    plan = gemm_plan(M, K, N, bs, bc, jnp.dtype(x.dtype).itemsize,
                     zero_frac=zero_frac_hint)
    stm, stk, bn = stm or plan.stm, stk or plan.stk, min(bn or plan.bn, N)
    validate_supertile(M, K, bs, bc, stm, stk)
    if scheduled is None:
        scheduled = interpret
    if scheduled:
        sched = consumer_schedule(bitmap)
        return scheduled_consume(x, w, sched, caps or plan.caps,
                                 from_payload=False, nm=M // bs, nk=K // bc,
                                 bs=bs, bc=bc)
    keep = bitmap.reshape(-1).astype(jnp.int32)
    return launch_supertile_gemm(x, w, keep, bs=bs, bc=bc, stm=stm, stk=stk,
                                 bn=bn, interpret=interpret)
