"""Pallas TPU kernel: fused Zebra comparator (paper Fig. 3, inference mode).

One HBM pass: load a ``(TM, TK)`` activation tile into VMEM, compute the
per-``(bs, bc)``-block max, compare against the threshold, zero dead blocks
in-register, write the tile and its keep-bitmap back. This is the paper's
RTL comparator recast as a VMEM-tiled epilogue (DESIGN.md §2/§7).

Tiling: the kernel tile (TM, TK) contains an integer number of Zebra
blocks; default TM=256, TK=512 with (bs, bc) = (8, 128) — i.e. 32x4 Zebra
blocks per VMEM tile, MXU/VPU aligned (TK multiple of 128 lanes, TM
multiple of 8 sublanes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..utils import cdiv


def _zebra_mask_kernel(x_ref, y_ref, bm_ref, *, t_obj: float, bs: int, bc: int):
    x = x_ref[...]
    TM, TK = x.shape
    xb = x.reshape(TM // bs, bs, TK // bc, bc)
    blockmax = jnp.max(jnp.abs(xb), axis=(1, 3))                  # (tm, tk)
    keep = blockmax >= jnp.asarray(t_obj, blockmax.dtype)
    y = xb * keep[:, None, :, None].astype(x.dtype)
    y_ref[...] = y.reshape(TM, TK)
    bm_ref[...] = keep.astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("t_obj", "bs", "bc", "tm", "tk",
                                             "interpret"))
def zebra_mask(x: jax.Array, *, t_obj: float, bs: int = 8, bc: int = 128,
               tm: int = 256, tk: int = 512, interpret: bool = True
               ) -> tuple[jax.Array, jax.Array]:
    """(M, K) -> (masked (M, K), keep bitmap (M//bs, K//bc) int8)."""
    M, K = x.shape
    tm = min(tm, M)
    tk = min(tk, K)
    if M % bs or K % bc:
        raise ValueError(f"(M={M}, K={K}) must divide by block ({bs},{bc})")
    if tm % bs or tk % bc:
        raise ValueError(f"tile ({tm},{tk}) must divide by block ({bs},{bc})")
    grid = (cdiv(M, tm), cdiv(K, tk))
    kernel = functools.partial(_zebra_mask_kernel, t_obj=t_obj, bs=bs, bc=bc)
    y, bm = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tm, tk), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((tm, tk), lambda i, j: (i, j)),
            pl.BlockSpec((tm // bs, tk // bc), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, K), x.dtype),
            jax.ShapeDtypeStruct((M // bs, K // bc), jnp.int8),
        ],
        interpret=interpret,
    )(x)
    return y, bm
