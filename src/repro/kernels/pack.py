"""Pallas TPU kernels: compressed activation transport (pack / unpack).

``zebra_pack`` compacts the *surviving* ``(bs, bc)`` blocks of a
Zebra-masked ``(M, K)`` map into a dense payload — live blocks first, in
the **GEMM-consumable consumer order** of ``kernels.schedule`` (grouped
by K-block column, columns ascending, rows ascending within a column) —
so the accelerator moves only ``n_live * bs * bc * itemsize`` payload
bytes plus the 1-bit-per-block index (paper Eq. 2/3) instead of the full
map, AND the downstream GEMM reads each K column's operand as one
contiguous slot run with zero dynamic-window gathers on its hot path.
``zebra_unpack`` is the exact inverse. Stream format: README.md
§Compressed activation transport.

Because JAX shapes are static, the payload buffer is allocated at the
worst case (``n_blocks`` slots); the *measured* stream length is
``n_live`` slots and everything past it is zeroed (slot order cannot
change the stream length). Compaction runs as a scatter through the
output BlockSpec index_map: block ``(r, k)``'s destination slot is
``schedule.slot_map``'s consumer-order prefix sum (scalar-prefetched in
SMEM). The grid iterates **K-block columns outermost** so the slot map
stays monotone along the traversal: dead blocks write to the slot the
*next* live block of their column also maps to, and the sequential TPU
grid makes the live block's write win — the dual of the consumers'
revolving-door read trick. Visits to each output slot remain a single
contiguous run of grid steps, which is what the TPU output-revisiting
rule requires.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .schedule import slot_map
from .supertile import gather_supertiles, validate_supertile


def _pack_kernel(dmap_ref, keep_ref, x_ref, out_ref):
    del dmap_ref, keep_ref
    out_ref[...] = x_ref[...][None]


def _unpack_kernel(smap_ref, keep_ref, *refs, R: int, C: int, bs: int,
                   nk: int):
    """Supertiled expander step: scatter the (stm, stk) supertile's R*C
    dynamically slotted payload windows back to their dense positions,
    zero-gating dead blocks (whose revolving-door windows alias live
    slots)."""
    del smap_ref                        # consumed by the BlockSpec index maps
    p_refs, out_ref = refs[:R * C], refs[R * C]
    i, kc = pl.program_id(0), pl.program_id(1)
    rows = []
    for r in range(R):
        cols = []
        for j in range(C):
            live = keep_ref[(i * R + r) * nk + kc * C + j] != 0
            blk = p_refs[r * C + j][...][0]
            cols.append(jnp.where(live, blk, jnp.zeros_like(blk)))
        rows.append(cols[0] if C == 1 else jnp.concatenate(cols, 1))
    out_ref[...] = rows[0] if R == 1 else jnp.concatenate(rows, 0)


def _prefix(bitmap: jax.Array) -> tuple[jax.Array, jax.Array]:
    """keep flags + the consumer-order block -> payload-slot map (THE one
    slot map, from kernels.schedule — producer, expander and consumers
    all address the stream through it)."""
    return slot_map(bitmap)


def expand_payload(payload: jax.Array, keep: jax.Array, smap: jax.Array,
                   nm: int, nk: int, bs: int, bc: int) -> jax.Array:
    """THE XLA blocked expansion of a compressed stream back to the dense
    (M, K) map — shared by zebra_unpack's interpret form and
    zebra_spmm_cs's interpret prologue, so the two cannot diverge.

    jnp.where, not multiplication: a dead block's revolving-door slot
    aliases a live block, and masking by * would leak NaN/Inf (and
    -0.0) from it where the kernel form writes exact +0."""
    blocks = jnp.where((keep != 0)[:, None, None], payload[smap],
                       jnp.zeros((), payload.dtype))
    return (blocks.reshape(nm, nk, bs, bc).transpose(0, 2, 1, 3)
            .reshape(nm * bs, nk * bc))


@functools.partial(jax.jit, static_argnames=("bs", "bc", "interpret"))
def zebra_pack(x: jax.Array, bitmap: jax.Array, *, bs: int = 8, bc: int = 128,
               interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Compact live blocks of a masked (M, K) map.

    Returns (payload (n_blocks, bs, bc) — live blocks first in consumer
    order (column-grouped; kernels.schedule), zero tail — and n_live ()
    int32).
    """
    M, K = x.shape
    if M % bs or K % bc:
        raise ValueError(f"(M={M}, K={K}) must divide by block ({bs},{bc})")
    nm, nk = M // bs, K // bc
    assert bitmap.shape == (nm, nk), (bitmap.shape, nm, nk)
    nb = nm * nk
    keep, dmap = _prefix(bitmap)
    n_live = jnp.sum(keep)

    # K-block column outermost: the consumer-order slot map is monotone
    # along this traversal (ascending within each column's run), which the
    # scatter-through-BlockSpec output-revisiting trick requires.
    payload = pl.pallas_call(
        _pack_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(nk, nm),
            in_specs=[
                pl.BlockSpec((bs, bc), lambda kc, i, dmap, keep: (i, kc)),
            ],
            out_specs=pl.BlockSpec(
                (1, bs, bc),
                lambda kc, i, dmap, keep: (dmap[i * nk + kc], 0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((nb, bs, bc), x.dtype),
        interpret=interpret,
    )(dmap, keep, x)

    # Slots >= n_live hold either stale dead-block writes or uninitialized
    # memory; zero them so the stream (and comparisons) are deterministic.
    live_slot = jnp.arange(nb)[:, None, None] < n_live
    payload = jnp.where(live_slot, payload, jnp.zeros((), x.dtype))
    return payload, n_live.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bs", "bc", "stm", "stk",
                                             "payload_windows", "interpret"))
def zebra_unpack(payload: jax.Array, bitmap: jax.Array, *, bs: int = 8,
                 bc: int = 128, stm: int | None = None, stk: int | None = None,
                 payload_windows: bool | None = None,
                 interpret: bool = True) -> jax.Array:
    """Inverse of zebra_pack: (n_blocks, bs, bc) payload -> dense (M, K).

    Two executable realizations of the one contract (see mask_pack.py):
    ``payload_windows=True`` is the TPU form — the grid steps over
    ``(stm, stk)`` supertiles (``tiles_for(kind="gather")``; the engine
    passes its budgeted tiles, standalone calls use the default-budget
    chooser) and each step writes its own dense window from R*C
    dynamically slotted payload windows. The interpret default runs the
    identical expansion as one XLA blocked gather (the Pallas
    interpreter charges ~100 us per dynamically-indexed window fetch,
    so the gather is the faster realization of the same dataflow on
    CPU, bit for bit)."""
    nm, nk = bitmap.shape
    assert payload.shape == (nm * nk, bs, bc), (payload.shape, nm, nk, bs, bc)
    M, K = nm * bs, nk * bc
    keep, smap = _prefix(bitmap)
    if payload_windows is None:
        payload_windows = not interpret
    if not payload_windows:
        return expand_payload(payload, keep, smap, nm, nk, bs, bc)

    item = jnp.dtype(payload.dtype).itemsize
    dstm, dstk = gather_supertiles(M, K, bs, bc, item)
    stm, stk = stm or dstm, stk or dstk
    validate_supertile(M, K, bs, bc, stm, stk)
    R, C = stm // bs, stk // bc

    def _p_idx(i, kc, smap, keep, *, r, j):
        # dead block: revolving-door fetch of an arbitrary valid slot,
        # zeroed in-kernel (exclusive prefix sum <= n_live <= nb - 1
        # whenever a dead block exists, so the index stays in bounds).
        return (smap[(i * R + r) * nk + kc * C + j], 0, 0)

    return pl.pallas_call(
        functools.partial(_unpack_kernel, R=R, C=C, bs=bs, nk=nk),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(nm // R, nk // C),
            in_specs=[pl.BlockSpec((1, bs, bc),
                                   functools.partial(_p_idx, r=r, j=j))
                      for r in range(R) for j in range(C)],
            out_specs=pl.BlockSpec((stm, stk),
                                   lambda i, kc, smap, keep: (i, kc)),
        ),
        out_shape=jax.ShapeDtypeStruct((M, K), payload.dtype),
        interpret=interpret,
    )(smap, keep, *([payload] * (R * C)))
