"""Pallas TPU kernels: compressed activation transport (pack / unpack).

``zebra_pack`` compacts the *surviving* ``(bs, bc)`` blocks of a
Zebra-masked ``(M, K)`` map into a dense payload — live blocks first, in
row-major block order — so the accelerator moves only
``n_live * bs * bc * itemsize`` payload bytes plus the 1-bit-per-block
index (paper Eq. 2/3) instead of the full map. ``zebra_unpack`` is the
exact inverse. Stream format: README.md §Compressed activation transport.

Because JAX shapes are static, the payload buffer is allocated at the
worst case (``n_blocks`` slots); the *measured* stream length is
``n_live`` slots and everything past it is zeroed. Compaction runs as a
scatter through the output BlockSpec index_map: block ``g``'s destination
slot is the exclusive prefix sum of the keep flags (scalar-prefetched in
SMEM). Dead blocks write to the slot the *next* live block also maps to,
so the sequential TPU grid makes the live block's write win — the dual of
zebra_spmm's revolving-door read trick. Visits to each output slot are a
single contiguous run of grid steps (the prefix sum is monotone), which
is what the TPU output-revisiting rule requires.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _pack_kernel(dmap_ref, keep_ref, x_ref, out_ref):
    del dmap_ref, keep_ref
    out_ref[...] = x_ref[...][None]


def _unpack_kernel(smap_ref, keep_ref, p_ref, out_ref, *, nk: int):
    del smap_ref
    i, j = pl.program_id(0), pl.program_id(1)
    live = keep_ref[i * nk + j] != 0
    blk = p_ref[...][0]
    out_ref[...] = jnp.where(live, blk, jnp.zeros_like(blk))


def _prefix(bitmap: jax.Array) -> tuple[jax.Array, jax.Array]:
    """keep flags + exclusive prefix sum (the block -> payload-slot map)."""
    keep = bitmap.reshape(-1).astype(jnp.int32)
    return keep, (jnp.cumsum(keep) - keep).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bs", "bc", "interpret"))
def zebra_pack(x: jax.Array, bitmap: jax.Array, *, bs: int = 8, bc: int = 128,
               interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Compact live blocks of a masked (M, K) map.

    Returns (payload (n_blocks, bs, bc) — live blocks first, zero tail —
    and n_live () int32).
    """
    M, K = x.shape
    if M % bs or K % bc:
        raise ValueError(f"(M={M}, K={K}) must divide by block ({bs},{bc})")
    nm, nk = M // bs, K // bc
    assert bitmap.shape == (nm, nk), (bitmap.shape, nm, nk)
    nb = nm * nk
    keep, dmap = _prefix(bitmap)
    n_live = jnp.sum(keep)

    payload = pl.pallas_call(
        _pack_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(nm, nk),
            in_specs=[
                pl.BlockSpec((bs, bc), lambda i, j, dmap, keep: (i, j)),
            ],
            out_specs=pl.BlockSpec(
                (1, bs, bc), lambda i, j, dmap, keep: (dmap[i * nk + j], 0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((nb, bs, bc), x.dtype),
        interpret=interpret,
    )(dmap, keep, x)

    # Slots >= n_live hold either stale dead-block writes or uninitialized
    # memory; zero them so the stream (and comparisons) are deterministic.
    live_slot = jnp.arange(nb)[:, None, None] < n_live
    payload = jnp.where(live_slot, payload, jnp.zeros((), x.dtype))
    return payload, n_live.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bs", "bc", "interpret"))
def zebra_unpack(payload: jax.Array, bitmap: jax.Array, *, bs: int = 8,
                 bc: int = 128, interpret: bool = True) -> jax.Array:
    """Inverse of zebra_pack: (n_blocks, bs, bc) payload -> dense (M, K)."""
    nm, nk = bitmap.shape
    assert payload.shape == (nm * nk, bs, bc), (payload.shape, nm, nk, bs, bc)
    keep, smap = _prefix(bitmap)

    return pl.pallas_call(
        functools.partial(_unpack_kernel, nk=nk),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(nm, nk),
            in_specs=[
                # dead block: revolving-door fetch of an arbitrary valid slot,
                # zeroed in-kernel (exclusive prefix sum <= n_live <= nb - 1
                # whenever a dead block exists, so the index stays in bounds).
                pl.BlockSpec(
                    (1, bs, bc), lambda i, j, smap, keep: (smap[i * nk + j], 0, 0)),
            ],
            out_specs=pl.BlockSpec((bs, bc), lambda i, j, smap, keep: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((nm * bs, nk * bc), payload.dtype),
        interpret=interpret,
    )(smap, keep, payload)
