"""Pure-jnp oracles for the Pallas kernels (the correctness contract).

Layout convention: activations are 2-D ``(M, K)`` token maps (batch·seq
flattened onto M, channels on K). Zebra blocks are ``(bs, bc)`` tiles;
bitmap[i, j] == keep for block (i, j).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def zebra_mask_ref(x: jax.Array, t_obj: float, bs: int, bc: int
                   ) -> tuple[jax.Array, jax.Array]:
    """Inference-mode Zebra: zero every (bs, bc) block whose max|x| < t_obj.

    Returns (masked x, keep bitmap (M//bs, K//bc) int8).
    """
    M, K = x.shape
    xb = x.reshape(M // bs, bs, K // bc, bc)
    blockmax = jnp.max(jnp.abs(xb), axis=(1, 3))                 # (Mb, Kb)
    keep = blockmax >= jnp.asarray(t_obj, blockmax.dtype)
    y = (xb * keep[:, None, :, None].astype(x.dtype)).reshape(M, K)
    return y, keep.astype(jnp.int8)


def zebra_spmm_ref(x: jax.Array, w: jax.Array, bitmap: jax.Array,
                   bs: int, bc: int) -> jax.Array:
    """Block-sparse activation x dense weight: y = (x ⊙ blockmask) @ w.

    x: (M, K), w: (K, N), bitmap: (M//bs, K//bc) keep flags.
    """
    M, K = x.shape
    mask = jnp.repeat(jnp.repeat(bitmap.astype(x.dtype), bs, 0), bc, 1)
    return ((x * mask).astype(jnp.float32) @ w.astype(jnp.float32))


def zebra_mask_then_spmm_ref(x, w, t_obj, bs, bc):
    y, bm = zebra_mask_ref(x, t_obj, bs, bc)
    return y.astype(jnp.float32) @ w.astype(jnp.float32), bm


def _to_blocks(x: jax.Array, bs: int, bc: int) -> jax.Array:
    """(M, K) -> (n_blocks, bs, bc) in row-major block order."""
    M, K = x.shape
    nm, nk = M // bs, K // bc
    return x.reshape(nm, bs, nk, bc).transpose(0, 2, 1, 3).reshape(nm * nk, bs, bc)


def _from_blocks(blocks: jax.Array, nm: int, nk: int) -> jax.Array:
    bs, bc = blocks.shape[-2:]
    return (blocks.reshape(nm, nk, bs, bc).transpose(0, 2, 1, 3)
            .reshape(nm * bs, nk * bc))


def zebra_pack_ref(x: jax.Array, bitmap: jax.Array, bs: int, bc: int
                   ) -> tuple[jax.Array, jax.Array]:
    """Compaction oracle: live (bs, bc) blocks first in CONSUMER order —
    grouped by K-block column, columns ascending, block rows ascending
    within a column (the GEMM-consumable order of kernels.schedule) —
    then a zeroed tail. Returns (payload (n_blocks, bs, bc), n_live ()
    int32). Deliberately an independent realization (a stable argsort on
    the (column, row) key), not the kernels' prefix-sum scatter."""
    nm, nk = bitmap.shape
    blocks = _to_blocks(x, bs, bc)                    # row-major block order
    keep = bitmap.reshape(-1).astype(jnp.int32)
    n_live = jnp.sum(keep)
    nb = nm * nk
    g = jnp.arange(nb, dtype=jnp.int32)
    r, k = g // nk, g % nk
    sortkey = jnp.where(keep != 0, k * nm + r, nb * nm + g)   # dead: after
    order = jnp.argsort(sortkey, stable=True)
    payload = blocks[order]
    live_slot = jnp.arange(nb)[:, None, None] < n_live
    payload = jnp.where(live_slot, payload, jnp.zeros((), x.dtype))
    return payload, n_live.astype(jnp.int32)


def zebra_unpack_ref(payload: jax.Array, bitmap: jax.Array, bs: int, bc: int
                     ) -> jax.Array:
    """Inverse of zebra_pack_ref: scatter consumer-order payload slots
    back to (M, K). Dead blocks are where-gated (not multiplied) to
    exact +0, matching the kernels — a dead block's slot aliases a live
    block, and * would leak NaN/Inf from it."""
    nm, nk = bitmap.shape
    keep2 = bitmap.astype(jnp.int32)                  # (nm, nk)
    counts = keep2.sum(axis=0)
    offsets = jnp.cumsum(counts) - counts             # column slot runs
    colrank = jnp.cumsum(keep2, axis=0) - keep2
    src = (offsets[None, :] + colrank).reshape(-1)    # block -> slot
    keep = keep2.reshape(-1)
    blocks = jnp.where((keep != 0)[:, None, None], payload[src],
                       jnp.zeros((), payload.dtype))
    return _from_blocks(blocks, nm, nk)


def zebra_mask_pack_ref(x: jax.Array, t_obj: float, bs: int, bc: int
                        ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Single-pass streaming oracle: comparator + compaction composed.

    Returns (payload, bitmap, n_live) — the contract for zebra_mask_pack.
    """
    y, bitmap = zebra_mask_ref(x, t_obj, bs, bc)
    payload, n_live = zebra_pack_ref(y, bitmap, bs, bc)
    return payload, bitmap, n_live


def zebra_spmm_cs_ref(payload: jax.Array, w: jax.Array, bitmap: jax.Array,
                      bs: int, bc: int) -> jax.Array:
    """Compressed-stream GEMM oracle: unpack the payload, then the dense
    masked matmul — the contract for zebra_spmm_cs."""
    x = zebra_unpack_ref(payload, bitmap, bs, bc)
    return x.astype(jnp.float32) @ w.astype(jnp.float32)
