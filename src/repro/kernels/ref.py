"""Pure-jnp oracles for the Pallas kernels (the correctness contract).

Layout convention: activations are 2-D ``(M, K)`` token maps (batch·seq
flattened onto M, channels on K). Zebra blocks are ``(bs, bc)`` tiles;
bitmap[i, j] == keep for block (i, j).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def zebra_mask_ref(x: jax.Array, t_obj: float, bs: int, bc: int
                   ) -> tuple[jax.Array, jax.Array]:
    """Inference-mode Zebra: zero every (bs, bc) block whose max|x| < t_obj.

    Returns (masked x, keep bitmap (M//bs, K//bc) int8).
    """
    M, K = x.shape
    xb = x.reshape(M // bs, bs, K // bc, bc)
    blockmax = jnp.max(jnp.abs(xb), axis=(1, 3))                 # (Mb, Kb)
    keep = blockmax >= jnp.asarray(t_obj, blockmax.dtype)
    y = (xb * keep[:, None, :, None].astype(x.dtype)).reshape(M, K)
    return y, keep.astype(jnp.int8)


def zebra_spmm_ref(x: jax.Array, w: jax.Array, bitmap: jax.Array,
                   bs: int, bc: int) -> jax.Array:
    """Block-sparse activation x dense weight: y = (x ⊙ blockmask) @ w.

    x: (M, K), w: (K, N), bitmap: (M//bs, K//bc) keep flags.
    """
    M, K = x.shape
    mask = jnp.repeat(jnp.repeat(bitmap.astype(x.dtype), bs, 0), bc, 1)
    return ((x * mask).astype(jnp.float32) @ w.astype(jnp.float32))


def zebra_mask_then_spmm_ref(x, w, t_obj, bs, bc):
    y, bm = zebra_mask_ref(x, t_obj, bs, bc)
    return y.astype(jnp.float32) @ w.astype(jnp.float32), bm
