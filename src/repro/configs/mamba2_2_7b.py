"""mamba2-2.7b [ssm] — attention-free SSD (state-space duality),
d_state=128, headdim=64, expand=2. The Mamba-2 block contains its own
gated MLP (d_ff=0 → no separate FFN). [arXiv:2405.21060]"""
from ..models.lm.config import LMConfig

CONFIG = LMConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50280,
    layer_pattern=("ssm",), norm="rmsnorm",
    tie_embeddings=True,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=128,
    head_dim=64,
    # Zebra applies to the gated SSD output map via layer_out site
    zebra_sites=("layer_out",),
)


def reduced() -> LMConfig:
    return CONFIG.replace(n_layers=2, d_model=128, vocab=512, ssm_state=16,
                          ssm_head_dim=32, ssm_chunk=32)
