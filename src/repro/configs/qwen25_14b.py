"""qwen2.5-14b [dense] — GQA with QKV bias. [hf:Qwen/Qwen2.5-14B]"""
from ..models.lm.config import LMConfig

CONFIG = LMConfig(
    name="qwen2.5-14b", family="dense",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=13824, vocab=152064,
    layer_pattern=("global",), qkv_bias=True, norm="rmsnorm", act="swiglu",
    tie_embeddings=True, rope_theta=1_000_000.0,
)


def reduced() -> LMConfig:
    return CONFIG.replace(n_layers=2, d_model=160, n_heads=8, n_kv_heads=2,
                          d_ff=320, vocab=512, attn_chunk=64)
