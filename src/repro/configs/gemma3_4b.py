"""gemma3-4b [dense] — 5 local (sliding-window) : 1 global layer pattern,
128k context. [hf:google/gemma-3-*]"""
from ..models.lm.config import LMConfig

CONFIG = LMConfig(
    name="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4,
    d_ff=10240, vocab=262144,
    layer_pattern=("local", "local", "local", "local", "local", "global"),
    window=1024, qkv_bias=False, norm="rmsnorm", act="swiglu",
    tie_embeddings=True, rope_theta=1_000_000.0,
)


def reduced() -> LMConfig:
    return CONFIG.replace(n_layers=6, d_model=128, n_heads=4, n_kv_heads=2,
                          d_ff=256, vocab=512, window=32, attn_chunk=64)
