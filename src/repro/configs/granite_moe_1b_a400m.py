"""granite-moe-1b-a400m [moe] — 32 experts, top-8 routing, narrow experts
(d_ff=512). [hf:ibm-granite/granite-3.0-1b-a400m-base]"""
from ..models.lm.config import LMConfig

CONFIG = LMConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
    d_ff=512, vocab=49155,
    layer_pattern=("global",), qkv_bias=False, norm="rmsnorm", act="swiglu",
    tie_embeddings=True,
    n_experts=32, top_k=8, capacity_factor=1.25,
    zebra_block_ch=128,
)


def reduced() -> LMConfig:
    return CONFIG.replace(n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
                          d_ff=128, vocab=512, n_experts=8, top_k=2,
                          attn_chunk=64)
