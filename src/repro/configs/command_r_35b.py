"""command-r-35b [dense] — GQA, no biases anywhere.
[hf:CohereForAI/c4ai-command-r-v01]"""
from ..models.lm.config import LMConfig

CONFIG = LMConfig(
    name="command-r-35b", family="dense",
    n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22528, vocab=256000,
    layer_pattern=("global",), qkv_bias=False, norm="layernorm", act="swiglu",
    tie_embeddings=True,
)


def reduced() -> LMConfig:
    return CONFIG.replace(n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
                          d_ff=256, vocab=512, attn_chunk=64)
