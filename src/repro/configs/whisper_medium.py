"""whisper-medium [audio] — encoder-decoder; the conv frontend is a STUB:
input_specs supplies precomputed frame embeddings (B, 1500, d_model).
Full MHA (kv=16 == heads), LayerNorm + GELU. [arXiv:2212.04356]"""
from ..models.lm.config import LMConfig

CONFIG = LMConfig(
    name="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=51865,
    layer_pattern=("global",), qkv_bias=True, norm="layernorm", act="gelu",
    tie_embeddings=True,
    encoder_layers=24, enc_seq=1500,
)


def reduced() -> LMConfig:
    return CONFIG.replace(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                          d_ff=256, vocab=512, encoder_layers=2, enc_seq=64,
                          attn_chunk=64)
