"""chameleon-34b [vlm] — early-fusion, VQ image tokens live in the text
vocab (65536 covers text + image codes); the modality frontend is the VQ
tokenizer, which is a STUB per the assignment: input_specs feeds token ids
directly. Backbone: dense GQA transformer. [arXiv:2405.09818]"""
from ..models.lm.config import LMConfig

CONFIG = LMConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab=65536,
    layer_pattern=("global",), qkv_bias=False, norm="rmsnorm", act="swiglu",
    tie_embeddings=False,
)


def reduced() -> LMConfig:
    return CONFIG.replace(n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
                          d_ff=256, vocab=512, attn_chunk=64)
