"""Architecture registry: the 10 assigned archs + the paper's CNN zoo.

``get(arch_id)`` -> LMConfig; ``reduced(arch_id)`` -> smoke-test config.
Shape cells for the dry-run live in ``shapes.py``.
"""
from __future__ import annotations

import importlib

_ARCH_MODULES = {
    "chameleon-34b": "chameleon_34b",
    "command-r-35b": "command_r_35b",
    "gemma3-4b": "gemma3_4b",
    "qwen2.5-14b": "qwen25_14b",
    "starcoder2-15b": "starcoder2_15b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "whisper-medium": "whisper_medium",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "mamba2-2.7b": "mamba2_2_7b",
}

ARCHS = tuple(_ARCH_MODULES)

CNN_ARCHS = ("vgg16", "resnet18", "resnet56", "mobilenet")


def _mod(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(f".{_ARCH_MODULES[arch]}", __package__)


def get(arch: str):
    return _mod(arch).CONFIG


def reduced(arch: str):
    return _mod(arch).reduced()
