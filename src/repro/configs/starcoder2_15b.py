"""starcoder2-15b [dense] — GQA kv=4, RoPE, LayerNorm + GELU MLP (the
StarCoder2 family keeps the classic MLP). [arXiv:2402.19173]"""
from ..models.lm.config import LMConfig

CONFIG = LMConfig(
    name="starcoder2-15b", family="dense",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4,
    d_ff=24576, vocab=49152,
    layer_pattern=("global",), qkv_bias=True, norm="layernorm", act="gelu",
    tie_embeddings=True,
)


def reduced() -> LMConfig:
    return CONFIG.replace(n_layers=2, d_model=192, n_heads=8, n_kv_heads=2,
                          d_ff=384, vocab=512, attn_chunk=64)
