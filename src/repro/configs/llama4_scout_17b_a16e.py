"""llama4-scout-17b-a16e [moe] — 16 experts, top-1 routing, early fusion
(vision frontend stubbed per the assignment: token ids in).
[hf:meta-llama/Llama-4-Scout-17B-16E]"""
from ..models.lm.config import LMConfig

CONFIG = LMConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab=202048,
    layer_pattern=("global",), qkv_bias=False, norm="rmsnorm", act="swiglu",
    tie_embeddings=True,
    n_experts=16, top_k=1, capacity_factor=1.25,
)


def reduced() -> LMConfig:
    return CONFIG.replace(n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
                          d_ff=128, vocab=512, n_experts=4, top_k=1,
                          attn_chunk=64)
