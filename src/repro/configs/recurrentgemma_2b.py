"""recurrentgemma-2b [hybrid] — Griffin: 2 RG-LRU recurrent blocks : 1
local-attention block, window 2048. [arXiv:2402.19427]"""
from ..models.lm.config import LMConfig

CONFIG = LMConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
    d_ff=7680, vocab=256000,
    layer_pattern=("rglru", "rglru", "local"),
    window=2048, lru_dim=2560, conv_width=4,
    qkv_bias=False, norm="rmsnorm", act="swiglu", tie_embeddings=True,
)


def reduced() -> LMConfig:
    return CONFIG.replace(n_layers=3, d_model=128, n_heads=4, n_kv_heads=1,
                          d_ff=256, vocab=512, lru_dim=128, window=32,
                          attn_chunk=64)
