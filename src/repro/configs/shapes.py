"""The assigned input-shape cells (arch × shape grid; 40 cells).

``long_500k`` needs sub-quadratic attention: it runs only for the
SSM / hybrid / mostly-local archs and is SKIPPED for pure full-attention
archs (see DESIGN.md §4 — 7 skips, noted in the roofline table).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}

# archs whose attention stack is sub-quadratic enough for 500k decode
LONG_OK = ("gemma3-4b", "recurrentgemma-2b", "mamba2-2.7b")


def cells(arch: str):
    """All shape cells that run for `arch` (skips applied)."""
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and arch not in LONG_OK:
            continue
        out.append(s)
    return out


def skipped(arch: str):
    return [s for s in SHAPES.values()
            if s.name == "long_500k" and arch not in LONG_OK]
