"""Continuous-batching serving over a paged compressed-KV pool.

- :mod:`~repro.serve.bucket` — power-of-two shape ladders (the bounded
  compile-shape contract shared by both serve paths);
- :mod:`~repro.serve.scheduler` — host-side admission / preemption /
  retirement policy over plain :class:`Request` records;
- :mod:`~repro.serve.pool` — the paged store of compressed KV payload
  slabs (page in/out in ``(bitmap, payload)`` stream form, per-page
  Eq. 2/3 metering + ingest validation);
- :mod:`~repro.serve.engine` — the slotted decode loop tying them
  together (``launch.serve`` is a thin CLI over this).
"""
from .bucket import bucket_ladder, pow2_bucket, pow2_ceil, pow2_floor
from .engine import ServeEngine
from .pool import PagedKVPool
from .scheduler import Request, Scheduler, synthetic_trace

__all__ = ["ServeEngine", "PagedKVPool", "Request", "Scheduler",
           "synthetic_trace", "pow2_bucket", "pow2_ceil", "pow2_floor",
           "bucket_ladder"]
