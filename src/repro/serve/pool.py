"""Paged pool of compressed KV payload slabs.

The serving engine keeps only the in-flight lanes' caches dense (the
"hot" working set); everything else — freshly prefilled requests on
their way into a lane, and requests evicted under slot pressure — lives
here as Zebra ``(bitmap, payload)`` streams. A page is ``page_tokens``
consecutive cache positions of one leaf, flattened to ``(rows, Hkv*hd)``
exactly like ``attention.zebra_kv_site`` lays the cache out on the wire,
and compressed with the PR 3/5 payload-across-jit handoff primitive
(``compress.stream``): the pool IS the transport, so every page is
metered on the shared ``BandwidthMeter`` (Eq. 2/3 reconciliation per
page) and validated at ingest via ``compress.integrity`` — a corrupt
page degrades to a dense page, never the whole request.

Block sizing follows the ``ffn.eff_block_ch`` fallback idiom: reduced
configs whose ``Hkv*hd`` doesn't divide ``zebra_block_ch`` compress at
``bc = Hkv*hd`` instead of passing through dense — the stream stays a
stream at every scale.

Leaves without a page-divisible token axis (recurrent state, odd
shapes) are stored dense and metered as dense traffic.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..compress import BandwidthMeter, CompressedMap, compress, decompress
from ..compress.integrity import validate_level, validate_map
from ..ft.faults import CorruptStream
from ..ft.inject import STREAM_KINDS, active_plan, corrupt_map

PAGE_SITE = "page"          # ft.inject site label for page-ingest chaos


class _Slab:
    """One request's paged store: per-leaf page lists + reassembly info."""

    def __init__(self, treedef):
        self.treedef = treedef
        self.leaves: list[tuple[str, Any]] = []   # ("paged", [...]) | ("dense", arr)
        self.page_shapes: list[tuple[int, ...] | None] = []


class PagedKVPool:
    """Compressed page-in/page-out store keyed by request id.

    ``page_out(rid, caches)`` replaces any previous slab for ``rid`` —
    the stream is re-emitted (and re-metered: eviction traffic is real
    traffic). ``page_in(rid)`` decompresses the slab back to the dense
    per-request tree, bitwise-equal to what was paged out (modulo pages
    that failed ingest validation, which were kept dense and are
    therefore trivially bitwise-equal too).
    """

    def __init__(self, *, page_tokens: int = 16, bs: int = 8, bc: int = 128,
                 validation: str = "off", use_kernel: bool = False,
                 interpret: bool = True, breaker=None):
        if page_tokens & (page_tokens - 1) or page_tokens < 1:
            raise ValueError(f"page_tokens must be a power of two, got {page_tokens}")
        self.page_tokens = page_tokens
        self.bs, self.bc = bs, bc
        self.validation = validate_level(validation)
        self.use_kernel = use_kernel
        self.interpret = interpret
        self.breaker = breaker    # ft.breaker.BreakerBoard | None — the
                                  # page-ingest circuit: open means pages
                                  # skip compress+validate wholesale
        self.meter = BandwidthMeter()
        self._slabs: dict[Any, _Slab] = {}
        # jitted codecs keyed on (shape, dtype): after warmup every page
        # op is one cached dispatch — the page path never retraces
        self._enc: dict = {}
        self._dec: dict = {}
        self.n_pages_out = 0
        self.n_pages_in = 0
        self.n_recovered = 0      # corrupt pages kept dense at ingest
        self.n_breaker_dense = 0  # pages sent dense by an OPEN breaker
        self.bytes_out = 0        # stream bytes written to the pool
        self.bytes_in = 0         # stream bytes read back out

    # ------------------------------------------------------------------
    def _eff_blocks(self, m: int, k: int) -> tuple[int, int]:
        """eff_block_ch-style divisor fallback so pages compress even
        when the reduced head dims don't divide the configured blocks."""
        bs = self.bs if m % self.bs == 0 else 1
        bc = self.bc if k % self.bc == 0 else k
        return bs, bc

    def _encode(self, page2d: jax.Array) -> CompressedMap:
        key = (tuple(page2d.shape), str(page2d.dtype))
        fn = self._enc.get(key)
        if fn is None:
            bs, bc = self._eff_blocks(*page2d.shape)
            fn = jax.jit(functools.partial(
                compress, bs=bs, bc=bc, use_kernel=self.use_kernel,
                interpret=self.interpret,
                checksum=(self.validation == "checksum")))
            self._enc[key] = fn
        return fn(page2d)

    def _decode(self, cm: CompressedMap) -> jax.Array:
        key = (tuple(cm.payload.shape), str(cm.payload.dtype), cm.bs, cm.bc)
        fn = self._dec.get(key)
        if fn is None:
            fn = jax.jit(functools.partial(
                decompress, use_kernel=self.use_kernel,
                interpret=self.interpret))
            self._dec[key] = fn
        return fn(cm)

    @staticmethod
    def _pageable(leaf) -> bool:
        """Attn cache leaves: (..., B, T, Hkv, hd) with T at axis -3 (the
        model_prefill_pad convention)."""
        return (hasattr(leaf, "ndim") and leaf.ndim >= 4
                and jnp.issubdtype(leaf.dtype, jnp.floating))

    # ------------------------------------------------------------------
    def page_out(self, rid, caches) -> None:
        """Compress a per-request cache tree into the slab store. The
        ingest boundary: an armed chaos plan (``ft.inject``) with a
        stream fault at site ``"page"`` corrupts pages here — after
        compression, before validation — and a page that fails
        ``validate_map`` is kept dense (per-page fallback)."""
        leaves, treedef = jax.tree_util.tree_flatten(caches)
        slab = _Slab(treedef)
        plan = active_plan()
        pt = self.page_tokens
        for i, leaf in enumerate(leaves):
            T = leaf.shape[-3] if self._pageable(leaf) else 0
            if not T or T % pt:
                slab.leaves.append(("dense", jnp.asarray(leaf)))
                slab.page_shapes.append(None)
                nbytes = int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
                self.meter.record_dense(f"req{rid}/leaf{i}", nbytes)
                self.bytes_out += nbytes
                continue
            k = int(np.prod(leaf.shape[-2:]))
            pages = []
            page_shape = leaf.shape[:-3] + (pt,) + leaf.shape[-2:]
            ax = leaf.ndim - 3
            for p in range(T // pt):
                page = jax.lax.slice_in_dim(leaf, p * pt, (p + 1) * pt, axis=ax)
                name = f"req{rid}/leaf{i}/pg{p}"
                if self.breaker is not None \
                        and not self.breaker.allow(PAGE_SITE):
                    # circuit OPEN: the compressed path at this boundary
                    # is sick — dense wholesale, skipping compress AND
                    # the per-page validate+fallback entirely (armed
                    # chaos faults stay armed: nothing fires on a path
                    # that never runs)
                    dense = jnp.asarray(page)
                    pages.append(dense)
                    nbytes = int(dense.size) * dense.dtype.itemsize
                    self.meter.record_dense(f"{name}+breaker-open", nbytes)
                    self.bytes_out += nbytes
                    self.n_breaker_dense += 1
                    continue
                cm = self._encode(page.reshape(-1, k))
                if plan is not None:
                    f = plan.take(STREAM_KINDS, PAGE_SITE)
                    if f is not None:
                        cm = corrupt_map(cm, f.kind, arg=f.arg)
                        plan.note(f.kind, PAGE_SITE)
                try:
                    validate_map(cm, level=self.validation,
                                 site=f"{PAGE_SITE}:{name}")
                except CorruptStream as e:
                    # per-page dense fallback: ONE page degrades, the
                    # request's other pages stay compressed — and the
                    # breaker counts the detection toward its trip window
                    if self.breaker is not None:
                        self.breaker.record_failure(PAGE_SITE)
                    self.n_recovered += 1
                    print(f"[pool] {e} — page kept dense")
                    dense = jnp.asarray(page)
                    pages.append(dense)
                    nbytes = int(dense.size) * dense.dtype.itemsize
                    self.meter.record_dense(name, nbytes)
                    self.bytes_out += nbytes
                    continue
                if self.breaker is not None and self.validation != "off":
                    self.breaker.record_success(PAGE_SITE)
                rec = self.meter.record(name, cm)
                self.bytes_out += rec.measured_bytes
                self.n_pages_out += 1
                pages.append(cm)
            slab.leaves.append(("paged", pages))
            slab.page_shapes.append(page_shape)
        self._slabs[rid] = slab

    def page_in(self, rid):
        """Slab -> dense per-request cache tree (bitwise round trip)."""
        slab = self._slabs[rid]
        out = []
        for (kind, stored), pshape in zip(slab.leaves, slab.page_shapes):
            if kind == "dense":
                out.append(stored)
                self.bytes_in += int(stored.size) * stored.dtype.itemsize
                continue
            parts = []
            for page in stored:
                if isinstance(page, CompressedMap):
                    parts.append(self._decode(page).reshape(pshape))
                    self.bytes_in += page.measured_bytes()
                    self.n_pages_in += 1
                else:                      # dense-fallback page
                    parts.append(page)
                    self.bytes_in += int(page.size) * page.dtype.itemsize
            out.append(jnp.concatenate(parts, axis=len(pshape) - 3))
        return jax.tree_util.tree_unflatten(slab.treedef, out)

    # ------------------------------------------------------------------
    def free(self, rid) -> None:
        self._slabs.pop(rid, None)

    def __contains__(self, rid) -> bool:
        return rid in self._slabs

    def request_bytes(self, rid) -> dict:
        """Per-request KV traffic: measured stream bytes vs the Eq. 2/3
        prediction at each page's measured zero fraction vs dense, plus
        the compressed-page count (the index-padding reconcile bound
        scales with it)."""
        prefix = f"req{rid}/"
        recs = [r for r in self.meter.records if r.site.startswith(prefix)]
        return {
            "measured": sum(r.measured_bytes for r in recs),
            "predicted": sum(r.predicted_bytes for r in recs),
            "dense": sum(r.dense_bytes for r in recs),
            "pages": sum(1 for r in recs if r.compressed),
        }

    def zero_frac(self) -> float:
        """Block-weighted zero fraction across every compressed page."""
        live = sum(r.n_live for r in self.meter.records)
        blocks = sum(r.n_blocks for r in self.meter.records)
        return 1.0 - live / blocks if blocks else 0.0
