"""Power-of-two shape bucketing for the serving paths.

Every jitted dispatch is keyed on its operand shapes, so admission-time
variability (prompt lengths, in-flight counts, cache growth) must be
quantized or the hot path recompiles per request. All serve-side shape
choices go through these helpers so the ladder — and therefore the
total number of compiled dispatch shapes — is computable up front and
asserted, not observed (serve/engine.py raises on any shape outside its
declared ladder).
"""
from __future__ import annotations


def pow2_ceil(n: int) -> int:
    """Smallest power of two >= n (n >= 1)."""
    n = int(n)
    if n < 1:
        raise ValueError(f"pow2_ceil needs n >= 1, got {n}")
    return 1 << (n - 1).bit_length()


def pow2_floor(n: int) -> int:
    """Largest power of two <= n (n >= 1)."""
    n = int(n)
    if n < 1:
        raise ValueError(f"pow2_floor needs n >= 1, got {n}")
    return 1 << (n.bit_length() - 1)


def pow2_bucket(n: int, lo: int = 8, hi: int | None = None) -> int:
    """Quantize ``n`` up to the power-of-two ladder clamped at ``lo``:
    the shape a jitted dispatch is compiled for. With ``hi``, values
    above the ladder top are an error — the caller must reject (serve)
    or split (paging) instead of silently growing the shape set."""
    b = max(pow2_ceil(max(n, 1)), pow2_ceil(lo))
    if hi is not None:
        top = pow2_ceil(hi)
        if b > top:
            raise ValueError(f"{n} exceeds the bucket ladder top {top}")
    return b


def bucket_ladder(lo: int, hi: int) -> tuple[int, ...]:
    """Every bucket pow2_bucket(·, lo, hi) can return — the full dispatch
    ladder [pow2_ceil(lo) .. pow2_ceil(hi)]."""
    b = pow2_ceil(lo)
    out = [b]
    while b < pow2_ceil(hi):
        b *= 2
        out.append(b)
    return tuple(out)
