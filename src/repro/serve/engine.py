"""Continuous-batching serving engine over a paged compressed-KV pool.

One engine = one model + one hot working set: a dense batched cache at
bucketed shape ``(Bb, C)`` whose lanes are in-flight requests at
*different* sequence positions, advanced together by the slotted decode
step (``steps.make_decode_slotted`` — vector ``pos``). Everything not in
a lane lives in the :class:`~repro.serve.pool.PagedKVPool` as compressed
payload slabs; admission and eviction are page-in/page-out in stream
form.

Bounded dispatch shapes — asserted, not observed
------------------------------------------------
The decode hot path may only be compiled at ``(Bb, C)`` pairs from the
declared power-of-two ladders (``batch_ladder`` x ``cache_ladder``) and
prefill only at prompt buckets from ``prefill_ladder``; any other shape
raises *before* tracing. Cache length only grows (grow-only C keeps
page-in padding one-directional), and local-attention rings stay at
``T == window`` because the cache ladder starts at
``pow2_ceil(window)`` — so a page written at one bucket reads back
bitwise at any later bucket.

Chunked admission
-----------------
Prompts are never padded (padding would poison cache positions the
decode mask can't hide). A request prefills its largest power-of-two
prefix ``Pb = pow2_floor(P)`` in one exact-shape dispatch, and the
remaining ``P - Pb`` prompt tokens ride the normal slotted decode as
teacher-forced steps (output discarded) — mixed prefill/decode
continuous batching. When ``Pb == P`` the last prompt token is replayed
at ``pos = P - 1`` (rewriting its own KV with the identical value) to
produce the first sampled token; prompts shorter than the smallest
prefill bucket skip prefill entirely and teacher-force from ``pos 0``.

Resilience (PR 10)
------------------
``run(..., ft_cfg=FTConfig(...))`` supervises the tick loop with the
same classify/backoff/decay policy as the training supervisor
(``ft.supervisor.FailurePolicy``): each tick starts from a snapshot
(every lane paged out to the pool + a deep copy of the host
bookkeeping), and a classified crash (``ft.inject.crash_tap`` at site
``"engine_tick"``) restores the snapshot and re-admits the in-flight
requests from their already-paged compressed KV — generated tokens are
kept, not replayed, and greedy decoding makes the recovered run
token-identical to an un-crashed one. Deadlines (``Request.deadline``)
are enforced at admission (shed what cannot finish in time) and
mid-flight (cancel a lane past its TTL); the pending queue is bounded
by ``queue_bound`` with overload shedding; and a per-site
:class:`~repro.ft.breaker.BreakerBoard` trips persistently-corrupt
stream boundaries (page ingest) to their dense path wholesale.
"""
from __future__ import annotations

import functools
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..ft.breaker import BreakerBoard, BreakerConfig
from ..ft.faults import classify as ft_classify
from ..ft.inject import crash_tap
from ..ft.supervisor import FailurePolicy, FTConfig
from ..launch.steps import make_decode_slotted, make_prefill
from ..models.lm import LM
from .bucket import bucket_ladder, pow2_bucket, pow2_ceil, pow2_floor
from .pool import PagedKVPool
from .scheduler import Request, Scheduler


def _tree_map(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


class ServeEngine:
    DONATE_ARGNUMS = (2,)     # the dense hot state — pool owns the slabs

    def __init__(self, model: LM, params, mesh, *, n_slots: int = 4,
                 max_cache_len: int = 256, page_tokens: int = 16,
                 min_prefill: int = 8, validation: str = "off",
                 temperature: float = 0.0, seed: int = 0,
                 use_kernel_codec: bool = False, queue_bound: int = 0,
                 max_hot_positions: int = 0,
                 breaker: BreakerConfig | None = None):
        cfg = model.cfg
        if cfg.encoder_layers:
            raise NotImplementedError("ServeEngine serves decoder-only "
                                      "stacks (no encoder cross-attention)")
        for pattern, _ in model.runs:
            bad = [t for t in pattern if t not in ("global", "local")]
            if bad:
                raise NotImplementedError(
                    f"ServeEngine pages attention caches only; layer types "
                    f"{bad} carry recurrent state (see ROADMAP follow-ons)")
        has_local = any("local" in p for p, _ in model.runs)
        if has_local and (cfg.window & (cfg.window - 1)):
            raise ValueError(f"window {cfg.window} must be a power of two "
                             "so ring slots align across prefill buckets")
        self.model, self.params, self.mesh = model, params, mesh
        self.cfg = cfg
        self.n_slots = n_slots
        self.temperature = temperature
        self._root_key = jax.random.PRNGKey(seed)

        # --- bucketed dispatch ladders (the compile-shape contract) ---
        c_lo = pow2_ceil(max(cfg.window if has_local else 1, page_tokens))
        self.c_lo = c_lo
        self.batch_ladder = bucket_ladder(1, n_slots)
        self.cache_ladder = bucket_ladder(c_lo, max(max_cache_len, c_lo))
        self.p_lo = min_prefill
        self.prefill_ladder = bucket_ladder(
            min_prefill, max(pow2_floor(self.cache_ladder[-1] - 1),
                             min_prefill))
        self.decode_shape_bound = len(self.batch_ladder) * len(self.cache_ladder)

        # resilience knobs: bounded pending queue (0 = unbounded), hot-set
        # position budget Bb*C (0 = unbounded; drives the "later" fits
        # verdict), and the per-boundary circuit breaker board the pool
        # consults at page ingest
        self.queue_bound = queue_bound
        self.max_hot_positions = max_hot_positions
        self.board = BreakerBoard(breaker)
        self.crash_recoveries = 0
        self._supervised = False
        self._deferred_free: list = []

        self.pool = PagedKVPool(page_tokens=page_tokens,
                                bs=cfg.zebra_block_seq, bc=cfg.zebra_block_ch,
                                validation=validation,
                                use_kernel=use_kernel_codec,
                                breaker=self.board)
        self._prefill = jax.jit(make_prefill(model, mesh))
        self._decode = jax.jit(make_decode_slotted(model, mesh, temperature),
                               donate_argnums=self.DONATE_ARGNUMS)
        self._decode_shapes: set[tuple[int, int]] = set()
        self._prefill_shapes: set[int] = set()

        # per-leaf batch axis of the cache tree (leaves are (B, ...) or,
        # under a scanned run, (count, B, ...)): diff two abstract inits
        a = jax.eval_shape(functools.partial(model.init_cache, 3, c_lo))
        b = jax.eval_shape(functools.partial(model.init_cache, 5, c_lo))

        def _axis(sa, sb):
            d = [i for i, (x, y) in enumerate(zip(sa.shape, sb.shape)) if x != y]
            assert len(d) == 1, (sa.shape, sb.shape)
            return d[0]
        self._baxes = _tree_map(_axis, a, b)

        # --- hot working set ---
        self._Bb = self.batch_ladder[0]
        self._C = self.cache_ladder[0]
        self._hot = model.init_cache(self._Bb, self._C)
        self._lanes: list[Request | None] = [None] * self._Bb
        self._step_no = 0
        self.scheduler: Scheduler | None = None

    # ------------------------------------------------------------------
    # lane surgery (host-side, between steps — never on the hot path)
    # ------------------------------------------------------------------
    def _take_lane(self, lane: int):
        return _tree_map(
            lambda x, a: jax.lax.slice_in_dim(x, lane, lane + 1, axis=a),
            self._hot, self._baxes)

    def _set_lane(self, hot, lane: int, sub):
        def one(x, a, s):
            idx = [slice(None)] * x.ndim
            idx[a] = slice(lane, lane + 1)
            return x.at[tuple(idx)].set(s.astype(x.dtype))
        return _tree_map(one, hot, self._baxes, sub)

    def _place(self, hot, lane: int, r: Request, sub, lanes) -> Any:
        hot = self._set_lane(hot, lane, sub)
        lanes[lane] = r
        return hot

    def _pad_like(self, sub, C: int):
        """Zero-pad a per-request tree (from prefill or page-in at an
        older, smaller bucket) up to this engine's lane shapes at cache
        bucket ``C``. End-padding is position-correct: global caches are
        position-indexed and rings stay at T == window."""
        ref = jax.eval_shape(functools.partial(self.model.init_cache, 1, C))

        def one(s, r):
            if s.shape == r.shape:
                return s
            assert all(a <= b for a, b in zip(s.shape, r.shape)), \
                (s.shape, r.shape)
            pad = [(0, b - a) for a, b in zip(s.shape, r.shape)]
            return jnp.pad(s, pad)
        return _tree_map(one, sub, ref)

    # ------------------------------------------------------------------
    # admission / eviction
    # ------------------------------------------------------------------
    def _req_cache_bucket(self, r: Request) -> int:
        return pow2_bucket(max(r.total_len, self.c_lo), lo=self.c_lo,
                           hi=self.cache_ladder[-1])

    def _fits(self, r: Request, n_active: int | None = None) -> str:
        """Admission verdict: ``"never"`` = this engine can never cache
        the request (empty prompt / total beyond the ladder — terminal
        reject); ``"later"`` = admitting it NOW would blow the hot-set
        position budget ``max_hot_positions`` (lanes x cache bucket), a
        transient condition that clears as lanes retire — the scheduler
        keeps it queued; ``"ok"`` otherwise."""
        if r.prompt_len < 1:
            return "never"
        try:
            Cr = self._req_cache_bucket(r)
        except ValueError:
            return "never"
        if self.max_hot_positions > 0:
            if n_active is None:
                n_active = sum(x is not None for x in self._lanes)
            C = max(self._C, Cr)               # grow-only cache bucket
            Bb = pow2_bucket(max(n_active + 1, 1), lo=1, hi=self.n_slots)
            if Bb * C > self.max_hot_positions:
                # infeasible even alone -> never (C never shrinks here,
                # so waiting can't help); otherwise genuinely transient
                if n_active == 0:
                    return "never"
                return "later"
        return "ok"

    def _min_ticks(self, r: Request) -> int:
        """Minimum engine ticks to finish ``r`` if admitted right now —
        the slot clock the deadline-aware admission measures against
        (teacher-forced tail + decode, no queueing or preemption)."""
        if r.pos > 0:                          # resuming paged progress
            return max(r.total_len - 1 - r.pos, 0)
        fed = min(self._prefill_bucket(r.prompt_len), r.prompt_len - 1)
        return max(r.total_len - 1 - fed, 0)

    def _prefill_bucket(self, P: int) -> int:
        pb = pow2_floor(P)
        return pb if pb >= self.p_lo else 0

    def _admit_tree(self, r: Request):
        """Prefill (first admission) or page-in (re-admission after
        eviction) one request; returns its per-request cache tree. Either
        way the caches cross the engine boundary in stream form — fresh
        prefills round-trip through the pool so page ingest validation
        and byte metering cover admission traffic too."""
        if r.rid in self.pool and r.pos > 0:   # evicted/crashed: resume
            # the pos > 0 guard matters after a crash restore: a request
            # that was rolled back to before its first step may still
            # have a post-snapshot slab in the pool, but its restored
            # next_tok/fed bookkeeping belongs to the fresh-prefill path
            return self.pool.page_in(r.rid)
        P = r.prompt_len
        pb = self._prefill_bucket(P)
        if pb:
            if pb not in self.prefill_ladder:
                raise RuntimeError(f"prefill bucket {pb} outside ladder "
                                   f"{self.prefill_ladder}")
            self._prefill_shapes.add(pb)
            prompt = jnp.asarray(r.prompt[:pb], jnp.int32)[None, :]
            _, (caches, _), _ = self._prefill(self.params, prompt)
        else:                                  # short prompt: decode-only
            caches = self.model.init_cache(1, self.c_lo)
        r.fed = min(pb, P - 1)                 # Pb == P replays last token
        r.pos = r.fed
        r.next_tok = int(r.prompt[r.fed])
        # pad to the ladder floor BEFORE paging out: prefill buckets below
        # page_tokens would otherwise fall to the dense leaf path — padded,
        # admission traffic rides the stream like eviction traffic (the
        # zero tail is all dead blocks, nearly free on the wire)
        self.pool.page_out(r.rid, self._pad_like(caches, self.c_lo))
        return self.pool.page_in(r.rid)

    def _evict(self, lane: int, tick: int) -> None:
        r = self._lanes[lane]
        self.pool.page_out(r.rid, self._take_lane(lane))
        self._lanes[lane] = None
        self.scheduler.preempt(r, tick)

    # ------------------------------------------------------------------
    def _schedule(self, tick: int, now: float) -> None:
        sched = self.scheduler
        for lane, r in enumerate(self._lanes):
            if r is not None and sched.should_preempt(r):
                self._evict(lane, tick)
        n_active = sum(r is not None for r in self._lanes)
        pending_admits = {"n": 0}

        def fits(r):
            # sequential admits within one tick see the growing batch
            v = self._fits(r, n_active + pending_admits["n"])
            if v == "ok":
                pending_admits["n"] += 1
            return v
        admitted = sched.admit(tick, self.n_slots - n_active, fits,
                               eta=self._min_ticks)
        for r in admitted:
            r.t_submit = r.t_submit or now
        new_active = [r for r in self._lanes if r is not None] + admitted
        Bb = pow2_bucket(max(len(new_active), 1), lo=1, hi=self.n_slots)
        C = self._C
        for r in admitted:
            C = max(C, self._req_cache_bucket(r))
        if Bb == self._Bb and C == self._C:
            free = [i for i, r in enumerate(self._lanes) if r is None]
            for lane, r in zip(free, admitted):
                sub = self._pad_like(self._admit_tree(r), C)
                self._hot = self._place(self._hot, lane, r, sub, self._lanes)
            return
        # bucket change: rebuild the hot set at (Bb, C), carrying lanes
        assert Bb in self.batch_ladder and C in self.cache_ladder, (Bb, C)
        carried = [(r, self._pad_like(self._take_lane(lane), C))
                   for lane, r in enumerate(self._lanes) if r is not None]
        hot = self.model.init_cache(Bb, C)
        lanes: list[Request | None] = [None] * Bb
        self._Bb, self._C = Bb, C
        for lane, (r, sub) in enumerate(carried + [(r, None) for r in admitted]):
            if sub is None:
                sub = self._pad_like(self._admit_tree(r), C)
            hot = self._place(hot, lane, r, sub, lanes)
        self._hot, self._lanes = hot, lanes

    # ------------------------------------------------------------------
    def _step(self, now: float) -> float:
        """One slotted decode dispatch across every lane. Returns the
        post-sync wall clock."""
        key = (self._Bb, self._C)
        if key not in self._decode_shapes:
            if self._Bb not in self.batch_ladder \
                    or self._C not in self.cache_ladder:
                raise RuntimeError(f"decode dispatch shape {key} outside "
                                   f"the bucketed ladder")
            self._decode_shapes.add(key)
            if len(self._decode_shapes) > self.decode_shape_bound:
                raise RuntimeError("decode dispatch shape count exceeded "
                                   f"its bound {self.decode_shape_bound}")
        tok = jnp.asarray(
            [[r.next_tok if r else 0] for r in self._lanes], jnp.int32)
        pos = jnp.asarray(
            [r.pos if r else 0 for r in self._lanes], jnp.int32)
        step_key = jax.random.fold_in(self._root_key, self._step_no)
        self._step_no += 1
        nxt, (caches, _) = self._decode(self.params, tok, (self._hot, None),
                                        pos, step_key)
        self._hot = caches
        nxt_host = np.asarray(nxt)[:, 0]       # device sync
        now = time.time()
        for lane, r in enumerate(self._lanes):
            if r is None:
                continue
            r.slot_steps += 1
            r.pos += 1
            if r.pos < r.prompt_len:           # teacher-forced prompt tail
                r.next_tok = int(r.prompt[r.pos])
                continue
            t = int(nxt_host[lane])
            r.out.append(t)
            r.next_tok = t
            r.token_times.append(now)
            if not r.t_first:
                r.t_first = now
        return now

    def _free_slab(self, rid) -> None:
        """Free a request's pool slab — deferred while supervised: a
        restore to the last snapshot rolls back post-snapshot retires
        and cancels, and their slabs must still be there to resume
        from. Deferred frees flush at the next snapshot (by then any
        restore lands at or after it) or at end of run."""
        if self._supervised:
            self._deferred_free.append(rid)
        else:
            self.pool.free(rid)

    def _retire(self, now: float) -> None:
        for lane, r in enumerate(self._lanes):
            if r is not None and r.done:
                r.t_done = now
                self.scheduler.retire(r)
                self._free_slab(r.rid)
                self._lanes[lane] = None

    def _cancel_deadlines(self, tick: int) -> None:
        """Mid-flight SLO enforcement: a lane past its TTL is cancelled
        (shed with reason ``"deadline"``) — finishing it late serves
        nobody and starves requests that can still meet theirs."""
        for lane, r in enumerate(self._lanes):
            if r is not None and r.deadline is not None \
                    and tick > r.deadline and not r.done:
                self._lanes[lane] = None
                self._free_slab(r.rid)
                self.scheduler.shed(r, "deadline")

    # ------------------------------------------------------------------
    # crash-recovery snapshots
    # ------------------------------------------------------------------
    def _snapshot(self, tick: int) -> dict:
        """Consistent restore point as of the START of ``tick``: every
        lane paged out to the pool (compressed, metered — snapshot
        traffic is real traffic) + a deep copy of the host bookkeeping.
        Lanes keep running from the dense hot set; the paged copy is
        only read back on restore."""
        for rid in self._deferred_free:       # committed: restores from
            self.pool.free(rid)               # now on land at >= this tick
        self._deferred_free.clear()
        for lane, r in enumerate(self._lanes):
            if r is not None:
                self.pool.page_out(r.rid, self._take_lane(lane))
        return {"tick": tick, "step_no": self._step_no,
                "Bb": self._Bb, "C": self._C,
                "lanes": [r.rid if r is not None else None
                          for r in self._lanes],
                "sched": self.scheduler.snapshot()}

    def _restore(self, snap: dict) -> int:
        """Rebuild the engine at the snapshot: fresh hot set, restored
        bookkeeping, and every formerly-running lane requeued at the
        FRONT of the queue (in lane order) — re-admission then flows
        through ``_admit_tree``'s pool-resume path, so recovery reuses
        the same page-in machinery as preemption. Tokens generated
        before the snapshot are kept, not replayed. Returns the tick to
        resume at."""
        self.scheduler.restore(snap["sched"])
        self._step_no = snap["step_no"]
        self._Bb, self._C = snap["Bb"], snap["C"]
        self._hot = self.model.init_cache(self._Bb, self._C)
        self._lanes = [None] * self._Bb
        self._deferred_free.clear()           # those retires rolled back
        self.crash_recoveries += 1
        inflight = [rid for rid in snap["lanes"] if rid is not None]
        for rid in reversed(inflight):        # appendleft: keep lane order
            r = self.scheduler._all[rid]
            r.retries += 1
            if r.retries > r.retry_budget:
                self.pool.free(rid)
                self.scheduler.shed(r, "retry-budget")
                continue
            r.recovered = True
            self.scheduler.requeue_front(r)
        return snap["tick"]

    # ------------------------------------------------------------------
    def run(self, requests: list[Request], *, preempt_after: int = 0,
            ft_cfg: FTConfig | None = None, snapshot_every: int = 1) -> dict:
        """Serve a trace to completion; returns the throughput report.

        With ``ft_cfg`` the loop is supervised: snapshots every
        ``snapshot_every`` ticks, and a classified failure (e.g. an
        injected ``crash`` at site ``"engine_tick"``) restores the last
        snapshot after a jittered backoff instead of killing the run —
        bounded by ``ft_cfg.max_failures`` exactly like the training
        supervisor. Shed-policy classes are logged, never counted."""
        self.scheduler = Scheduler(requests, preempt_after=preempt_after,
                                   queue_bound=self.queue_bound)
        policy = FailurePolicy(ft_cfg) if ft_cfg is not None else None
        self._supervised = policy is not None
        self._deferred_free = []
        self.crash_recoveries = 0
        snap: dict | None = None
        snap_tick = -1
        tick = 0
        # the board clock is engine-lifetime monotone (advance() keeps
        # the max) but ticks restart per run — offset by the clock as of
        # this run's start so probe deadlines scheduled in an earlier
        # run (or its warmup) stay reachable
        board_base = self.board.now
        t0 = now = time.time()
        while True:
            try:
                if policy is not None and tick != snap_tick \
                        and tick % max(snapshot_every, 1) == 0:
                    snap = self._snapshot(tick)
                    snap_tick = tick
                crash_tap(tick)
                self.board.advance(board_base + tick)
                self._cancel_deadlines(tick)
                self._schedule(tick, now)
                # bound the queue AFTER admission: what this tick's free
                # slots absorbed was never "pending" — a burst no wider
                # than the slots + bound must not shed at all
                self.scheduler.shed_overflow(tick)
                if not any(r is not None for r in self._lanes):
                    nxt = self.scheduler.next_arrival()
                    if nxt is None:
                        break
                    tick = max(tick + 1, nxt)  # idle until the next arrival
                    continue
                now = self._step(now)
                self._retire(now)
                if policy is not None:
                    policy.note_success()
                tick += 1
            except Exception as e:  # noqa: BLE001 — classified below
                if policy is None:
                    raise
                cls = ft_classify(e)
                if cls is None:
                    raise                      # a bug, not a fault
                pol = policy.record(cls, tick, e)
                if pol == "shed":
                    continue                   # already shed by the scheduler
                if not policy.count() or snap is None:
                    raise                      # budget exhausted / no restore
                delay = policy.backoff()
                if delay:
                    time.sleep(delay)
                tick = self._restore(snap)
                snap_tick = tick               # snap still valid for this tick
                continue
        for rid in self._deferred_free:
            self.pool.free(rid)
        self._deferred_free.clear()
        self._supervised = False
        wall = time.time() - t0
        return self.report(wall)

    # ------------------------------------------------------------------
    def report(self, wall: float) -> dict:
        # raises if any page's measured bytes leave the Eq. 2/3
        # index-padding bound — the per-page reconcile is load-bearing
        rec = self.pool.meter.reconcile(tol_bytes_per_map=1.0)
        done = [r for r in self.scheduler.completed if r.status == "done"]
        deltas = []
        for r in done:
            prev = r.t_submit
            for t in r.token_times:
                deltas.append(t - prev)
                prev = t
        deltas = np.asarray(sorted(deltas)) if deltas else np.zeros(1)
        kv = {"measured": 0, "predicted": 0.0, "dense": 0, "pages": 0}
        for r in done:
            rb = self.pool.request_bytes(r.rid)
            for k in kv:
                kv[k] += rb[k]
        n_tok = sum(len(r.out) for r in done)
        total = max(len(self.scheduler._all), 1)
        sched = self.scheduler
        return {
            "n_requests": len(done),
            "n_rejected": sum(1 for r in self.scheduler.completed
                              if r.status == "rejected"),
            # --- resilience (SLOs, crash recovery, breaker) ---
            "n_shed": sched.n_shed,
            "shed_frac": sched.n_shed / total,
            "deadline_misses": sched.deadline_misses,
            "deadline_miss_frac": sched.deadline_misses / total,
            "deferrals": sched.deferrals,
            "retries": sum(r.retries for r in sched._all.values()),
            "crash_recoveries": self.crash_recoveries,
            "recovered_requests": sum(1 for r in done if r.recovered),
            "breaker_trips": self.board.trips,
            "breaker_probes": self.board.probes,
            "breaker_tripped_sites": self.board.tripped_sites(),
            "breaker_labels": self.board.labels(),
            "breakers": self.board.snapshot(),
            "pages_breaker_dense": self.pool.n_breaker_dense,
            # --- throughput / latency / bytes ---
            "wall_s": wall,
            "requests_per_s": len(done) / wall if wall else 0.0,
            "tokens_per_s": n_tok / wall if wall else 0.0,
            "tokens": n_tok,
            "steps": self._step_no,
            "p50_token_ms": float(np.percentile(deltas, 50) * 1e3),
            "p95_token_ms": float(np.percentile(deltas, 95) * 1e3),
            "evictions": self.scheduler.evictions,
            "kv_bytes_measured": int(kv["measured"]),
            "kv_bytes_predicted": float(kv["predicted"]),
            "kv_bytes_dense": int(kv["dense"]),
            "kv_pages": int(kv["pages"]),
            "pages_recovered": self.pool.n_recovered,
            "zero_frac": self.pool.zero_frac(),
            "decode_shapes": len(self._decode_shapes),
            "decode_shape_bound": self.decode_shape_bound,
            "prefill_shapes": len(self._prefill_shapes),
            "prefill_shape_bound": len(self.prefill_ladder),
            "reconcile_max_delta_bytes": rec["max_abs_delta_bytes"],
        }
