"""Request admission, preemption and retirement for the serving engine.

Everything here is host-side policy over plain Python state — the
scheduler never touches device arrays. The engine asks it three
questions per step: who newly fits in a free slot (FCFS over arrived
requests), who must be preempted (round-robin fairness under slot
pressure: a lane that has held its slot ``preempt_after`` consecutive
steps while others wait is evicted to the compressed pool and requeued),
and who is done (EOS or ``max_new`` reached).
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np


@dataclasses.dataclass
class Request:
    """One serving request plus its host-side decode bookkeeping.

    ``pos`` is the cache position the next step writes; ``fed`` counts
    prompt tokens whose KV is final in the cache. Until ``pos`` reaches
    ``prompt_len`` the lane is teacher-forced (chunked-prefill tail: the
    next input token comes from the prompt and the step's output is
    discarded); from there on the model's own tokens feed back."""
    rid: int
    prompt: np.ndarray              # (P,) int32
    max_new: int
    arrival: int = 0                # engine tick at which it becomes visible
    eos_token: int | None = None
    # --- runtime ---
    out: list = dataclasses.field(default_factory=list)
    next_tok: int = 0
    pos: int = 0
    fed: int = 0                    # prompt tokens with final KV in cache
    status: str = "waiting"         # waiting | running | done
    slot_steps: int = 0             # consecutive steps in-slot (preempt clock)
    evictions: int = 0
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0
    token_times: list = dataclasses.field(default_factory=list)

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def total_len(self) -> int:
        """Cache positions the request needs end to end."""
        return self.prompt_len + self.max_new

    @property
    def done(self) -> bool:
        if len(self.out) >= self.max_new:
            return True
        return (self.eos_token is not None and len(self.out) > 0
                and self.out[-1] == self.eos_token)


def synthetic_trace(n_requests: int, *, vocab: int, seed: int = 0,
                    prompt_lo: int = 8, prompt_hi: int = 48,
                    gen_lo: int = 8, gen_hi: int = 32,
                    arrival_every: int = 0) -> list[Request]:
    """Deterministic heavy-traffic trace: ``n_requests`` requests with
    varying prompt/gen lengths. ``arrival_every`` staggers arrivals every
    N engine steps (0 = all arrive at tick 0 — a burst)."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        plen = int(rng.integers(prompt_lo, prompt_hi + 1))
        gen = int(rng.integers(gen_lo, gen_hi + 1))
        prompt = rng.integers(1, vocab, size=plen).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt, max_new=gen,
                            arrival=i * arrival_every))
    return reqs


class Scheduler:
    """FCFS admission with optional round-robin preemption."""

    def __init__(self, requests: list[Request], *, preempt_after: int = 0):
        self.waiting: deque[Request] = deque(
            sorted(requests, key=lambda r: (r.arrival, r.rid)))
        self.preempt_after = preempt_after
        self.evictions = 0
        self.completed: list[Request] = []

    # ------------------------------------------------------------------
    def pending(self) -> int:
        return len(self.waiting)

    def next_arrival(self) -> int | None:
        return self.waiting[0].arrival if self.waiting else None

    def admit(self, tick: int, free_slots: int,
              fits=lambda r: True) -> list[Request]:
        """Pop up to ``free_slots`` arrived requests, FCFS. ``fits``
        vetoes requests the engine can't cache (too long for the
        ladder) — they are dropped with a visible status."""
        admitted = []
        while self.waiting and free_slots > 0 \
                and self.waiting[0].arrival <= tick:
            r = self.waiting.popleft()
            if not fits(r):
                r.status = "rejected"
                self.completed.append(r)
                continue
            r.status = "running"
            r.slot_steps = 0
            admitted.append(r)
            free_slots -= 1
        return admitted

    def should_preempt(self, r: Request) -> bool:
        """Evict a lane that has monopolized its slot while others wait."""
        return (self.preempt_after > 0 and r.slot_steps >= self.preempt_after
                and bool(self.waiting))

    def preempt(self, r: Request, tick: int) -> None:
        r.status = "waiting"
        r.slot_steps = 0
        r.evictions += 1
        r.arrival = tick                # back of the arrived queue
        self.evictions += 1
        self.waiting.append(r)

    def retire(self, r: Request) -> None:
        r.status = "done"
        self.completed.append(r)
