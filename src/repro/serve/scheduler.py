"""Request admission, preemption and retirement for the serving engine.

Everything here is host-side policy over plain Python state — the
scheduler never touches device arrays. The engine asks it three
questions per step: who newly fits in a free slot (FCFS over arrived
requests), who must be preempted (round-robin fairness under slot
pressure: a lane that has held its slot ``preempt_after`` consecutive
steps while others wait is evicted to the compressed pool and requeued),
and who is done (EOS or ``max_new`` reached).

Terminal statuses (the glossary the README's Robustness section keys
off):

``done``      finished normally (EOS or ``max_new``).
``rejected``  can NEVER run on this engine — the prompt+gen total is
              beyond the cache ladder. A permanent verdict at admission.
``shed``      COULD have run, but an SLO dropped it: ``shed_reason`` is
              ``"deadline"`` (TTL unmeetable given the slot clock, at
              admission or mid-flight), ``"overload"`` (bounded pending
              queue overflowed — newest fresh arrivals go first), or
              ``"retry-budget"`` (crash re-admissions exhausted
              ``retry_budget``).

A transiently-infeasible ``fits`` verdict (``"later"``) is *not*
terminal: the request stays queued at its FCFS position and is re-tried
every tick, bounded by the shed policy above. Requests that finish
after surviving an engine crash additionally carry ``recovered=True``.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np


@dataclasses.dataclass
class Request:
    """One serving request plus its host-side decode bookkeeping.

    ``pos`` is the cache position the next step writes; ``fed`` counts
    prompt tokens whose KV is final in the cache. Until ``pos`` reaches
    ``prompt_len`` the lane is teacher-forced (chunked-prefill tail: the
    next input token comes from the prompt and the step's output is
    discarded); from there on the model's own tokens feed back."""
    rid: int
    prompt: np.ndarray              # (P,) int32
    max_new: int
    arrival: int = 0                # engine tick at which it becomes visible
    eos_token: int | None = None
    deadline_ticks: int | None = None  # TTL in engine ticks from arrival
    retry_budget: int = 3           # crash re-admissions before shedding
    # --- runtime ---
    out: list = dataclasses.field(default_factory=list)
    next_tok: int = 0
    pos: int = 0
    fed: int = 0                    # prompt tokens with final KV in cache
    status: str = "waiting"         # waiting | running | done | rejected | shed
    shed_reason: str = ""           # deadline | overload | retry-budget
    slot_steps: int = 0             # consecutive steps in-slot (preempt clock)
    evictions: int = 0
    retries: int = 0                # crash re-admissions consumed
    recovered: bool = False         # survived an engine crash in-flight
    deadline: int | None = None     # absolute tick, fixed at creation —
                                    # preemption mutates `arrival`, so the
                                    # TTL anchors to the ORIGINAL arrival
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0
    token_times: list = dataclasses.field(default_factory=list)

    def __post_init__(self):
        if self.deadline is None and self.deadline_ticks is not None:
            self.deadline = self.arrival + int(self.deadline_ticks)

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def total_len(self) -> int:
        """Cache positions the request needs end to end."""
        return self.prompt_len + self.max_new

    @property
    def done(self) -> bool:
        if len(self.out) >= self.max_new:
            return True
        return (self.eos_token is not None and len(self.out) > 0
                and self.out[-1] == self.eos_token)


def synthetic_trace(n_requests: int, *, vocab: int, seed: int = 0,
                    prompt_lo: int = 8, prompt_hi: int = 48,
                    gen_lo: int = 8, gen_hi: int = 32,
                    arrival_every: int = 0,
                    deadline_ticks: int | None = None) -> list[Request]:
    """Deterministic heavy-traffic trace: ``n_requests`` requests with
    varying prompt/gen lengths. ``arrival_every`` staggers arrivals every
    N engine steps (0 = all arrive at tick 0 — a burst);
    ``deadline_ticks`` attaches a uniform TTL to every request."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        plen = int(rng.integers(prompt_lo, prompt_hi + 1))
        gen = int(rng.integers(gen_lo, gen_hi + 1))
        prompt = rng.integers(1, vocab, size=plen).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt, max_new=gen,
                            arrival=i * arrival_every,
                            deadline_ticks=deadline_ticks))
    return reqs


# per-request runtime fields captured by Scheduler.snapshot() — list
# fields (out, token_times) are copied separately
_REQ_FIELDS = ("next_tok", "pos", "fed", "status", "shed_reason",
               "slot_steps", "evictions", "retries", "recovered",
               "arrival", "t_submit", "t_first", "t_done")


class Scheduler:
    """FCFS admission with optional round-robin preemption, a bounded
    pending queue (``queue_bound`` — overflow is shed, newest fresh
    arrivals first) and deadline-aware admission (a request whose TTL
    can't be met given the engine's slot clock is shed, not queued)."""

    def __init__(self, requests: list[Request], *, preempt_after: int = 0,
                 queue_bound: int = 0):
        self.waiting: deque[Request] = deque(
            sorted(requests, key=lambda r: (r.arrival, r.rid)))
        self.preempt_after = preempt_after
        self.queue_bound = queue_bound     # 0 = unbounded (PR 9 behavior)
        self.evictions = 0
        self.n_shed = 0
        self.deadline_misses = 0           # sheds with reason "deadline"
        self.deferrals = 0                 # transient fits-veto re-queues
        self.completed: list[Request] = []
        self._all: dict = {r.rid: r for r in requests}

    # ------------------------------------------------------------------
    def pending(self) -> int:
        return len(self.waiting)

    def next_arrival(self) -> int | None:
        return self.waiting[0].arrival if self.waiting else None

    def shed(self, r: Request, reason: str) -> None:
        """Terminal drop under an SLO: distinct from ``rejected`` (which
        means the request could never run on this engine at all)."""
        r.status = "shed"
        r.shed_reason = reason
        self.n_shed += 1
        if reason == "deadline":
            self.deadline_misses += 1
        self.completed.append(r)

    def shed_overflow(self, tick: int) -> list[Request]:
        """Bounded pending queue: when more than ``queue_bound`` *fresh*
        arrivals are waiting, shed the newest of them. The bound is
        admission backpressure, so it counts (and sheds) only requests
        with no progress — preempted or crash-requeued work-in-progress
        holds paged KV and real tokens, and must neither be shed nor
        squeeze fresh arrivals out of the queue by occupying it."""
        if self.queue_bound <= 0:
            return []
        fresh = [r for r in self.waiting if r.arrival <= tick
                 and r.pos == 0 and r.evictions == 0 and r.retries == 0]
        excess = len(fresh) - self.queue_bound
        if excess <= 0:
            return []
        victims = sorted(fresh, key=lambda r: (r.arrival, r.rid))[-excess:]
        for r in victims:
            self.waiting.remove(r)
            self.shed(r, "overload")
        return victims

    def admit(self, tick: int, free_slots: int,
              fits=lambda r: True, eta=None) -> list[Request]:
        """Pop up to ``free_slots`` arrived requests, FCFS.

        ``fits`` returns a verdict per request: ``"ok"`` (admit),
        ``"never"`` (beyond the cache ladder — terminal ``rejected``, as
        PR 9 did for every veto) or ``"later"`` (transiently infeasible,
        e.g. the hot-set budget is full of other lanes — the request
        keeps its FCFS position and is re-tried next tick). Plain
        ``True``/``False`` still work and mean ok/never.

        ``eta(r)`` is the engine's minimum ticks-to-finish estimate; a
        request whose deadline can't be met even if admitted right now
        (``tick + eta > deadline``) is shed instead of occupying a slot
        it cannot use to meet its SLO."""
        admitted: list[Request] = []
        deferred: list[Request] = []
        while self.waiting and free_slots > 0 \
                and self.waiting[0].arrival <= tick:
            r = self.waiting.popleft()
            if r.deadline is not None:
                need = eta(r) if eta is not None \
                    else max(r.total_len - 1 - r.pos, 0)
                if tick + need > r.deadline:
                    self.shed(r, "deadline")
                    continue
            verdict = fits(r)
            if verdict is True:
                verdict = "ok"
            elif verdict is False:
                verdict = "never"
            if verdict == "never":
                r.status = "rejected"
                self.completed.append(r)
                continue
            if verdict == "later":
                self.deferrals += 1
                deferred.append(r)
                continue
            r.status = "running"
            r.slot_steps = 0
            admitted.append(r)
            free_slots -= 1
        for r in reversed(deferred):       # restore FCFS queue position
            self.waiting.appendleft(r)
        return admitted

    def should_preempt(self, r: Request) -> bool:
        """Evict a lane that has monopolized its slot while others wait."""
        return (self.preempt_after > 0 and r.slot_steps >= self.preempt_after
                and bool(self.waiting))

    def preempt(self, r: Request, tick: int) -> None:
        r.status = "waiting"
        r.slot_steps = 0
        r.evictions += 1
        r.arrival = tick                # back of the arrived queue
        self.evictions += 1
        self.waiting.append(r)

    def retire(self, r: Request) -> None:
        r.status = "done"
        self.completed.append(r)

    def requeue_front(self, r: Request) -> None:
        """Crash re-admission: a formerly-running lane goes back to the
        FRONT of the queue (it already holds paged KV and progress) —
        unlike ``preempt``, its arrival and TTL anchor are untouched."""
        r.status = "waiting"
        r.slot_steps = 0
        self.waiting.appendleft(r)

    # ------------------------------------------------------------------
    # crash-recovery snapshots (host-side bookkeeping only — the KV
    # itself is snapshotted by the engine paging lanes into the pool)
    # ------------------------------------------------------------------
    def snapshot(self) -> dict:
        reqs = {}
        for r in self._all.values():
            d = {f: getattr(r, f) for f in _REQ_FIELDS}
            d["out"] = list(r.out)
            d["token_times"] = list(r.token_times)
            reqs[r.rid] = d
        return {"reqs": reqs,
                "waiting": [r.rid for r in self.waiting],
                "completed": [r.rid for r in self.completed],
                "evictions": self.evictions, "n_shed": self.n_shed,
                "deadline_misses": self.deadline_misses,
                "deferrals": self.deferrals}

    def restore(self, snap: dict) -> None:
        for rid, d in snap["reqs"].items():
            r = self._all[rid]
            for f in _REQ_FIELDS:
                setattr(r, f, d[f])
            r.out = list(d["out"])
            r.token_times = list(d["token_times"])
        self.waiting = deque(self._all[rid] for rid in snap["waiting"])
        self.completed = [self._all[rid] for rid in snap["completed"]]
        self.evictions = snap["evictions"]
        self.n_shed = snap["n_shed"]
        self.deadline_misses = snap["deadline_misses"]
        self.deferrals = snap["deferrals"]
