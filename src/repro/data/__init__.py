from .synthetic import (  # noqa: F401
    SYN_CIFAR10,
    SYN_TINYIMAGENET,
    ImageDatasetConfig,
    LMDatasetConfig,
    StreamingLoader,
    image_batch,
    lm_batch,
)
