"""Procedural datasets (offline container — DESIGN.md §6).

Image stream: class-conditional oriented-stripe/blob textures composited on
low-amplitude background clutter. Properties we need for the reproduction:
  * learnable (a small CNN reaches high accuracy, degrades when over-pruned)
  * real "background" pixels so Zebra's zero-block story is testable
  * deterministic per (seed, step) — the pipeline is a counter-indexed PRNG
    stream, so a restarted job replays no sample (fault-tolerance §5).

LM stream: noisy affine-recurrence token sequences (x_{t+1} = a*x_t + b + ε
mod V_eff embedded in the full vocab) — enough structure for loss to fall.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ImageDatasetConfig:
    name: str = "syn-cifar10"     # or "syn-tinyimagenet"
    num_classes: int = 10
    hw: int = 32
    seed: int = 0
    noise: float = 0.15           # background clutter amplitude
    fg_classes_per_image: int = 1


SYN_CIFAR10 = ImageDatasetConfig("syn-cifar10", 10, 32)
SYN_TINYIMAGENET = ImageDatasetConfig("syn-tinyimagenet", 200, 64)


def _class_texture(cls: int, num_classes: int, hw: int, rng: np.random.Generator):
    """Oriented stripe patch whose (angle, frequency, phase-color) encode cls."""
    angle = np.pi * (cls % num_classes) / num_classes
    freq = 2.0 + 3.0 * ((cls * 7) % 5)
    yy, xx = np.meshgrid(np.linspace(-1, 1, hw), np.linspace(-1, 1, hw), indexing="ij")
    u = np.cos(angle) * xx + np.sin(angle) * yy
    base = np.sin(2 * np.pi * freq * u + rng.uniform(0, 2 * np.pi))
    color = np.array([np.sin(cls), np.cos(2 * cls), np.sin(3 * cls + 1)]) * 0.5 + 0.75
    return base[None, :, :] * color[:, None, None]          # (3, hw, hw)


def image_batch(cfg: ImageDatasetConfig, batch: int, step: int):
    """-> (images (B,3,H,W) float32 ~N(0,1)-ish, labels (B,) int32)."""
    rng = np.random.default_rng((cfg.seed << 32) ^ (step & 0xFFFFFFFF))
    hw = cfg.hw
    labels = rng.integers(0, cfg.num_classes, size=(batch,))
    imgs = rng.normal(0.0, cfg.noise, size=(batch, 3, hw, hw)).astype(np.float32)
    for i in range(batch):
        tex = _class_texture(int(labels[i]), cfg.num_classes, hw, rng)
        # place the foreground patch over a random sub-window; the rest stays
        # background clutter => spatially sparse information, like photos.
        ph = rng.integers(hw // 2, hw + 1)
        pw = rng.integers(hw // 2, hw + 1)
        top = rng.integers(0, hw - ph + 1)
        left = rng.integers(0, hw - pw + 1)
        imgs[i, :, top:top + ph, left:left + pw] += tex[:, :ph, :pw].astype(np.float32)
    return imgs, labels.astype(np.int32)


@dataclasses.dataclass(frozen=True)
class LMDatasetConfig:
    vocab: int = 32000
    effective_vocab: int = 509    # prime < vocab: structure lives here
    seed: int = 0
    noise_p: float = 0.05


def lm_batch(cfg: LMDatasetConfig, batch: int, seq: int, step: int):
    """-> (tokens (B, S+1) int32); inputs = [:, :-1], labels = [:, 1:]."""
    rng = np.random.default_rng((cfg.seed << 32) ^ (0x5BCD ^ step))
    V = cfg.effective_vocab
    a = 5 + 2 * rng.integers(0, 20, size=(batch, 1))
    b = rng.integers(0, V, size=(batch, 1))
    x = np.empty((batch, seq + 1), dtype=np.int64)
    x[:, 0] = rng.integers(0, V, size=batch)
    for t in range(seq):
        nxt = (a[:, 0] * x[:, t] + b[:, 0]) % V
        flip = rng.random(batch) < cfg.noise_p
        nxt = np.where(flip, rng.integers(0, V, size=batch), nxt)
        x[:, t + 1] = nxt
    return (x % cfg.vocab).astype(np.int32)


class StreamingLoader:
    """Counter-indexed loader: `state` is just the step counter, so
    checkpoint/restore = persist an int. Shards the global batch by host."""

    def __init__(self, make_fn, global_batch: int, host_id: int = 0, n_hosts: int = 1,
                 start_step: int = 0):
        assert global_batch % n_hosts == 0
        self.make_fn = make_fn
        self.local_batch = global_batch // n_hosts
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.step = start_step

    def __next__(self):
        # fold host_id into the counter stream so hosts draw disjoint data
        out = self.make_fn(self.local_batch, self.step * self.n_hosts + self.host_id)
        self.step += 1
        return out

    def state(self) -> int:
        return self.step

    def restore(self, step: int) -> None:
        self.step = step
