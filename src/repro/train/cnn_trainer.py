"""CNN training loop — the paper's experimental pipeline (§III).

Loss assembly (paper Eq. 1 + partner methods):
    L = λ·CE + Σ_{l,c} ||T_obj − T_{l,c}||²  (+ ρ_NS·Σ|γ|  during NS
    sparsity-training)  with WP / NS masks held fixed during retrain.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..core import (LayerAux, ZebraConfig, collect_zebra_loss,
                    mean_zero_frac, reduced_bandwidth_pct, slimming,
                    weight_pruning)
from ..data import ImageDatasetConfig, StreamingLoader, image_batch
from ..models.cnn import build as build_cnn
from ..models.cnn.common import accuracy, cross_entropy, topk_accuracy
from ..optim import Optimizer, apply_updates, clip_by_global_norm


def _sum_bytes(auxes) -> LayerAux:
    """Exact cross-site byte accumulation (the (mb_hi, mb_lo) pair)."""
    acc = LayerAux.zero()
    for a in auxes:
        acc = acc + LayerAux.of_site(a)
    return acc


@dataclasses.dataclass(frozen=True)
class CNNTrainConfig:
    model: str = "resnet18"
    width_mult: float = 1.0
    dataset: ImageDatasetConfig = ImageDatasetConfig()
    batch: int = 64
    steps: int = 300
    zebra: ZebraConfig = ZebraConfig()
    ns_rho: float = 0.0            # BN-γ L1 weight (NS sparsity training)
    grad_clip: float = 10.0
    seed: int = 0


class CNNTrainer:
    def __init__(self, cfg: CNNTrainConfig, optimizer: Optimizer):
        self.cfg = cfg
        self.model = build_cnn(cfg.model, cfg.dataset.num_classes,
                               cfg.dataset.hw, cfg.width_mult)
        self.opt = optimizer
        self.wp_masks = None       # magnitude weight-pruning masks (fixed)
        self.ns_masks = None       # network-slimming channel masks (fixed)
        self._train_step = jax.jit(self._step, static_argnames=("train",))
        self._eval_step = jax.jit(self._eval)

    # ------------------------------------------------------------------
    def init_state(self, key=None):
        key = key if key is not None else jax.random.PRNGKey(self.cfg.seed)
        variables = self.model.init(key, self.cfg.zebra)
        opt_state = self.opt.init(self._trainable(variables))
        return {"variables": variables, "opt": opt_state,
                "step": jnp.int32(0)}

    def _trainable(self, variables):
        return {"params": variables["params"], "zebra": variables["zebra"]}

    # ------------------------------------------------------------------
    def _loss_fn(self, trainable, state_bn, images, labels, train: bool):
        variables = {"params": trainable["params"], "state": state_bn,
                     "zebra": trainable["zebra"]}
        zcfg = self.cfg.zebra.replace(mode="train" if train else "infer")
        logits, new_bn, auxes = self.model.apply(variables, images, train, zcfg)
        ce = cross_entropy(logits, labels)
        zreg = collect_zebra_loss(auxes)
        # with use_tnet=False the reg slot is the realized zero-block count
        # (gradient-free observable) — Eq. 1's trainable term is zero, so it
        # stays out of the loss
        loss = self.cfg.zebra.lambda_ce * ce + \
            (zreg if self.cfg.zebra.use_tnet else 0.0)
        if self.cfg.ns_rho > 0:
            loss = loss + self.cfg.ns_rho * slimming.gamma_l1(trainable["params"])
        acc_bytes = _sum_bytes(auxes)
        metrics = {"ce": ce, "zebra_reg": zreg,
                   "acc": accuracy(logits, labels),
                   "zero_frac": mean_zero_frac(auxes),
                   # nonzero when training through the stream backend; the
                   # (hi, lo) legs keep the count exact past 16 MiB
                   # (measured_bytes alone is the rounding f32 display)
                   "measured_bytes": acc_bytes.measured_bytes,
                   "measured_bytes_hi": acc_bytes.mb_hi,
                   "measured_bytes_lo": acc_bytes.mb_lo}
        return loss, (new_bn, metrics, auxes)

    def _apply_fixed_masks(self, trainable):
        if self.wp_masks is not None:
            trainable = dict(trainable)
            trainable["params"] = weight_pruning.apply_masks(
                trainable["params"], self.wp_masks)
        if self.ns_masks is not None:
            trainable = dict(trainable)
            trainable["params"] = slimming.apply_masks(
                trainable["params"], self.ns_masks)
        return trainable

    def _step(self, state, images, labels, train: bool = True):
        trainable = self._apply_fixed_masks(self._trainable(state["variables"]))
        grad_fn = jax.value_and_grad(self._loss_fn, has_aux=True)
        (loss, (new_bn, metrics, _)), grads = grad_fn(
            trainable, state["variables"]["state"], images, labels, train)
        grads, gnorm = clip_by_global_norm(grads, self.cfg.grad_clip)
        updates, new_opt = self.opt.update(grads, state["opt"], trainable,
                                           state["step"])
        new_trainable = apply_updates(trainable, updates)
        new_trainable = self._apply_fixed_masks(new_trainable)
        new_vars = {"params": new_trainable["params"], "state": new_bn,
                    "zebra": new_trainable["zebra"]}
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return {"variables": new_vars, "opt": new_opt,
                "step": state["step"] + 1}, metrics

    def _eval(self, variables, images, labels):
        zcfg = self.cfg.zebra.replace(mode="infer")
        logits, _, auxes = self.model.apply(variables, images, False, zcfg)
        acc = _sum_bytes(auxes)
        return {"acc": accuracy(logits, labels),
                "top5": topk_accuracy(logits, labels, k=5),
                "ce": cross_entropy(logits, labels),
                "zero_frac": mean_zero_frac(auxes),
                "zero_fracs": jnp.stack([a["zero_frac"] for a in auxes]),
                # observed stream bytes per forward (site engine; nonzero
                # only for the stream/fused backends); the (hi, lo) legs
                # let the host read the total exactly past 16 MiB
                "measured_bytes_hi": acc.mb_hi,
                "measured_bytes_lo": acc.mb_lo}

    # ------------------------------------------------------------------
    def train(self, steps: int | None = None, log_every: int = 50,
              loader: StreamingLoader | None = None, state=None,
              callback: Callable | None = None):
        cfg = self.cfg
        steps = steps or cfg.steps
        loader = loader or StreamingLoader(
            partial(image_batch, cfg.dataset), cfg.batch)
        state = state or self.init_state()
        history = []
        for _ in range(steps):
            images, labels = next(loader)
            state, metrics = self._train_step(state, images, labels)
            if int(state["step"]) % log_every == 0 or int(state["step"]) == steps:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = int(state["step"])
                history.append(m)
                if callback:
                    callback(m)
        return state, history

    # ------------------------------------------------------------------
    def evaluate(self, variables, batches: int = 8, batch: int = 128, seed: int = 10_000):
        cfg = self.cfg
        accs, top5s, zfs, per_site, mbytes = [], [], [], [], []
        for i in range(batches):
            images, labels = image_batch(cfg.dataset, batch, seed + i)
            out = self._eval_step(variables, images, labels)
            accs.append(float(out["acc"]))
            top5s.append(float(out["top5"]))
            zfs.append(float(out["zero_frac"]))
            per_site.append(np.asarray(out["zero_fracs"]))
            # exact host-side readout of the (hi, lo) byte pair
            from ..core.engine import MB_BASE
            mbytes.append(float(out["measured_bytes_hi"]) * MB_BASE
                          + float(out["measured_bytes_lo"]))
        specs = self.model.map_specs(cfg.dataset.hw, cfg.zebra)
        site_zf = np.mean(np.stack(per_site), axis=0)
        bw = reduced_bandwidth_pct(specs, list(site_zf))
        return {"acc": float(np.mean(accs)), "top5": float(np.mean(top5s)),
                "zero_frac": float(np.mean(zfs)), "reduced_bandwidth_pct": bw,
                "site_zero_fracs": site_zf,
                "measured_bytes": float(np.mean(mbytes))}

    # ------------------------------------------------------------------
    # Partner-method hooks (paper §III.A)
    def apply_weight_pruning(self, variables, prune_frac: float):
        self.wp_masks = weight_pruning.magnitude_masks(variables["params"], prune_frac)
        return weight_pruning.sparsity(self.wp_masks)

    def apply_network_slimming(self, variables, prune_frac: float):
        self.ns_masks = slimming.channel_masks(variables["params"], prune_frac)
        return slimming.pruned_channel_frac(self.ns_masks)
