from .cnn_trainer import CNNTrainer, CNNTrainConfig  # noqa: F401
