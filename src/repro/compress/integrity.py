"""Stream integrity — the validated wire contract of the (bitmap, payload)
stream.

Every boundary the compressed stream crosses (jit handoffs, checkpointed
activation maps, mesh collectives) trusts two things that nothing used to
check: the consumer slot map is *derived* from bitmap prefix sums, so one
flipped bitmap bit silently relocates every later payload block, and a
truncated or NaN-poisoned payload flows straight into the GEMM. This
module is the ONE place the wire contract is written down and checked,
at three ``ZebraConfig.validation`` levels:

``off``
    No checks, no checksum — the hot path is bit-identical to the
    pre-validation code (bench-gated: stream_bytes and kernel latency
    unchanged).
``structural``
    Cheap invariants computable from the stream alone:
    * ``n_live == popcount(bitmap)`` — the producer counter and the
      index must agree (catches any single bitmap bit flip: popcount
      moves by exactly 1);
    * payload buffer capacity == total block count (static shape check);
    * every live payload slot is fully finite (catches NaN/Inf poison);
    * every live payload slot has at least one nonzero element — a kept
      block always does (the comparator keeps ``max|x| >= t_obj > 0``;
      the lossless bitmap keeps ``max|x| > 0``), so an all-zero live
      slot means the payload was truncated or the slot map shifted.
``checksum``
    Structural plus a uint32 position-mixed XOR fold over the bitmap
    bits, the live payload words and ``n_live`` — detects arbitrary
    content corruption (e.g. a live value flipped to another finite
    nonzero value, which structural invariants cannot see). Computed
    in-graph by the producer (``stream_checksum``), carried in
    ``CompressedMap.checksum`` / alongside the stream, recomputed and
    compared on ingest.

Two API surfaces for the two kinds of boundary:

* **In-graph** (:func:`check_stream`): returns a traced bool "stream is
  intact" flag — the engine and the collectives gate a
  ``lax.cond``-style recompute-from-dense fallback on it. A detected
  failure also fires :func:`note_failure` (a ``jax.debug.callback``)
  so chaos tests and the faults bench can observe detections from
  outside the jit.
* **Host-side** (:func:`validate_map` / :func:`validate_payload`):
  raises :class:`repro.ft.faults.CorruptStream` with the failed
  invariant named — for boundaries where the stream is concrete
  (serve's prefill -> decode handoff, checkpoint restore), where the
  caller routes the exception through the ``ft.faults`` policy table.
"""
from __future__ import annotations

import logging
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

_log = logging.getLogger("repro.integrity")

VALIDATION_LEVELS = ("off", "structural", "checksum")

# Knuth multiplicative-hash constants (odd -> bijective mod 2**32): the
# position mix makes the XOR fold order-sensitive, so two swapped words
# or two identical flips at different positions still change the fold.
_K1 = np.uint32(2654435761)
_K2 = np.uint32(40503 * 65537 + 1)


def validate_level(level: str) -> str:
    if level not in VALIDATION_LEVELS:
        raise ValueError(f"unknown validation level {level!r}; expected one "
                         f"of {VALIDATION_LEVELS}")
    return level


# ---------------------------------------------------------------------------
# uint32 folds (in-graph; also run host-side on concrete arrays)
# ---------------------------------------------------------------------------

def _xor_reduce(x: jax.Array, axis: int) -> jax.Array:
    return lax.reduce(x, np.uint32(0), lax.bitwise_xor, (axis,))


def _payload_words(payload: jax.Array) -> jax.Array:
    """(nb, bs, bc) payload -> (nb, words) uint32 bit patterns."""
    nb = payload.shape[0]
    flat = payload.reshape(nb, -1)
    if flat.dtype == jnp.float32:
        return lax.bitcast_convert_type(flat, jnp.uint32)
    if flat.dtype in (jnp.bfloat16, jnp.float16):
        return lax.bitcast_convert_type(flat, jnp.uint16).astype(jnp.uint32)
    # integer payloads (not produced today): fold the values themselves
    return flat.astype(jnp.uint32)


def _slot_hashes(payload: jax.Array) -> jax.Array:
    """Per-slot position-mixed XOR fold -> (nb,) uint32."""
    words = _payload_words(payload)
    j = jnp.arange(words.shape[1], dtype=jnp.uint32)
    return _xor_reduce((words + j) * _K1, axis=1)


def stream_checksum(payload: jax.Array, bitmap: jax.Array,
                    n_live: jax.Array) -> jax.Array:
    """uint32 checksum of one stream: bitmap bits + live payload slots +
    the live count, each position-mixed before the XOR fold. Dead slots
    (index >= n_live) are excluded, so producers that leave garbage in
    the worst-case tail and producers that zero it hash identically."""
    nb = payload.shape[0]
    bits = bitmap.reshape(-1).astype(jnp.uint32)
    i = jnp.arange(bits.shape[0], dtype=jnp.uint32)
    bm_hash = _xor_reduce((bits + i) * _K1, axis=0)
    slot = _slot_hashes(payload)
    s = jnp.arange(nb, dtype=jnp.uint32)
    live = s < n_live.astype(jnp.uint32)
    pl_hash = _xor_reduce(jnp.where(live, (slot + s) * _K2, jnp.uint32(0)),
                          axis=0)
    return (bm_hash * _K2) ^ pl_hash ^ (n_live.astype(jnp.uint32) * _K1)


# ---------------------------------------------------------------------------
# In-graph validation
# ---------------------------------------------------------------------------

def _static_contract(payload, bitmap, bs: int, bc: int) -> None:
    """Shape-level invariants are static — a wrong capacity is a
    programming error at trace time, not data corruption."""
    nb = int(bitmap.shape[0]) * int(bitmap.shape[1])
    if tuple(payload.shape) != (nb, bs, bc):
        raise ValueError(
            f"stream contract: payload {tuple(payload.shape)} != worst-case "
            f"capacity {(nb, bs, bc)} for bitmap {tuple(bitmap.shape)}")


def check_stream(payload: jax.Array, bitmap: jax.Array, n_live: jax.Array,
                 *, level: str, checksum: jax.Array | None = None,
                 live_nonzero: bool = True) -> jax.Array:
    """Traced bool: does this stream satisfy the wire contract at
    ``level``? ``level="off"`` returns constant True (and traces no
    checks at all, keeping the gated-off hot path untouched).

    ``live_nonzero`` asserts the kept-block invariant (every live slot
    has a nonzero element); disable it for streams whose bitmap can
    legitimately keep all-zero blocks (t_obj == 0, or union-capacity
    payloads where a slot is live in the union but zero locally).
    """
    validate_level(level)
    if level == "off":
        return jnp.bool_(True)
    _static_contract(payload, bitmap, payload.shape[1], payload.shape[2])
    nb = payload.shape[0]
    n_live = jnp.asarray(n_live).astype(jnp.int32)
    pop = jnp.sum(bitmap.astype(jnp.int32))
    ok = (n_live == pop) & (n_live >= 0) & (n_live <= nb)
    slot_idx = jnp.arange(nb, dtype=jnp.int32)
    live = slot_idx < n_live
    flat = payload.reshape(nb, -1)
    if jnp.issubdtype(flat.dtype, jnp.floating):
        slot_finite = jnp.all(jnp.isfinite(flat.astype(jnp.float32)), axis=1)
        ok = ok & jnp.all(jnp.where(live, slot_finite, True))
    if live_nonzero:
        slot_nz = jnp.max(jnp.abs(flat.astype(jnp.float32)), axis=1) > 0
        ok = ok & jnp.all(jnp.where(live, slot_nz, True))
    if level == "checksum" and checksum is not None:
        ok = ok & (stream_checksum(payload, bitmap, n_live)
                   == jnp.asarray(checksum).astype(jnp.uint32))
    return ok


# ---------------------------------------------------------------------------
# Detection observability (jit-safe)
# ---------------------------------------------------------------------------

_FAILURES: list[str] = []


def note_failure(site: str) -> None:
    """Record one detected-and-recovered stream failure. Call from inside
    jit via ``jax.debug.callback(integrity.note_failure, site=...)`` on
    the recovery branch — the chaos tests and faults bench read
    :func:`failures` to assert the detection actually fired (bitwise
    parity of the recovered output alone cannot distinguish "detected
    and recovered" from "fault never bit")."""
    _FAILURES.append(str(site))


def failures() -> list[str]:
    return list(_FAILURES)


def clear_failures() -> None:
    _FAILURES.clear()


# ---------------------------------------------------------------------------
# Host-side validation (concrete streams at process boundaries)
# ---------------------------------------------------------------------------

def validate_payload(payload, bitmap, n_live, *, level: str,
                     checksum=None, live_nonzero: bool = True,
                     site: str = "stream") -> None:
    """Validate one concrete stream; raise ``ft.faults.CorruptStream``
    naming the first failed invariant. The checks mirror
    :func:`check_stream` exactly — one contract, two surfaces."""
    from ..ft.faults import CorruptStream
    validate_level(level)
    if level == "off":
        return
    payload = np.asarray(payload)
    bitmap = np.asarray(bitmap)
    nl = int(n_live)
    nb = bitmap.size
    if payload.ndim != 3:
        raise CorruptStream(f"{site}: payload shape {payload.shape} is not "
                            f"a (n_blocks, bs, bc) buffer")
    if payload.shape[0] != nb:
        raise CorruptStream(f"{site}: payload capacity {payload.shape[0]} != "
                            f"block count {nb}")
    pop = int(bitmap.astype(np.int64).sum())
    if not (0 <= nl <= nb):
        raise CorruptStream(f"{site}: n_live {nl} outside [0, {nb}]")
    if nl != pop:
        raise CorruptStream(f"{site}: n_live {nl} != popcount(bitmap) {pop} "
                            f"— a flipped index bit relocates every later "
                            f"payload block")
    flat = payload.reshape(nb, -1).astype(np.float32)
    live = np.arange(nb) < nl
    if np.issubdtype(payload.dtype, np.floating) or payload.dtype.name == "bfloat16":
        bad = live & ~np.isfinite(flat).all(axis=1)
        if bad.any():
            raise CorruptStream(f"{site}: non-finite payload in live slot "
                                f"{int(np.argmax(bad))}")
    if live_nonzero:
        zeroed = live & (np.abs(flat).max(axis=1, initial=0.0) == 0)
        if zeroed.any():
            raise CorruptStream(
                f"{site}: live payload slot {int(np.argmax(zeroed))} is "
                f"all-zero — truncated payload or shifted slot map")
    if level == "checksum":
        if checksum is None:
            raise CorruptStream(f"{site}: validation level 'checksum' but "
                                f"the stream carries no checksum")
        want = int(np.uint32(checksum))
        got = int(np.asarray(stream_checksum(
            jnp.asarray(payload), jnp.asarray(bitmap), jnp.int32(nl))))
        if got != want:
            raise CorruptStream(f"{site}: checksum mismatch (stored "
                                f"{want:#010x}, recomputed {got:#010x})")


def validate_map(cm: Any, *, level: str, live_nonzero: bool = True,
                 site: str = "stream") -> None:
    """Host-side ingest validation of one ``CompressedMap`` (raises
    ``CorruptStream``). The packed index is unpacked to the (nm, nk)
    bitmap first — the same representation the in-graph contract folds."""
    from .stream import unpack_bitmap
    validate_level(level)
    if level == "off":
        return
    bitmap = unpack_bitmap(jnp.asarray(cm.index), cm.m // cm.bs,
                           cm.k // cm.bc)
    validate_payload(cm.payload, bitmap, cm.n_live, level=level,
                     checksum=cm.checksum, live_nonzero=live_nonzero,
                     site=site)


def map_checksum(cm: Any) -> jax.Array:
    """The stream checksum of one ``CompressedMap`` (over the unpacked
    bitmap + live payload + n_live)."""
    from .stream import unpack_bitmap
    bitmap = unpack_bitmap(jnp.asarray(cm.index), cm.m // cm.bs,
                           cm.k // cm.bc)
    return stream_checksum(jnp.asarray(cm.payload), bitmap,
                           jnp.asarray(cm.n_live))


def attach_checksum(cm: Any) -> Any:
    """Return the map with its checksum computed and carried in-band."""
    import dataclasses
    return dataclasses.replace(cm, checksum=map_checksum(cm))
