"""Compressed activation transport — the paper's (bitmap, payload) stream
made real: pack/unpack codecs over Zebra-masked maps plus measured-bytes
accounting that reconciles against the Eq. 2/3 analytic predictions."""
from .stream import (  # noqa: F401
    CompressedMap,
    compress,
    compress_masked,
    decompress,
    compress_tree,
    decompress_tree,
    nonzero_bitmap,
    pack_bitmap,
    unpack_bitmap,
    transport_tokens,
)
from .meter import BandwidthMeter, SiteRecord  # noqa: F401
from .integrity import (  # noqa: F401
    VALIDATION_LEVELS,
    attach_checksum,
    check_stream,
    map_checksum,
    stream_checksum,
    validate_map,
    validate_payload,
)
