"""Measured-bytes accounting: reconcile observed compressed stream lengths
against the paper's analytic predictions (Eq. 2/3).

Per site the meter records what a transport actually moved — payload bytes
(``n_live * bs * bc * itemsize``) plus packed-index bytes
(``ceil(n_blocks / 8)``) — and compares with ``stored_bits(spec,
zero_frac) / 8``. The two can only differ by index padding: Eq. 3 counts
exactly ``n_blocks`` bits, while a real stream rounds the index up to
whole bytes, so ``0 <= measured - predicted < 1`` byte per map (plus
float roundoff in the analytic term). ``reconcile`` asserts that bound.

Interconnect links (``distributed/collectives.py``) get the same
treatment via ``record_link``: one record per (site, mesh axis) covering
the ``n_maps`` per-shard maps an inbound link carried, reconciled
against ``n_maps * stored_bits(spec, mean zero_frac)`` — exact because
``stored_bits`` is linear in ``zero_frac``, so the sum over shards
equals ``n_maps`` times the value at the mean. The padding bound scales
to ``n_maps`` bytes (one index rounding per map).
"""
from __future__ import annotations

import dataclasses

from ..core.bandwidth import TokenMapSpec, reduced_bandwidth_pct, stored_bits
from ..utils import human_bytes
from .stream import CompressedMap


@dataclasses.dataclass
class SiteRecord:
    site: str
    dense_bytes: int
    payload_bytes: int
    index_bytes: int
    n_blocks: int
    n_live: int
    spec: object | None = None       # TokenMapSpec for compressed sites

    @property
    def compressed(self) -> bool:
        return self.spec is not None

    @property
    def measured_bytes(self) -> int:
        return self.payload_bytes + self.index_bytes

    @property
    def zero_frac(self) -> float:
        if not self.n_blocks:
            return 0.0
        return 1.0 - self.n_live / self.n_blocks

    @property
    def predicted_bytes(self) -> float:
        """Eq. 2 (+3) stored size at this site's measured zero fraction."""
        if not self.compressed:
            return float(self.dense_bytes)
        return stored_bits(self.spec, self.zero_frac) / 8.0


@dataclasses.dataclass
class LinkRecord:
    """Bytes ONE inbound interconnect link carried for one collective —
    ``n_maps`` per-shard compressed streams (all-gather: the other
    ``n - 1`` shards' maps; psum ring: ``n - 1`` union-capacity
    payloads). ``n_blocks``/``spec`` describe ONE shard map; ``n_live``
    is the total across the maps the link moved."""
    site: str
    axis: str
    dense_bytes: int
    payload_bytes: int
    index_bytes: int
    n_blocks: int                    # blocks per map
    n_live: int                      # total live blocks across n_maps maps
    n_maps: int
    spec: object                     # TokenMapSpec of one shard map

    @property
    def measured_bytes(self) -> int:
        return self.payload_bytes + self.index_bytes

    @property
    def zero_frac(self) -> float:
        total = self.n_blocks * self.n_maps
        if not total:
            return 0.0
        return 1.0 - self.n_live / total

    @property
    def predicted_bytes(self) -> float:
        """Eq. 2/3 over the link's maps. stored_bits is linear in
        zero_frac, so Σ_s stored_bits(spec, zf_s) == n_maps *
        stored_bits(spec, mean zf) exactly — no per-shard breakdown
        needed."""
        return self.n_maps * stored_bits(self.spec, self.zero_frac) / 8.0


class BandwidthMeter:
    """Counts bytes a transport actually moved, site by site."""

    def __init__(self):
        self.records: list[SiteRecord] = []
        self.links: list[LinkRecord] = []

    # ------------------------------------------------------------------
    def record(self, site: str, cm: CompressedMap) -> SiteRecord:
        r = SiteRecord(site=site, dense_bytes=cm.dense_bytes(),
                       payload_bytes=cm.payload_bytes(),
                       index_bytes=cm.index_bytes(), n_blocks=cm.n_blocks,
                       n_live=int(cm.n_live), spec=cm.spec())
        self.records.append(r)
        return r

    def record_dense(self, site: str, nbytes: int) -> SiteRecord:
        """An uncompressed transport (incompatible leaf) — moved as-is."""
        r = SiteRecord(site=site, dense_bytes=int(nbytes),
                       payload_bytes=int(nbytes), index_bytes=0,
                       n_blocks=0, n_live=0)
        self.records.append(r)
        return r

    def record_link(self, site: str, axis: str, *, m: int, k: int,
                    bs: int, bc: int, dtype_bits: int, n_live: int,
                    n_maps: int, dense_bytes: int | None = None
                    ) -> LinkRecord:
        """One inbound link of a compressed collective: ``n_maps``
        per-shard (m, k) maps at (bs, bc) blocks, ``n_live`` live blocks
        total. Byte rule matches ``core.engine.stream_bytes`` per map:
        payload + one byte-rounded packed index each."""
        nb = (m // bs) * (k // bc)
        payload = int(n_live) * bs * bc * dtype_bits // 8
        index = int(n_maps) * ((nb + 7) // 8)
        if dense_bytes is None:
            dense_bytes = int(n_maps) * m * k * dtype_bits // 8
        r = LinkRecord(site=site, axis=axis, dense_bytes=int(dense_bytes),
                       payload_bytes=payload, index_bytes=index,
                       n_blocks=nb, n_live=int(n_live), n_maps=int(n_maps),
                       spec=TokenMapSpec(s=m, d=k, bits=dtype_bits,
                                         block_seq=bs, block_ch=bc))
        self.links.append(r)
        return r

    # ------------------------------------------------------------------
    def dense_bytes(self) -> int:
        return sum(r.dense_bytes for r in self.records)

    def measured_bytes(self) -> int:
        return sum(r.measured_bytes for r in self.records)

    def measured_reduction_pct(self) -> float:
        base = self.dense_bytes()
        return 100.0 * (1.0 - self.measured_bytes() / base) if base else 0.0

    def ici_bytes(self, axis: str | None = None) -> int:
        """Interconnect bytes actually moved (per mesh axis, or total)."""
        return sum(r.measured_bytes for r in self.links
                   if axis is None or r.axis == axis)

    def ici_dense_bytes(self, axis: str | None = None) -> int:
        return sum(r.dense_bytes for r in self.links
                   if axis is None or r.axis == axis)

    def ici_per_axis(self) -> dict[str, tuple[int, int]]:
        """{axis: (moved, dense-equivalent)} over all recorded links."""
        out: dict[str, tuple[int, int]] = {}
        for r in self.links:
            m, d = out.get(r.axis, (0, 0))
            out[r.axis] = (m + r.measured_bytes, d + r.dense_bytes)
        return out

    def predicted_reduction_pct(self) -> float:
        """Eq. 2/3 prediction over the compressed sites, at the measured
        per-site zero fractions (dense sites contribute their full size)."""
        comp = [r for r in self.records if r.compressed]
        if not comp:
            return 0.0
        pct = reduced_bandwidth_pct([r.spec for r in comp],
                                    [r.zero_frac for r in comp])
        dense = sum(r.dense_bytes for r in self.records if not r.compressed)
        base = self.dense_bytes()
        return pct * (1.0 - dense / base) if base else pct

    # ------------------------------------------------------------------
    def reconcile(self, tol_bytes_per_map: float = 1.0) -> dict:
        """Check measured vs predicted site by site. Returns the worst
        absolute delta; raises if any site exceeds the index-padding bound
        (< 1 byte per map by construction; `tol_bytes_per_map` adds slack
        for float roundoff in the analytic term)."""
        deltas = {}
        for r in self.records:
            if not r.compressed:
                continue
            delta = r.measured_bytes - r.predicted_bytes
            deltas[r.site] = delta
            if not (-tol_bytes_per_map <= delta < 1.0 + tol_bytes_per_map):
                raise AssertionError(
                    f"site {r.site}: measured {r.measured_bytes} B vs "
                    f"predicted {r.predicted_bytes:.2f} B (delta {delta:.2f} "
                    f"exceeds index-padding bound)")
        for r in self.links:
            # one index rounding per map the link carried -> the padding
            # bound scales to n_maps bytes
            delta = r.measured_bytes - r.predicted_bytes
            key = f"link:{r.site}@{r.axis}"
            deltas[key] = delta
            bound = r.n_maps * (1.0 + tol_bytes_per_map)
            if not (-r.n_maps * tol_bytes_per_map <= delta < bound):
                raise AssertionError(
                    f"{key}: measured {r.measured_bytes} B vs predicted "
                    f"{r.predicted_bytes:.2f} B (delta {delta:.2f} exceeds "
                    f"the {r.n_maps}-map index-padding bound)")
        return {"n_sites": len(deltas),
                "max_abs_delta_bytes": max((abs(d) for d in deltas.values()),
                                           default=0.0),
                "deltas": deltas}

    # ------------------------------------------------------------------
    def report(self, max_rows: int = 12) -> str:
        lines = [f"{'site':42s} {'dense':>10s} {'measured':>10s} "
                 f"{'pred':>10s} {'zero%':>6s}"]
        for r in self.records[:max_rows]:
            lines.append(
                f"{r.site[:42]:42s} {human_bytes(r.dense_bytes):>10s} "
                f"{human_bytes(r.measured_bytes):>10s} "
                f"{human_bytes(r.predicted_bytes):>10s} "
                f"{100 * r.zero_frac:5.1f}%")
        if len(self.records) > max_rows:
            lines.append(f"  ... {len(self.records) - max_rows} more sites")
        lines.append(
            f"TOTAL dense {human_bytes(self.dense_bytes())} -> measured "
            f"{human_bytes(self.measured_bytes())}  "
            f"(measured reduction {self.measured_reduction_pct():.2f}%, "
            f"predicted {self.predicted_reduction_pct():.2f}%)")
        return "\n".join(lines)
