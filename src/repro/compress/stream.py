"""Compressed activation stream — the transport form of a Zebra-masked map.

This is the byte-level object the paper's accelerator moves over DRAM
(Eq. 2/3): a dense payload of the surviving ``(bs, bc)`` blocks plus a
packed 1-bit-per-block keep index. See README.md §Compressed activation
transport for the exact layout.

``CompressedMap`` is a pytree, so it can cross jit boundaries, be shipped
between hosts, or sit in a checkpoint. Measured byte counts
(``payload_bytes`` / ``index_bytes``) are *observed* stream lengths, which
``BandwidthMeter`` reconciles against the analytic ``stored_bits``
prediction from ``core.bandwidth``.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..core.bandwidth import TokenMapSpec
from ..kernels import ref
from ..kernels.mask_pack import zebra_mask_pack
from ..kernels.pack import zebra_pack, zebra_unpack
from ..utils import cdiv


# ---------------------------------------------------------------------------
# 1-bit block index (Eq. 3): little-endian bit order, row-major block order
# ---------------------------------------------------------------------------

def pack_bitmap(bitmap: jax.Array) -> jax.Array:
    """(Mb, Kb) keep flags -> (ceil(n_blocks/8),) uint8. Bit b of byte i is
    block i*8 + b (little-endian within the byte)."""
    flat = bitmap.reshape(-1).astype(jnp.uint8)
    n = flat.shape[0]
    pad = cdiv(n, 8) * 8 - n
    flat = jnp.pad(flat, (0, pad))
    weights = (jnp.uint8(1) << jnp.arange(8, dtype=jnp.uint8))
    return jnp.sum(flat.reshape(-1, 8) * weights, axis=1).astype(jnp.uint8)


def unpack_bitmap(packed: jax.Array, nm: int, nk: int) -> jax.Array:
    """Inverse of pack_bitmap -> (nm, nk) int8 keep flags."""
    bits = (packed[:, None] >> jnp.arange(8, dtype=jnp.uint8)) & jnp.uint8(1)
    return bits.reshape(-1)[: nm * nk].reshape(nm, nk).astype(jnp.int8)


# ---------------------------------------------------------------------------
# The stream object
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CompressedMap:
    """One compressed activation map: worst-case payload buffer (live blocks
    first, zero tail), packed index, and the measured live count.

    ``checksum`` is the optional in-band integrity word
    (``compress.integrity.stream_checksum`` — uint32 position-mixed XOR
    fold over bitmap bits + live payload + n_live). ``None`` (default)
    keeps the pre-integrity wire format; producers attach it when
    ``ZebraConfig.validation == "checksum"`` and ingest boundaries
    recompute and compare."""
    payload: jax.Array          # (n_blocks, bs, bc), activation dtype
    index: jax.Array            # (ceil(n_blocks/8),) uint8
    n_live: jax.Array           # () int32
    shape: tuple[int, ...]      # original (pre-flatten) map shape
    m: int                      # flattened rows
    k: int                      # flattened cols
    bs: int
    bc: int
    checksum: jax.Array | None = None   # () uint32, or None (unchecksummed)

    def tree_flatten(self):
        return ((self.payload, self.index, self.n_live, self.checksum),
                (self.shape, self.m, self.k, self.bs, self.bc))

    @classmethod
    def tree_unflatten(cls, aux, children):
        payload, index, n_live, checksum = children
        return cls(payload, index, n_live, *aux, checksum=checksum)

    # --- measured stream accounting (host side; n_live must be concrete) ---
    @property
    def n_blocks(self) -> int:
        return (self.m // self.bs) * (self.k // self.bc)

    @property
    def itemsize(self) -> int:
        return jnp.dtype(self.payload.dtype).itemsize

    def payload_bytes(self) -> int:
        """Bytes of surviving-block data actually in the stream."""
        return int(self.n_live) * self.bs * self.bc * self.itemsize

    def index_bytes(self) -> int:
        return int(self.index.size)       # uint8

    def measured_bytes(self) -> int:
        return self.payload_bytes() + self.index_bytes()

    def dense_bytes(self) -> int:
        return self.m * self.k * self.itemsize

    def zero_frac(self) -> float:
        return 1.0 - int(self.n_live) / max(self.n_blocks, 1)

    def spec(self) -> TokenMapSpec:
        """The analytic MapSpec this stream instantiates (for Eq. 2/3)."""
        return TokenMapSpec(s=self.m, d=self.k, bits=self.itemsize * 8,
                            block_seq=self.bs, block_ch=self.bc)


# ---------------------------------------------------------------------------
# Codec entry points
# ---------------------------------------------------------------------------

def nonzero_bitmap(x: jax.Array, bs: int, bc: int) -> jax.Array:
    """Keep flags for lossless transport of an already-masked map: keep any
    block with at least one nonzero element."""
    M, K = x.shape
    xb = x.reshape(M // bs, bs, K // bc, bc)
    return (jnp.max(jnp.abs(xb), axis=(1, 3)) > 0).astype(jnp.int8)


def compress(x: jax.Array, bitmap: jax.Array | None = None, *, bs: int = 8,
             bc: int = 128, use_kernel: bool = True, interpret: bool = True,
             checksum: bool = False) -> CompressedMap:
    """(..., K) map -> CompressedMap. Leading dims flatten onto M. With no
    bitmap the nonzero-block bitmap is used (always lossless).
    ``checksum=True`` computes the in-band integrity word in-graph
    (``integrity.stream_checksum``) and carries it on the map."""
    shape = tuple(x.shape)
    x2 = x.reshape(-1, shape[-1])
    M, K = x2.shape
    if bitmap is None:
        bitmap = nonzero_bitmap(x2, bs, bc)
    if use_kernel:
        payload, n_live = zebra_pack(x2, bitmap, bs=bs, bc=bc,
                                     interpret=interpret)
    else:
        payload, n_live = ref.zebra_pack_ref(x2, bitmap, bs, bc)
    csum = None
    if checksum:
        from .integrity import stream_checksum
        csum = stream_checksum(payload, bitmap, n_live)
    return CompressedMap(payload=payload, index=pack_bitmap(bitmap),
                         n_live=n_live, shape=shape, m=M, k=K, bs=bs, bc=bc,
                         checksum=csum)


def decompress(cm: CompressedMap, *, use_kernel: bool = True,
               interpret: bool = True) -> jax.Array:
    bitmap = unpack_bitmap(cm.index, cm.m // cm.bs, cm.k // cm.bc)
    if use_kernel:
        x2 = zebra_unpack(cm.payload, bitmap, bs=cm.bs, bc=cm.bc,
                          interpret=interpret)
    else:
        x2 = ref.zebra_unpack_ref(cm.payload, bitmap, cm.bs, cm.bc)
    return x2.reshape(cm.shape)


def compress_masked(x: jax.Array, t_obj: float, *, bs: int = 8, bc: int = 128,
                    interpret: bool = True, checksum: bool = False
                    ) -> CompressedMap:
    """Streaming lossy codec entry: raw (..., K) map -> Zebra-thresholded
    CompressedMap via the two-phase parallel producer (``zebra_mask_pack``)
    — the dense masked map is never materialized on the way into the
    stream."""
    shape = tuple(x.shape)
    x2 = x.reshape(-1, shape[-1])
    M, K = x2.shape
    payload, bitmap, n_live = zebra_mask_pack(x2, t_obj=t_obj, bs=bs, bc=bc,
                                              interpret=interpret)
    csum = None
    if checksum:
        from .integrity import stream_checksum
        csum = stream_checksum(payload, bitmap, n_live)
    return CompressedMap(payload=payload, index=pack_bitmap(bitmap),
                         n_live=n_live, shape=shape, m=M, k=K, bs=bs, bc=bc,
                         checksum=csum)


def transport_tokens(x: jax.Array, t_obj: float, *, bs: int = 8, bc: int = 128,
                     interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """The full inference-site round trip in streaming form:
    ``zebra_mask_pack`` -> ``zebra_unpack`` — only the (payload, bitmap)
    stream between producer and expander. Returns (masked map, keep
    bitmap). Numerically identical to masking alone — but it
    *materializes* the compressed stream, so the serve path observably
    moves compressed bytes when use_kernel is on."""
    shape = tuple(x.shape)
    x2 = x.reshape(-1, shape[-1])
    payload, bitmap, _ = zebra_mask_pack(x2, t_obj=t_obj, bs=bs, bc=bc,
                                         interpret=interpret)
    y2 = zebra_unpack(payload, bitmap, bs=bs, bc=bc, interpret=interpret)
    return y2.reshape(shape), bitmap


# ---------------------------------------------------------------------------
# Pytree transport (e.g. the prefill -> decode KV-cache handoff)
# ---------------------------------------------------------------------------

def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p))))
                    for p in path)


def compress_tree(tree: Any, *, bs: int = 8, bc: int = 128,
                  use_kernel: bool = True, interpret: bool = True,
                  meter=None, site: str = "acts",
                  checksum: bool = False) -> Any:
    """Compress every compatible floating leaf of a pytree (lossless,
    nonzero-block bitmap); incompatible leaves pass through dense. Each leaf
    is recorded on `meter` under "<site>/<path>". ``checksum=True``
    attaches the in-band integrity word per compressed leaf."""
    def one(path, leaf):
        name = f"{site}/{_path_str(path)}"
        dims = None
        if hasattr(leaf, "ndim") and leaf.ndim >= 2 and \
                jnp.issubdtype(leaf.dtype, jnp.floating):
            for nd in (1, 2):
                k = int(np.prod(leaf.shape[-nd:]))
                m = int(np.prod(leaf.shape[:-nd])) if leaf.ndim > nd else 0
                if m and k % bc == 0 and m % bs == 0:
                    dims = (m, k)
                    break
        if dims is None:
            if meter is not None:
                meter.record_dense(name, int(leaf.size) *
                                   jnp.dtype(leaf.dtype).itemsize)
            return leaf
        cm = compress(leaf.reshape(dims), bs=bs, bc=bc, use_kernel=use_kernel,
                      interpret=interpret, checksum=checksum)
        cm = dataclasses.replace(cm, shape=tuple(leaf.shape))
        if meter is not None:
            meter.record(name, cm)
        return cm

    return jax.tree_util.tree_map_with_path(one, tree)


def decompress_tree(tree: Any, *, use_kernel: bool = True,
                    interpret: bool = True) -> Any:
    return jax.tree_util.tree_map(
        lambda l: decompress(l, use_kernel=use_kernel, interpret=interpret)
        if isinstance(l, CompressedMap) else l,
        tree, is_leaf=lambda l: isinstance(l, CompressedMap))
