"""Shared small utilities: PRNG plumbing, pytree helpers, shape math."""
from __future__ import annotations

import math
from typing import Any, Iterable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def key_iter(key: jax.Array):
    """Infinite iterator of fresh PRNG keys."""
    while True:
        key, sub = jax.random.split(key)
        yield sub


def param_count(tree: PyTree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def param_bytes(tree: PyTree) -> int:
    return sum(
        int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
        for x in jax.tree_util.tree_leaves(tree)
    )


def tree_cast(tree: PyTree, dtype) -> PyTree:
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree,
    )


def tree_zeros_like(tree: PyTree) -> PyTree:
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_scale(tree: PyTree, s) -> PyTree:
    return jax.tree_util.tree_map(lambda x: x * s, tree)


def global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def check_finite(tree: PyTree) -> jax.Array:
    """True iff every leaf is finite."""
    leaves = [jnp.all(jnp.isfinite(x)) for x in jax.tree_util.tree_leaves(tree)
              if jnp.issubdtype(x.dtype, jnp.floating)]
    return jnp.all(jnp.stack(leaves)) if leaves else jnp.asarray(True)


def pallas_eqns(jaxpr) -> list:
    """Every pallas_call equation in a jaxpr, in trace order, recursing
    through sub-jaxprs. THE launch counter — the structural contract
    tests (tests/test_mask_pack.py) and the kernel benchmarks
    (benchmarks/kernel_bench.py) must count the same way, so both use
    this."""
    out = []
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            out.append(eqn)
            continue                     # kernel bodies never nest launches
        for v in eqn.params.values():
            for sub in (v if isinstance(v, (tuple, list)) else (v,)):
                if isinstance(sub, jax.core.ClosedJaxpr):
                    out.extend(pallas_eqns(sub.jaxpr))
                elif isinstance(sub, jax.core.Jaxpr):
                    out.extend(pallas_eqns(sub))
    return out


def pallas_grids(jaxpr) -> list[tuple[int, ...]]:
    """Grid shape of every pallas_call in a jaxpr, in trace order."""
    return [tuple(e.params["grid_mapping"].grid) for e in pallas_eqns(jaxpr)]


def human_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f} {unit}"
        n /= 1024.0
    return f"{n:.2f} PiB"


def human_flops(n: float) -> str:
    for unit in ("FLOP", "KFLOP", "MFLOP", "GFLOP", "TFLOP", "PFLOP"):
        if abs(n) < 1000.0:
            return f"{n:.2f} {unit}"
        n /= 1000.0
    return f"{n:.2f} EFLOP"
