"""Production mesh builders.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — required for the dry-run's
XLA_FLAGS ordering (launch/dryrun.py sets the 512-device flag before any
jax initialization).
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    """jax.make_mesh across jax versions: axis_types=Auto exists only on
    newer releases; older ones default to Auto semantics without it."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod ("data","model"); 2 pods adds a pure-DP "pod"
    axis (cross-pod traffic = one gradient all-reduce per step, DCN-friendly).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(data: int | None = None, model: int = 1):
    """Mesh over whatever devices exist — the elastic-scaling entry point:
    axis sizes are derived from the live device count at (re)launch, and
    every sharding rule is expressed against axis *names*, so any
    (pods, data, model) factorization lowers unchanged (DESIGN.md §5)."""
    n = len(jax.devices())
    if data is None:
        data = n // model
    return _make_mesh((data, model), ("data", "model"))


def batch_axes(mesh) -> tuple[str, ...]:
    """Axes carrying the global batch (pure DP axes + the FSDP axis)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
