# NOTE: do not import dryrun here — it sets XLA_FLAGS at import time.
from .mesh import make_production_mesh, make_host_mesh, batch_axes  # noqa: F401
