"""Roofline terms from compiled dry-run artifacts (EXPERIMENTS.md §Roofline).

TPU v5e hardware constants (per assignment):
  197 TFLOP/s bf16 per chip; 819 GB/s HBM; ~50 GB/s/link ICI.

Convention: after SPMD partitioning, ``compiled.cost_analysis()`` describes
the PER-DEVICE program, so flops/bytes here are per-device; the assignment
formula ``HLO_FLOPs / (chips × peak)`` with global FLOPs is identical to
``flops_per_device / peak``. Collective bytes are summed from the
per-device HLO, so they are also per-device wire bytes.
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # B/s per chip
ICI_BW = 50e9              # B/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(m: re.Match) -> float:
    dt, dims = m.group(1), m.group(2)
    if dt not in _DTYPE_BYTES:
        return 0.0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.-]+)\s*=\s*(.*)$")


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum wire bytes of every collective op in the (per-device) HLO.

    Detection keys off the instruction NAME (XLA names instructions after
    their opcode: %all-gather.42, %all-reduce.1, ...), which is immune to
    opcode strings appearing inside op_name metadata. The wire-byte proxy
    per op is the largest shape printed before the opcode token (the
    result for all-gather/all-to-all/permute — the gathered size; the
    operand-sized ring payload for all-reduce; for reduce-scatter the
    result prefix is the scattered shard, an undercount we accept
    uniformly across cells). ``-done`` halves of async pairs are skipped.
    """
    out: dict[str, float] = {k: 0.0 for k in COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if m is None:
            continue
        name, rhs = m.group(1), m.group(2)
        kind = next((k for k in COLLECTIVES if name.startswith(k)), None)
        if kind is None or name.startswith(f"{kind}-done"):
            continue
        opc = f"{kind}-start(" if name.startswith(f"{kind}-start") else f"{kind}("
        prefix = rhs.split(opc)[0]
        sizes = [_shape_bytes(s) for s in _SHAPE_RE.finditer(prefix)]
        if not sizes:
            continue
        out[kind] += max(sizes)
        out["count"] += 1
    out["total"] = sum(out[k] for k in COLLECTIVES)
    return out


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float          # 6·N·D (train) / 2·N_active·D (inference)
    hlo_flops_global: float
    useful_ratio: float         # model_flops / hlo_flops_global
    ideal_s: float              # model_flops / (chips·peak)
    fraction: float             # ideal_s / max(term)  -> roofline fraction
    bottleneck: str

    def row(self) -> dict:
        return dataclasses.asdict(self)


def analyze(*, flops_pd: float, bytes_pd: float, coll_bytes_pd: float,
            chips: int, n_params_active: int, tokens: int, kind: str) -> Roofline:
    compute_s = flops_pd / PEAK_FLOPS
    memory_s = bytes_pd / HBM_BW
    collective_s = coll_bytes_pd / ICI_BW
    mult = 6.0 if kind == "train" else 2.0
    model_flops = mult * n_params_active * tokens
    hlo_global = flops_pd * chips
    ideal = model_flops / (chips * PEAK_FLOPS)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    frac = ideal / max(max(terms.values()), 1e-30)
    return Roofline(compute_s, memory_s, collective_s, model_flops,
                    hlo_global, model_flops / max(hlo_global, 1e-30),
                    ideal, frac, bottleneck)
