import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init,
# and the production meshes below need 512 placeholder host devices.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell and
extract the roofline terms from the compiled artifact.

  PYTHONPATH=src python -m repro.launch.dryrun --arch command-r-35b \
      --shape train_4k [--multi-pod] [--out benchmarks/artifacts]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Success criterion (assignment): .lower().compile() succeeds, prints
memory_analysis() (fits) and cost_analysis() (FLOPs/bytes for §Roofline).
Artifacts are written as JSON for benchmarks/roofline.py and EXPERIMENTS.md.
"""
import argparse
import json
import time
import traceback


def _compile_cell(arch, shape_name, mesh, overrides):
    from .steps import build_cell
    cell = build_cell(arch, shape_name, mesh, overrides)
    with mesh:
        compiled = cell.fn.lower(*cell.args).compile()
    return cell, compiled


def _costs(compiled, rl):
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    coll = rl.collective_bytes(compiled.as_text())
    return (float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0)),
            coll)


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             overrides: dict | None = None, tag: str = "baseline") -> dict:
    """Compile the FULL rolled model (memory_analysis = the fits-proof),
    then two small UNROLLED variants A (1 superlayer) / B (2 superlayers)
    whose exact per-superlayer cost delta extrapolates the true FLOPs /
    bytes / collective bytes — XLA's cost analysis counts while-loop
    (scan) bodies once, so the rolled counts alone undercount by the trip
    count (see EXPERIMENTS.md §Dry-run methodology)."""
    import jax
    from . import roofline as rl
    from .mesh import make_production_mesh
    from .. import configs as cfglib
    from ..models.lm.model import layer_runs

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    t0 = time.time()
    cell, compiled = _compile_cell(arch, shape_name, mesh, overrides)
    t_compile = time.time() - t0
    t_lower = 0.0

    mem = compiled.memory_analysis()
    print(f"--- memory_analysis [{arch} x {shape_name} x "
          f"{'multi' if multi_pod else 'single'}-pod] ---")
    print(mem)
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    print("--- cost_analysis (per-device, rolled; see extrapolation below) ---")
    print({k: v for k, v in sorted(ca.items()) if "{" not in k})

    # --- A/B extrapolation over superlayer count ---
    cfg = cell.cfg
    P = len(cfg.layer_pattern)
    g, r = divmod(cfg.n_layers, P)
    ov = dict(overrides or {})
    ov["unroll_runs"] = True
    # cost-extraction variants take the attend_full path (identical op
    # totals, but no attention-internal scan — XLA's cost analysis counts
    # while bodies once, which would otherwise hide attention FLOPs) and
    # the banded local path for the same reason. memory_analysis above is
    # from the production (chunked/rolled) compile.
    from ..configs.shapes import SHAPES as _SH
    ov.setdefault("attn_chunk", max(_SH[shape_name].seq_len, cfg.attn_chunk))
    ov.setdefault("local_impl", "banded")

    def variant(m):
        v = dict(ov)
        v["n_layers"] = m * P + r
        if cfg.encoder_layers:
            v["encoder_layers"] = max(1, round(cfg.encoder_layers * m / max(g, 1)))
        _, comp = _compile_cell(arch, shape_name, mesh, v)
        return _costs(comp, rl)

    if g > 1:
        fA, bA, cA = variant(1)
        fB, bB, cB = variant(2)
        scale = g - 1
        flops_pd = fA + scale * (fB - fA)
        bytes_pd = bA + scale * (bB - bA)
        coll = {k: cA[k] + scale * (cB[k] - cA[k]) for k in cA}
    else:
        flops_pd, bytes_pd, coll = _costs(compiled, rl)
    print(f"extrapolated per-device: flops={flops_pd:.4g} bytes={bytes_pd:.4g} "
          f"collective={coll['total']:.4g}")

    cfg = cell.cfg
    counts = cfg.param_counts()
    kind = cell.shape.kind
    tokens = (cell.shape.global_batch * cell.shape.seq_len
              if kind in ("train", "prefill") else cell.shape.global_batch)
    roof = rl.analyze(flops_pd=flops_pd, bytes_pd=bytes_pd,
                      coll_bytes_pd=coll["total"], chips=chips,
                      n_params_active=counts["active"], tokens=tokens,
                      kind=kind)

    art = {
        "arch": arch, "shape": shape_name, "tag": tag,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "kind": kind, "tokens": tokens,
        "params_total": counts["total"], "params_active": counts["active"],
        "flops_per_device": flops_pd, "bytes_per_device": bytes_pd,
        "collective_bytes_per_device": coll,
        "memory_analysis": str(mem),
        "peak_memory_per_device": getattr(mem, "temp_size_in_bytes", None),
        "argument_size": getattr(mem, "argument_size_in_bytes", None),
        "output_size": getattr(mem, "output_size_in_bytes", None),
        "roofline": roof.row(),
        "lower_s": t_lower, "compile_s": t_compile,
    }
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        pod = "mp" if multi_pod else "sp"
        fn = os.path.join(out_dir, f"{arch}__{shape_name}__{pod}__{tag}.json")
        with open(fn, "w") as f:
            json.dump(art, f, indent=1)
        print("artifact ->", fn)
    r = roof
    print(f"roofline: compute={r.compute_s*1e3:.2f}ms memory={r.memory_s*1e3:.2f}ms "
          f"collective={r.collective_s*1e3:.2f}ms bottleneck={r.bottleneck} "
          f"useful={r.useful_ratio:.3f} fraction={r.fraction:.3f}")
    return art


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="benchmarks/artifacts")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--overrides", default=None, help="JSON dict of LMConfig overrides")
    args = ap.parse_args()

    from .. import configs
    from ..configs.shapes import cells as shape_cells

    overrides = json.loads(args.overrides) if args.overrides else None
    todo: list[tuple[str, str, bool]] = []
    if args.all:
        for arch in configs.ARCHS:
            for sc in shape_cells(arch):
                todo.append((arch, sc.name, False))
                if args.both_meshes or args.multi_pod:
                    todo.append((arch, sc.name, True))
    else:
        meshes = [args.multi_pod] if not args.both_meshes else [False, True]
        for mp in meshes:
            todo.append((args.arch, args.shape, mp))

    failures = []
    for arch, shape, mp in todo:
        print(f"\n=== DRY-RUN {arch} x {shape} x {'2x16x16' if mp else '16x16'} ===",
              flush=True)
        try:
            run_cell(arch, shape, mp, args.out, overrides, args.tag)
        except Exception as e:  # noqa: BLE001 - report and continue
            traceback.print_exc()
            failures.append((arch, shape, mp, repr(e)))
    if failures:
        print("\nFAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print(f"\nall {len(todo)} dry-run cells passed")


if __name__ == "__main__":
    main()
