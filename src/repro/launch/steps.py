"""jit-able train / prefill / decode steps with full sharding annotations.

``build_cell(arch, shape, mesh)`` returns everything the dry-run, the
trainer and the server need: the jitted function, ShapeDtypeStruct args
(no allocation), and the in/out shardings. The same builders drive real
execution on hardware — dry-run and production share one code path.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import configs
from ..configs.shapes import SHAPES, ShapeCell
from ..distributed import sharding as shd
from ..distributed.ctx import sharding_hints
from ..models.lm import LM, LMConfig
from ..optim import adamw, apply_updates, clip_by_global_norm, warmup_cosine
from ..optim.compress import compressed_gradients


@dataclasses.dataclass
class Cell:
    arch: str
    shape: ShapeCell
    cfg: LMConfig
    fn: Callable                    # jitted
    args: tuple                     # ShapeDtypeStructs
    model: LM
    donate: tuple = ()


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------

def _hint_args(cfg, mesh):
    from ..distributed.sharding import dp as dp_fn
    pure_dp = getattr(cfg, "sharding_profile", "tp") == "dp"
    return dict(dp=dp_fn(mesh, cfg), tp=None if pure_dp else "model")


def make_train_step(model: LM, opt, mesh, compress: str = "bf16",
                    grad_clip: float = 1.0):
    cfg = model.cfg

    def train_step(state, batch):
        with sharding_hints(mesh, **_hint_args(cfg, mesh)):
            params = state["params"]
            K = max(getattr(cfg, "grad_accum", 1), 1)

            def loss_fn(p, toks, enc):
                return model.loss(p, toks, "train", enc)

            if K == 1:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch["tokens"],
                                           batch.get("enc_feats"))
            else:
                # microbatched gradient accumulation: activation memory /K
                B = batch["tokens"].shape[0]
                toks = batch["tokens"].reshape(K, B // K, -1)
                enc = batch.get("enc_feats")
                enc = (enc.reshape(K, B // K, *enc.shape[1:])
                       if enc is not None else None)

                from ..core.engine import MB_BASE, add_byte_pair

                def micro(acc, i):
                    g_acc, l_acc, m_acc = acc
                    e_i = enc[i] if enc is not None else None
                    (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                        params, toks[i], e_i)
                    g_acc = jax.tree_util.tree_map(
                        lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                    # the byte pair takes the exact int32-carry add (a
                    # plain f32 add rounds the lo legs past 2**24); the
                    # f32 display value is dropped and rebuilt after the
                    # scan — no point accumulating a rounding readout
                    m = dict(m)
                    m.pop("measured_bytes")
                    hi, lo = add_byte_pair(
                        m_acc["measured_bytes_hi"], m_acc["measured_bytes_lo"],
                        m.pop("measured_bytes_hi"), m.pop("measured_bytes_lo"))
                    m_acc = dict({k: m_acc[k] + m[k] for k in m},
                                 measured_bytes_hi=hi, measured_bytes_lo=lo)
                    return (g_acc, l_acc + l, m_acc), None

                g0 = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                m0 = {k: jnp.float32(0.0) for k in
                      ("ce", "zebra_reg", "zero_frac", "router_aux",
                       "measured_bytes_hi", "measured_bytes_lo")}
                (grads, loss, metrics), _ = jax.lax.scan(
                    micro, (g0, jnp.float32(0.0), m0), jnp.arange(K))
                grads = jax.tree_util.tree_map(lambda g: g / K, grads)
                loss = loss / K
                # bytes are extensive (total moved for the whole global
                # batch), not a per-microbatch mean like ce/zero_frac —
                # the (hi, lo) legs stay the exact accumulated pair
                bkeys = ("measured_bytes_hi", "measured_bytes_lo")
                metrics = {k: (v if k in bkeys else v / K)
                           for k, v in metrics.items()}
                metrics["measured_bytes"] = (
                    metrics["measured_bytes_hi"] * jnp.float32(MB_BASE)
                    + metrics["measured_bytes_lo"])
            grads, comp_state = compressed_gradients(
                grads, state["compress"], compress)
            grads, gnorm = clip_by_global_norm(grads, grad_clip)
            updates, opt_state = opt.update(grads, state["opt"], params,
                                            state["step"])
            params = apply_updates(params, updates)
            metrics = dict(metrics, loss=loss, grad_norm=gnorm)
            new_state = {"params": params, "opt": opt_state,
                         "compress": comp_state, "step": state["step"] + 1}
            return new_state, metrics
    return train_step


def make_train_state_shape(model: LM, opt, compress: str = "bf16"):
    """Abstract train state via eval_shape (no allocation)."""
    def init_fn(key):
        params = model.init(key)
        from ..optim.compress import init_state
        return {"params": params, "opt": opt.init(params),
                "compress": init_state(params, compress),
                "step": jnp.zeros((), jnp.int32)}
    return jax.eval_shape(init_fn, jax.random.PRNGKey(0)), init_fn


def train_state_specs(state_shape, cfg: LMConfig, mesh):
    return {
        "params": shd.param_specs(state_shape["params"], cfg, mesh),
        "opt": shd.param_specs(state_shape["opt"], cfg, mesh),
        "compress": shd.param_specs(state_shape["compress"], cfg, mesh),
        "step": P(),
    }


# ---------------------------------------------------------------------------
# Serve steps
# ---------------------------------------------------------------------------

def make_prefill(model: LM, mesh):
    def prefill(params, tokens, enc_feats=None):
        with sharding_hints(mesh, **_hint_args(model.cfg, mesh)):
            cache_len = tokens.shape[1]
            return model.prefill(params, tokens, cache_len, enc_feats)
    return prefill


def make_decode_step(model: LM, mesh):
    def decode_step(params, token, state, pos):
        with sharding_hints(mesh, **_hint_args(model.cfg, mesh)):
            return model.decode_step(params, token, state, pos)
    return decode_step


def _next_token(logits, temperature: float, key, i=None):
    """Greedy argmax at temperature 0.0; categorical sampling otherwise
    (``key`` folded with the step index when scanning)."""
    if temperature > 0.0:
        if key is None:
            raise ValueError("temperature > 0 requires a PRNG key")
        if i is not None:
            key = jax.random.fold_in(key, i)
        nxt = jax.random.categorical(
            key, logits.astype(jnp.float32) / temperature, axis=-1)
    else:
        nxt = jnp.argmax(logits, axis=-1)
    return nxt.astype(jnp.int32)[:, None]


def make_generate(model: LM, mesh, steps: int, temperature: float = 0.0):
    """Whole-generation decode as ONE jitted ``lax.scan`` over the
    decode step — a single dispatch for ``steps`` tokens instead of one
    Python-loop dispatch per token. ``temperature=0.0`` (default) is
    greedy argmax; > 0 samples from the softmax at that temperature, in
    which case ``generate`` takes a PRNG ``key`` (folded per step).

    ``state`` may arrive with its KV caches in compressed payload form
    (``CompressedMap`` leaves from serve.py's prefill -> decode handoff):
    what crosses the jit boundary is the (payload, bitmap) stream, and the
    caches are unpacked here, inside the dispatch, before the scan.

    generate(params, tok0 (B,1), state, pos0[, key])
        -> (tokens (B, steps), state)
    """
    from ..compress import decompress_tree

    def generate(params, tok0, state, pos0, key=None):
        with sharding_hints(mesh, **_hint_args(model.cfg, mesh)):
            state = decompress_tree(state)     # no-op for dense caches

            def body(carry, i):
                tok, st = carry
                logits, st = model.decode_step(params, tok, st, pos0 + i)
                nxt = _next_token(logits, temperature, key, i)
                return (nxt, st), nxt

            (_, state_out), toks = jax.lax.scan(
                body, (tok0, state), jnp.arange(steps, dtype=jnp.int32))
            return jnp.moveaxis(toks[..., 0], 0, 1), state_out
    return generate


def make_decode_slotted(model: LM, mesh, temperature: float = 0.0):
    """One continuous-batching decode step across B independent request
    lanes: ``token (B,1)``, ``pos (B,)`` — each lane at its own sequence
    position (serve/engine.py's hot path). Returns the per-lane next
    token alongside the updated state; ``key`` is ignored at temperature
    0.0 but stays in the signature so the jitted dispatch shape set is
    sampler-independent.

    Unlike the compressed prefill->decode handoff (whose payload buffers
    can't back the dense outputs — PR 3 dropped donation there), the hot
    state here IS the dense working set, with the compressed slabs owned
    by the pool: the caller jits this with ``donate_argnums=(2,)`` and
    the cache buffers are reused in place across every step.
    """
    def decode_slotted(params, token, state, pos, key):
        with sharding_hints(mesh, **_hint_args(model.cfg, mesh)):
            logits, state = model.decode_step(params, token, state, pos)
            return _next_token(logits, temperature, key, None), state
    return decode_slotted


# ---------------------------------------------------------------------------
# Cell builder (arch x shape x mesh)
# ---------------------------------------------------------------------------

def cell_config(arch: str, shape: ShapeCell, overrides: dict | None = None) -> LMConfig:
    cfg = configs.get(arch)
    kw: dict[str, Any] = dict(overrides or {})
    if shape.kind in ("prefill", "decode"):
        kw.setdefault("param_dtype", "bfloat16")   # serving weights in bf16
        kw.setdefault("zebra_sites", tuple(cfg.zebra_sites) + ("kv_cache",))
    return cfg.replace(**kw)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_cell(arch: str, shape_name: str, mesh, overrides: dict | None = None,
               compress: str = "bf16") -> Cell:
    shape = SHAPES[shape_name]
    cfg = cell_config(arch, shape, overrides)
    model = LM(cfg)
    B, S = shape.global_batch, shape.seq_len
    dpspec = shd.batch_spec(mesh, 2, B, cfg)
    ns = lambda spec: jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec,
        is_leaf=lambda x: isinstance(x, P))

    if shape.kind == "train":
        opt = adamw(warmup_cosine(3e-4, 2000, 100_000))
        state_shape, _ = make_train_state_shape(model, opt, compress)
        sspec = train_state_specs(state_shape, cfg, mesh)
        batch = {"tokens": _sds((B, S + 1), jnp.int32)}
        bspec = {"tokens": shd.batch_spec(mesh, 2, B, cfg)}
        if cfg.encoder_layers:
            batch["enc_feats"] = _sds((B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
            bspec["enc_feats"] = shd.batch_spec(mesh, 3, B, cfg)
        fn = jax.jit(make_train_step(model, opt, mesh, compress),
                     in_shardings=(ns(sspec), ns(bspec)),
                     out_shardings=(ns(sspec), None),
                     donate_argnums=(0,))
        return Cell(arch, shape, cfg, fn, (state_shape, batch), model, (0,))

    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspec = shd.param_specs(params_shape, cfg, mesh)

    if shape.kind == "prefill":
        tokens = _sds((B, S), jnp.int32)
        args = [params_shape, tokens]
        in_sh = [ns(pspec), NamedSharding(mesh, dpspec)]
        if cfg.encoder_layers:
            args.append(_sds((B, cfg.enc_seq, cfg.d_model), jnp.bfloat16))
            in_sh.append(NamedSharding(mesh, shd.batch_spec(mesh, 3, B, cfg)))
        fn = jax.jit(make_prefill(model, mesh), in_shardings=tuple(in_sh))
        return Cell(arch, shape, cfg, fn, tuple(args), model)

    # decode: one new token with a seq_len KV cache
    cache_shape = jax.eval_shape(
        functools.partial(model.init_cache, B, S))
    cspec = [shd.cache_specs(c, cfg, mesh) for c in cache_shape]
    enc_shape = None
    espec = None
    if cfg.encoder_layers:
        enc_shape = _sds((B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
        espec = shd.batch_spec(mesh, 3, B, cfg)
    state_shape = (cache_shape, enc_shape)
    sspec = (cspec, espec)
    token = _sds((B, 1), jnp.int32)
    pos = _sds((), jnp.int32)
    fn = jax.jit(make_decode_step(model, mesh),
                 in_shardings=(ns(pspec), NamedSharding(mesh, dpspec),
                               ns(sspec), None),
                 out_shardings=(None, ns(sspec)),
                 donate_argnums=(2,))
    return Cell(arch, shape, cfg, fn, (params_shape, token, state_shape, pos),
                model, (2,))


def input_specs(arch: str, shape_name: str, mesh, overrides: dict | None = None):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    return build_cell(arch, shape_name, mesh, overrides).args
