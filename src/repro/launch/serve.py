"""Serving launcher — a thin CLI over two paths:

* one-shot batch (default): prefill a batch of prompts, then decode with
  the sharded KV cache (+ Zebra KV-cache block compression accounting);
* continuous batching (``--requests N``): serve a synthetic
  heavy-traffic trace through ``repro.serve.ServeEngine`` — request
  admission, slotted decode across in-flight requests at different
  positions, and a paged pool of compressed KV payload slabs.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b --reduced \
        --batch 4 --prompt-len 64 --gen 32
    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b --reduced \
        --requests 16 --slots 8 --gen 24 --validate structural
"""
from __future__ import annotations

import argparse
import itertools
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import configs
from ..data import LMDatasetConfig, lm_batch
from ..distributed import sharding as shd
from ..models.lm import LM
from ..serve.bucket import pow2_bucket, pow2_ceil
from .mesh import make_host_mesh
from .steps import _next_token, make_generate, make_prefill


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--t-obj", type=float, default=0.1)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy argmax; > 0 samples from the softmax "
                         "at this temperature (seeded by --seed)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--use-kernel", action="store_true",
                    help="legacy alias for --backend stream (compressed "
                         "activation transport + measured-bytes accounting)")
    ap.add_argument("--backend", default="",
                    choices=["", "reference", "pallas", "stream", "fused"],
                    help="Zebra site-engine backend for every activation "
                         "site (core.engine); stream/fused also transport "
                         "the prefill->decode KV caches compressed")
    ap.add_argument("--validate", default="off",
                    choices=["off", "structural", "checksum"],
                    help="stream-integrity level at every ingest boundary "
                         "(compress.integrity): the engine's in-graph "
                         "producer->consumer checks, host-side validation "
                         "of the prefill->decode cache handoff, and the "
                         "serve pool's per-page ingest check with dense "
                         "fallback")
    ap.add_argument("--requests", type=int, default=0,
                    help="continuous-batching mode: serve a synthetic "
                         "trace of N requests (repro.serve.ServeEngine) "
                         "instead of the one-shot batch path")
    ap.add_argument("--slots", type=int, default=4,
                    help="in-flight request lanes (continuous mode)")
    ap.add_argument("--page-tokens", type=int, default=16,
                    help="cache positions per compressed KV page")
    ap.add_argument("--preempt-after", type=int, default=0,
                    help="evict a lane to the compressed pool after this "
                         "many consecutive steps while requests wait "
                         "(0 = never)")
    ap.add_argument("--deadline-ticks", type=int, default=0,
                    help="per-request TTL in engine ticks (continuous "
                         "mode): a request that cannot finish by "
                         "arrival + TTL given the slot clock is shed at "
                         "admission, and a lane past its TTL is "
                         "cancelled mid-flight (0 = no deadlines)")
    ap.add_argument("--queue-bound", type=int, default=0,
                    help="bounded pending queue (continuous mode): "
                         "arrived waiters beyond this count are shed, "
                         "newest fresh arrivals first (0 = unbounded)")
    ap.add_argument("--supervise", action="store_true",
                    help="run the continuous engine loop under the "
                         "crash-recoverable supervisor (per-tick "
                         "snapshots + classified restore/backoff)")
    args = ap.parse_args()

    backend = args.backend or ("stream" if args.use_kernel else "")
    cfg = configs.reduced(args.arch) if args.reduced else configs.get(args.arch)
    cfg = cfg.replace(param_dtype="bfloat16",
                      zebra_sites=tuple(cfg.zebra_sites) + ("kv_cache",),
                      zebra_t_obj=args.t_obj, zebra_backend=backend,
                      zebra_validation=args.validate)
    mesh = make_host_mesh(model=args.model_parallel)
    model = LM(cfg)

    params = jax.jit(model.init)(jax.random.PRNGKey(0))
    pshard = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        shd.param_specs(params, cfg, mesh), is_leaf=lambda x: isinstance(x, P))
    params = jax.device_put(params, pshard)

    if args.requests:
        return serve_continuous(args, cfg, mesh, model, params)

    key = jax.random.PRNGKey(args.seed)
    prefill = jax.jit(make_prefill(model, mesh), static_argnames=())
    # whole-generation lax.scan: ONE dispatch for gen-1 tokens (steps.py);
    # length-0 scan at --gen 1 costs nothing. With a compressed handoff the
    # state arrives in payload form, whose buffers can't back the dense
    # outputs — donating them would only warn.
    donate = () if backend in ("stream", "fused") else (2,)
    generate = jax.jit(make_generate(model, mesh, max(args.gen - 1, 0),
                                     args.temperature),
                       donate_argnums=donate)

    ds = LMDatasetConfig(vocab=cfg.vocab)
    B, S = args.batch, args.prompt_len
    prompts = jnp.asarray(lm_batch(ds, B, S, 0)[:, :S])
    enc = (jnp.zeros((B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
           if cfg.encoder_layers else None)

    cache_len = S + args.gen
    t0 = time.time()
    if enc is not None:
        logits, state, aux = jax.block_until_ready(
            model_prefill_pad(prefill, params, prompts, cache_len, enc))
    else:
        logits, state, aux = jax.block_until_ready(
            model_prefill_pad(prefill, params, prompts, cache_len))
    t_pref = time.time() - t0
    # named SiteAux/LayerAux fields; zero_frac guards the n_blocks == 0
    # (no block-divisible site) case internally
    n_blocks = float(aux.n_blocks)
    zebra_zero_frac = float(aux.zero_frac)
    measured_bytes = float(aux.measured_bytes_exact())  # exact past 16 MiB
    if backend in ("stream", "fused"):
        state = transport_state_compressed(state, cfg,
                                           validation=args.validate)
    # first token gets its own fold (2^32-1 can't collide with the scan's
    # per-step fold_in(key, i), i < gen)
    tok = _next_token(logits, args.temperature,
                      jax.random.fold_in(key, 2**32 - 1))

    t0 = time.time()
    if args.temperature > 0.0:
        toks, state = generate(params, tok, state, jnp.int32(S), key)
    else:
        toks, state = generate(params, tok, state, jnp.int32(S))
    jax.block_until_ready(toks)
    t_dec = time.time() - t0
    gen = np.asarray(jnp.concatenate([tok, toks], axis=1))[:, :args.gen]
    print(f"[serve] {cfg.name} batch={B} prompt={S} gen={args.gen}")
    print(f"  prefill: {t_pref*1e3:.1f} ms  decode: "
          f"{t_dec/max(args.gen-1,1)*1e3:.2f} ms/token (single scan dispatch)")
    if n_blocks > 0:
        # block-weighted mean over every prefill Zebra site (ffn_hidden +
        # kv_cache); the kv-cache-only traffic cut is the TOTAL line of the
        # per-leaf transport report above when --backend stream/fused is on
        print(f"  zebra zero-block fraction, all prefill sites: "
              f"{zebra_zero_frac:.3f}")
    else:
        print("  zebra: no block-divisible site this shape — zero-block "
              "fraction n/a")
    if measured_bytes > 0:
        print(f"  zebra in-model transport: {measured_bytes/1e6:.3f} MB "
              f"measured compressed stream bytes (prefill sites)")
    print("  sample continuation:", gen[0, :16].tolist())


_SPOT_CHECK = itertools.count()        # rotates the sampled leaf per call


def validate_state_ingest(cstate, dense_state, level: str,
                          site: str = "serve", breaker=None):
    """Validate every ``CompressedMap`` leaf of a handoff tree at the
    consumer boundary; a corrupt leaf is replaced by its dense source
    (the ``ft.faults`` "recompute-dense" policy, applied per leaf) so one
    bad stream degrades ONE cache's transport instead of failing the
    batch. An armed chaos plan (``ft.inject``) with a stream fault at
    ``site`` corrupts leaves here — after compression, before
    validation — exercising the real ingest path.

    The handoff is also a circuit-breaker boundary: pass a
    ``ft.breaker.BreakerBoard`` (or arm one ambiently via
    ``breaker_scope``) and per-leaf detections feed its trip window;
    with the site OPEN the whole tree degrades to its dense source
    wholesale — no per-leaf validate+fallback — until half-open probes
    pass. Returns ``(tree, n_recovered)``."""
    from ..compress import CompressedMap
    from ..compress.integrity import validate_map
    from ..ft.breaker import active_board
    from ..ft.faults import CorruptStream
    from ..ft.inject import STREAM_KINDS, active_plan, corrupt_map

    is_cm = lambda l: isinstance(l, CompressedMap)
    dense_leaves = jax.tree_util.tree_leaves(dense_state)
    c_leaves, treedef = jax.tree_util.tree_flatten(cstate, is_leaf=is_cm)
    board = breaker if breaker is not None else active_board()
    if board is not None:
        board.tick()                        # call-counted breaker clock
        if not board.allow(site):
            out = [d if is_cm(c) else c
                   for d, c in zip(dense_leaves, c_leaves)]
            return jax.tree_util.tree_unflatten(treedef, out), 0
    plan = active_plan()
    out, n_bad = [], 0
    for i, (d, c) in enumerate(zip(dense_leaves, c_leaves)):
        if not is_cm(c):
            out.append(c)
            continue
        if plan is not None:
            f = plan.take(STREAM_KINDS, site)
            if f is not None:
                c = corrupt_map(c, f.kind, arg=f.arg)
                plan.note(f.kind, site)
        try:
            validate_map(c, level=level, site=f"{site}:leaf{i}")
            out.append(c)
            if board is not None and level != "off":
                board.record_success(site)
        except CorruptStream as e:
            n_bad += 1
            if board is not None:
                board.record_failure(site)
            print(f"[serve] {e} — leaf {i} recovered from its dense source")
            out.append(d)
    return jax.tree_util.tree_unflatten(treedef, out), n_bad


def transport_state_compressed(state, cfg, sample_leaf: int | None = None,
                               validation: str = "off"):
    """The prefill -> decode handoff in compressed stream form: pack every
    compatible cache leaf (lossless nonzero-block bitmap), count the bytes
    actually moved, reconcile against Eq. 2/3, and hand the caches to the
    decode loop IN PAYLOAD FORM — the ``CompressedMap`` pytree itself
    crosses the jit boundary, and ``steps.make_generate`` unpacks it
    inside the decode dispatch. Losslessness (pinned exhaustively by
    tests/test_compress.py) is spot-checked on one sampled leaf so the
    handoff doesn't pay a second full decompression for a print — the
    sample rotates across calls within a process (long-running servers /
    test suites cover every leaf; pin one with ``sample_leaf``). The Eq.
    2/3 reconcile bound is asserted for EVERY leaf individually —
    ``meter.reconcile`` raises on the first leaf outside it."""
    from ..compress import (BandwidthMeter, CompressedMap, compress_tree,
                            decompress)

    caches, enc_out = state
    meter = BandwidthMeter()
    ccaches = compress_tree(caches, bs=cfg.zebra_block_seq,
                            bc=cfg.zebra_block_ch, meter=meter, site="kv",
                            checksum=(validation == "checksum"))
    is_cm = lambda l: isinstance(l, CompressedMap)
    sampled = [(a, c) for a, c in zip(
        jax.tree_util.tree_leaves(caches),
        jax.tree_util.tree_leaves(ccaches, is_leaf=is_cm)) if is_cm(c)]
    idx = 0
    ok = True
    if sampled:
        idx = (next(_SPOT_CHECK) if sample_leaf is None else sample_leaf) \
            % len(sampled)
        ok = bool(jnp.array_equal(sampled[idx][0], decompress(sampled[idx][1])))
    # raises per leaf if any measured-predicted delta leaves the
    # index-padding bound (+1 B float-roundoff slack) — no leaf can hide
    # behind the max in the report below
    rec = meter.reconcile(tol_bytes_per_map=1.0)
    print("[serve] compressed KV-cache transport (prefill -> decode, "
          "payload form):")
    print(meter.report())
    print(f"  lossless (sampled leaf {idx + 1}/{max(len(sampled), 1)}): {ok}"
          f"  reconcile: {rec['n_sites']} sites, every leaf within the "
          f"index-padding bound, max |measured - predicted| = "
          f"{rec['max_abs_delta_bytes']:.2f} B")
    if rec["n_sites"] == 0:
        print("  WARNING: no cache leaf was block-divisible — every leaf "
              "moved dense; pick batch/prompt-len/gen so that "
              "batch*(prompt+gen) divides by zebra_block_seq")
    if validation != "off":
        (ccaches, n_bad) = validate_state_ingest(ccaches, caches, validation)
        print(f"  ingest validation ({validation}): "
              f"{'clean' if n_bad == 0 else f'{n_bad} leaf(s) recovered dense'}")
    return ccaches, enc_out


def model_prefill_pad(prefill_fn, params, prompts, cache_len, enc=None,
                      bucket=True):
    """prefill builds a cache sized to the prompt; pad it to cache_len so
    decode can run. (One jit'd pad via device_put keeps shardings.)

    ``cache_len`` is bucketed up to the power-of-two ladder
    (``serve.bucket.pow2_bucket`` — the same helper the continuous
    engine's cache ladder uses) so downstream decode jits, which key on
    the padded cache shape, compile at most once per bucket instead of
    once per distinct ``prompt+gen`` total. End-padding past the
    requested length is position-correct: the decode mask never attends
    beyond ``pos``. ``bucket=False`` keeps the exact length."""
    if enc is not None:
        logits, (caches, enc_out), aux = prefill_fn(params, prompts, enc)
    else:
        logits, (caches, enc_out), aux = prefill_fn(params, prompts)
    S = prompts.shape[1]
    if bucket:
        cache_len = pow2_bucket(max(cache_len, S), lo=8)
    pad = cache_len - S

    def padk(x):
        if x.ndim >= 4 and x.shape[-3] == S:   # (.., B, T, H, hd) attn caches
            cfgpad = [(0, 0)] * x.ndim
            cfgpad[-3] = (0, pad)
            return jnp.pad(x, cfgpad)
        return x
    caches = jax.tree_util.tree_map(padk, caches)
    return logits, (caches, enc_out), aux


def serve_continuous(args, cfg, mesh, model, params) -> None:
    """``--requests N``: run a synthetic heavy-traffic trace through the
    continuous-batching engine and print its throughput report."""
    from ..ft import FTConfig
    from ..serve import ServeEngine, synthetic_trace

    eng = ServeEngine(model, params, mesh, n_slots=args.slots,
                      max_cache_len=pow2_ceil(args.prompt_len + args.gen),
                      page_tokens=args.page_tokens,
                      validation=args.validate,
                      temperature=args.temperature, seed=args.seed,
                      queue_bound=args.queue_bound)
    trace = synthetic_trace(
        args.requests, vocab=cfg.vocab, seed=args.seed,
        prompt_lo=max(args.prompt_len // 4, 4), prompt_hi=args.prompt_len,
        gen_lo=max(args.gen // 4, 1), gen_hi=args.gen,
        deadline_ticks=args.deadline_ticks or None)
    ft_cfg = FTConfig(jitter_seed=args.seed) if args.supervise else None
    rep = eng.run(trace, preempt_after=args.preempt_after, ft_cfg=ft_cfg)
    print(f"[serve] {cfg.name} continuous: {rep['n_requests']} requests "
          f"({rep['n_rejected']} rejected, {rep['n_shed']} shed, "
          f"{rep['deadline_misses']} deadline misses) in "
          f"{rep['wall_s']:.2f} s over {args.slots} slots")
    print(f"  {rep['requests_per_s']:.2f} req/s  {rep['tokens_per_s']:.1f} "
          f"tok/s  p50 {rep['p50_token_ms']:.1f} ms/token  "
          f"p95 {rep['p95_token_ms']:.1f} ms/token  "
          f"evictions {rep['evictions']}")
    print(f"  KV stream: {rep['kv_bytes_measured']/1e6:.3f} MB measured "
          f"(dense {rep['kv_bytes_dense']/1e6:.3f} MB) over "
          f"{rep['kv_pages']} pages, zero-block fraction "
          f"{rep['zero_frac']:.3f}, {rep['pages_recovered']} pages "
          f"recovered dense")
    print(f"  dispatch shapes: decode {rep['decode_shapes']}"
          f"/{rep['decode_shape_bound']}  prefill {rep['prefill_shapes']}"
          f"/{rep['prefill_shape_bound']}  reconcile max "
          f"|measured-predicted| {rep['reconcile_max_delta_bytes']:.2f} B")


if __name__ == "__main__":
    main()
