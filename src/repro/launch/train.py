"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch gemma3-4b --reduced \
        --steps 50 --batch 8 --seq 128 --ckpt /tmp/zebra_run

Assembles the full production path — mesh from live devices, sharded jit
train step (FSDP+TP+Zebra), counter-indexed data stream, fault-tolerant
supervisor with async checkpoints + auto-resume — and runs it. On this CPU
container use --reduced; on a real slice drop it and the exact same code
drives the full config (jax.distributed.initialize() is called when the
environment advertises multiple processes).
"""
from __future__ import annotations

import argparse
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import configs
from ..data import LMDatasetConfig, StreamingLoader, lm_batch
from ..distributed import sharding as shd
from ..ft import FTConfig, StepSupervisor
from ..models.lm import LM
from ..optim import adamw, warmup_cosine
from .mesh import make_host_mesh
from .steps import make_train_state_shape, make_train_step, train_state_specs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--compress", default="bf16", choices=["none", "bf16", "int8"])
    ap.add_argument("--t-obj", type=float, default=0.1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if jax.process_count() > 1:  # multi-host slice: controller handles init
        pass

    cfg = configs.reduced(args.arch) if args.reduced else configs.get(args.arch)
    cfg = cfg.replace(zebra_t_obj=args.t_obj)
    mesh = make_host_mesh(model=args.model_parallel)
    model = LM(cfg)
    opt = adamw(warmup_cosine(args.lr, max(args.steps // 10, 1), args.steps))

    state_shape, init_fn = make_train_state_shape(model, opt, args.compress)
    sspec = train_state_specs(state_shape, cfg, mesh)
    sshard = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), sspec,
                                    is_leaf=lambda x: isinstance(x, P))
    bshard = {"tokens": NamedSharding(mesh, shd.batch_spec(mesh, 2))}

    step_fn = jax.jit(make_train_step(model, opt, mesh, args.compress),
                      in_shardings=(sshard, bshard),
                      out_shardings=(sshard, None), donate_argnums=(0,))

    ds = LMDatasetConfig(vocab=cfg.vocab, seed=args.seed)
    loader = StreamingLoader(
        lambda b, s: {"tokens": lm_batch(ds, b, args.seq, s)},
        args.batch, host_id=jax.process_index(), n_hosts=jax.process_count())

    sup = StepSupervisor(FTConfig(ckpt_dir=args.ckpt, ckpt_every=args.ckpt_every))

    def fresh():
        with mesh:
            return jax.jit(init_fn, out_shardings=sshard)(
                jax.random.PRNGKey(args.seed))
    state, start, extra = sup.resume_or_init(fresh)
    loader.restore(extra.get("loader_step", start))
    print(f"[train] {cfg.name} params={cfg.param_counts()['total']:,} "
          f"mesh={dict(mesh.shape)} start_step={start}")

    def log(step, m):
        if step % 10 == 0 or step <= 2:
            print(f"step {step:5d} loss={m['loss']:.4f} ce={m['ce']:.4f} "
                  f"zreg={m['zebra_reg']:.4f} zf={m['zero_frac']:.3f} "
                  f"gnorm={m['grad_norm']:.2f}", flush=True)

    state, step = sup.run(state, step_fn, loader, args.steps, start,
                          loader_state_fn=loader.state, on_metrics=log)
    if sup.straggler_events:
        print(f"[ft] {len(sup.straggler_events)} straggler step(s) flagged")
    print(f"[train] done at step {step}; checkpoints in {args.ckpt}")


if __name__ == "__main__":
    main()
