"""Shared NN layers (functional, pytree params). CNN side uses NCHW (paper
convention); LM side uses (B, S, D).

Every layer is an (init, apply) pair. BatchNorm keeps running stats in a
separate `state` tree so `apply` stays pure.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# ----------------------------------------------------------------------------
# Initializers
# ----------------------------------------------------------------------------

def he_normal(key, shape, dtype=jnp.float32, fan_in=None):
    if fan_in is None:
        fan_in = int(np.prod(shape[1:])) if len(shape) > 1 else shape[0]
    return jax.random.normal(key, shape, dtype) * np.sqrt(2.0 / fan_in)


def lecun_normal(key, shape, dtype=jnp.float32, fan_in=None):
    if fan_in is None:
        fan_in = int(np.prod(shape[1:])) if len(shape) > 1 else shape[0]
    return jax.random.normal(key, shape, dtype) * np.sqrt(1.0 / fan_in)


# ----------------------------------------------------------------------------
# Conv2D (NCHW / OIHW)
# ----------------------------------------------------------------------------

def conv_init(key, c_in, c_out, k, dtype=jnp.float32, groups: int = 1):
    w = he_normal(key, (c_out, c_in // groups, k, k), dtype,
                  fan_in=(c_in // groups) * k * k)
    return {"w": w}


def conv_apply(p, x, stride: int = 1, padding="SAME", groups: int = 1):
    return jax.lax.conv_general_dilated(
        x, p["w"].astype(x.dtype),
        window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=groups)


# ----------------------------------------------------------------------------
# BatchNorm (NCHW, per-channel)
# ----------------------------------------------------------------------------

def bn_init(c, dtype=jnp.float32):
    params = {"scale": jnp.ones((c,), dtype), "bias": jnp.zeros((c,), dtype)}
    state = {"mean": jnp.zeros((c,), jnp.float32), "var": jnp.ones((c,), jnp.float32)}
    return params, state


def bn_apply(p, s, x, train: bool, momentum: float = 0.9, eps: float = 1e-5):
    """Returns (y, new_state)."""
    if train:
        mean = jnp.mean(x, axis=(0, 2, 3))
        var = jnp.var(x, axis=(0, 2, 3))
        new_s = {"mean": momentum * s["mean"] + (1 - momentum) * mean.astype(jnp.float32),
                 "var": momentum * s["var"] + (1 - momentum) * var.astype(jnp.float32)}
    else:
        mean, var = s["mean"], s["var"]
        new_s = s
    inv = jax.lax.rsqrt(var.astype(jnp.float32) + eps)
    y = (x - mean[None, :, None, None].astype(x.dtype)) * inv[None, :, None, None].astype(x.dtype)
    y = y * p["scale"][None, :, None, None].astype(x.dtype) + p["bias"][None, :, None, None].astype(x.dtype)
    return y, new_s


# ----------------------------------------------------------------------------
# Dense
# ----------------------------------------------------------------------------

def dense_init(key, d_in, d_out, dtype=jnp.float32, bias=True, init=he_normal):
    p = {"w": init(key, (d_in, d_out), dtype, fan_in=d_in)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense_apply(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


# ----------------------------------------------------------------------------
# Pooling
# ----------------------------------------------------------------------------

def max_pool(x, k=2, stride=2):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 1, k, k), (1, 1, stride, stride), "VALID")


def avg_pool(x, k=2, stride=2):
    s = jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, 1, k, k), (1, 1, stride, stride), "VALID")
    return s / (k * k)


def global_avg_pool(x):
    return jnp.mean(x, axis=(2, 3))


# ----------------------------------------------------------------------------
# Norms for LM side
# ----------------------------------------------------------------------------

def rmsnorm_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_apply(p, x, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm_apply(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)
