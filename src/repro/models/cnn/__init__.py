from .vgg import VGG16  # noqa: F401
from .resnet import ResNet, resnet18, resnet56  # noqa: F401
from .mobilenet import MobileNetV1  # noqa: F401
from .common import cross_entropy, accuracy, topk_accuracy  # noqa: F401


def build(name: str, num_classes: int = 10, in_hw: int = 32, width_mult: float = 1.0):
    name = name.lower()
    if name == "vgg16":
        return VGG16(num_classes, in_hw, width_mult)
    if name in ("resnet18", "resnet-18"):
        return resnet18(num_classes, in_hw, width_mult)
    if name in ("resnet56", "resnet-56"):
        return resnet56(num_classes, in_hw, width_mult)
    if name in ("mobilenet", "mobilenetv1"):
        return MobileNetV1(num_classes, in_hw, width_mult)
    raise ValueError(f"unknown CNN {name!r}")
