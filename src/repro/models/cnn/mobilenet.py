"""MobileNetV1 (CIFAR variant) — depthwise-separable convs, Zebra after
every ReLU (both the depthwise and pointwise activations hit DRAM)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..layers import (bn_apply, bn_init, conv_apply, conv_init, dense_apply,
                      dense_init, global_avg_pool)
from ...core.zebra import ZebraConfig
from ...core.bandwidth import MapSpec
from .common import ZebraSites, relu, site_block

# (out_channels, stride) per separable block; CIFAR variant (stem stride 1)
MB_PLAN = [(64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2),
           (512, 1), (512, 1), (512, 1), (512, 1), (512, 1), (1024, 2), (1024, 1)]


class MobileNetV1:
    def __init__(self, num_classes=10, in_hw=32, width_mult: float = 1.0):
        self.num_classes = num_classes
        self.in_hw = in_hw
        self.plan = [(max(8, int(c * width_mult)), s) for c, s in MB_PLAN]
        self.stem_c = max(8, int(32 * width_mult))

    def init(self, key, zcfg: ZebraConfig = ZebraConfig()):
        keys = iter(jax.random.split(key, 256))
        sites = ZebraSites(zcfg)
        params, state, zebra = {}, {}, {}
        params["stem"] = conv_init(next(keys), 3, self.stem_c, 3)
        params["bn_stem"], state["bn_stem"] = bn_init(self.stem_c)
        nm, tn = sites.init_site(next(keys), self.stem_c)
        zebra[nm] = tn
        c_in = self.stem_c
        for i, (c, s) in enumerate(self.plan):
            params[f"dw{i}"] = conv_init(next(keys), c_in, c_in, 3, groups=c_in)
            params[f"bn_dw{i}"], state[f"bn_dw{i}"] = bn_init(c_in)
            nm, tn = sites.init_site(next(keys), c_in)
            zebra[nm] = tn
            params[f"pw{i}"] = conv_init(next(keys), c_in, c, 1)
            params[f"bn_pw{i}"], state[f"bn_pw{i}"] = bn_init(c)
            nm, tn = sites.init_site(next(keys), c)
            zebra[nm] = tn
            c_in = c
        params["fc"] = dense_init(next(keys), c_in, self.num_classes)
        return {"params": params, "state": state, "zebra": zebra}

    def apply(self, variables, x, train: bool, zcfg: ZebraConfig):
        p, s, z = variables["params"], variables["state"], variables.get("zebra")
        sites = ZebraSites(zcfg)
        ns = {}
        x = conv_apply(p["stem"], x)
        x, ns["bn_stem"] = bn_apply(p["bn_stem"], s["bn_stem"], x, train)
        x = sites(relu(x), z)
        c_in = self.stem_c
        for i, (c, st) in enumerate(self.plan):
            x = conv_apply(p[f"dw{i}"], x, stride=st, groups=c_in)
            x, ns[f"bn_dw{i}"] = bn_apply(p[f"bn_dw{i}"], s[f"bn_dw{i}"], x, train)
            x = sites(relu(x), z)
            x = conv_apply(p[f"pw{i}"], x)
            x, ns[f"bn_pw{i}"] = bn_apply(p[f"bn_pw{i}"], s[f"bn_pw{i}"], x, train)
            x = sites(relu(x), z)
            c_in = c
        x = global_avg_pool(x)
        return dense_apply(p["fc"], x), ns, sites.auxes

    def map_specs(self, in_hw: int | None = None, zcfg: ZebraConfig = ZebraConfig()):
        hw = in_hw or self.in_hw
        specs = []

        def add(c, hw):
            b = site_block(hw, hw, zcfg.block_hw)
            specs.append(MapSpec(c=c, h=hw, w=hw, bits=zcfg.act_bits, block=b))

        add(self.stem_c, hw)
        c_in = self.stem_c
        for c, st in self.plan:
            if st == 2:
                hw //= 2
            add(c_in, hw)   # depthwise ReLU map
            add(c, hw)      # pointwise ReLU map
            c_in = c
        return specs
