"""Shared machinery for the paper's CNN zoo (VGG16 / ResNet / MobileNet).

Every model is an object with:
  init(key)                -> {"params", "state", "zebra"}
  apply(variables, x, train, zcfg) -> (logits, new_state, zebra_auxes)
  map_specs(input_hw)      -> [MapSpec] for bandwidth accounting (§bandwidth)

A *Zebra site* sits after every ReLU that produces a DRAM-bound activation
map (paper Fig. 2: Zebra is applied to the activation maps). Block size
follows the paper: `zcfg.block_hw` normally, shrinking to the largest
divisor when a deep map is smaller than the block (paper: "we set block
size as 2 when the size of activation maps goes to 2x2").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.engine import SiteAux, site_block, zebra_site
from ...core.zebra import ZebraConfig, init_threshold_net
from ...core.bandwidth import MapSpec


class ZebraSites:
    """Collects threshold nets at init and auxes at apply time. Every site
    executes through the unified engine (``core.engine.zebra_site``), so
    ``zcfg.backend`` picks reference | pallas | stream per forward — with
    ``stream``, CNN maps move in compressed (bitmap, payload) form and each
    ``SiteAux.measured_bytes`` reports the observed stream length."""

    def __init__(self, zcfg: ZebraConfig):
        self.zcfg = zcfg
        self.auxes: list[SiteAux] = []
        self.specs: list[MapSpec] = []
        self._tnets: dict = {}
        self._i = 0

    # ---- init-time ----
    def init_site(self, key, channels: int) -> tuple[str, dict | None]:
        name = f"z{self._i}"
        self._i += 1
        # use_tnet=False: constant-T_obj (deployment-matched) training —
        # no net, and the kernel backends stay trainable at this site
        tnet = init_threshold_net(key, channels) if self.zcfg.use_tnet else None
        return name, tnet

    # ---- apply-time ----
    def __call__(self, x: jax.Array, zebra_params: dict | None) -> jax.Array:
        name = f"z{self._i}"
        self._i += 1
        B, C, H, W = x.shape
        b = site_block(H, W, self.zcfg.block_hw)
        cfg = self.zcfg.replace(block_hw=b)
        tnet = zebra_params.get(name) if zebra_params else None
        if cfg.mode == "train" and tnet is None and cfg.use_tnet:
            cfg = cfg.replace(enabled=False)   # net expected but missing:
                                               # passthrough (legacy ckpts)
        y, aux = zebra_site(x, cfg, site=name, layout="nchw", tnet=tnet)
        self.auxes.append(aux)
        self.specs.append(MapSpec(c=C, h=H, w=W, bits=cfg.act_bits, block=b))
        return y


def relu(x):
    return jax.nn.relu(x)


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def accuracy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))


def topk_accuracy(logits: jax.Array, labels: jax.Array, k: int = 5) -> jax.Array:
    topk = jax.lax.top_k(logits, k)[1]
    return jnp.mean(jnp.any(topk == labels[:, None], axis=-1).astype(jnp.float32))
