"""ResNet-18 / ResNet-56 (CIFAR/Tiny-ImageNet variants) with Zebra sites.

ResNet-18: stem conv3x3 -> 4 stages of 2 BasicBlocks (64,128,256,512).
ResNet-56: CIFAR style, 3 stages of 9 BasicBlocks (16,32,64).
Zebra is applied after every ReLU (both intra-block and post-residual).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..layers import (bn_apply, bn_init, conv_apply, conv_init, dense_apply,
                      dense_init, global_avg_pool)
from ...core.zebra import ZebraConfig
from ...core.bandwidth import MapSpec
from .common import ZebraSites, relu, site_block


def _block_init(keys, c_in, c_out, stride):
    p = {
        "conv1": conv_init(next(keys), c_in, c_out, 3),
        "conv2": conv_init(next(keys), c_out, c_out, 3),
    }
    pb1, sb1 = bn_init(c_out)
    pb2, sb2 = bn_init(c_out)
    p["bn1"], p["bn2"] = pb1, pb2
    s = {"bn1": sb1, "bn2": sb2}
    if stride != 1 or c_in != c_out:
        p["proj"] = conv_init(next(keys), c_in, c_out, 1)
        pbp, sbp = bn_init(c_out)
        p["bnp"], s["bnp"] = pbp, sbp
    return p, s


def _block_apply(p, s, x, stride, train, sites, z):
    h = conv_apply(p["conv1"], x, stride=stride)
    h, ns1 = bn_apply(p["bn1"], s["bn1"], h, train)
    h = relu(h)
    h = sites(h, z)
    h = conv_apply(p["conv2"], h)
    h, ns2 = bn_apply(p["bn2"], s["bn2"], h, train)
    if "proj" in p:
        sc = conv_apply(p["proj"], x, stride=stride)
        sc, nsp = bn_apply(p["bnp"], s["bnp"], sc, train)
        new_s = {"bn1": ns1, "bn2": ns2, "bnp": nsp}
    else:
        sc = x
        new_s = {"bn1": ns1, "bn2": ns2}
    y = relu(h + sc)
    y = sites(y, z)
    return y, new_s


class ResNet:
    def __init__(self, stage_sizes, stage_channels, num_classes=10, in_hw=32,
                 width_mult: float = 1.0):
        self.stage_sizes = stage_sizes
        self.stage_channels = [max(8, int(c * width_mult)) for c in stage_channels]
        self.num_classes = num_classes
        self.in_hw = in_hw

    # ---- layout helpers -------------------------------------------------
    def _walk(self):
        """Yield (stage, block, c_in, c_out, stride)."""
        c_in = self.stage_channels[0]
        for si, (n, c) in enumerate(zip(self.stage_sizes, self.stage_channels)):
            for bi in range(n):
                stride = 2 if (si > 0 and bi == 0) else 1
                yield si, bi, c_in, c, stride
                c_in = c

    def init(self, key, zcfg: ZebraConfig = ZebraConfig()):
        keys = iter(jax.random.split(key, 4096))
        sites = ZebraSites(zcfg)
        params, state, zebra = {}, {}, {}
        c0 = self.stage_channels[0]
        params["stem"] = conv_init(next(keys), 3, c0, 3)
        params["bn_stem"], state["bn_stem"] = bn_init(c0)
        name, tnet = sites.init_site(next(keys), c0)
        zebra[name] = tnet
        for si, bi, c_in, c_out, stride in self._walk():
            p, s = _block_init(keys, c_in, c_out, stride)
            params[f"s{si}b{bi}"], state[f"s{si}b{bi}"] = p, s
            for _ in range(2):  # two ReLU sites per block
                name, tnet = sites.init_site(next(keys), c_out)
                zebra[name] = tnet
        params["fc"] = dense_init(next(keys), self.stage_channels[-1], self.num_classes)
        return {"params": params, "state": state, "zebra": zebra}

    def apply(self, variables, x, train: bool, zcfg: ZebraConfig):
        p, s, z = variables["params"], variables["state"], variables.get("zebra")
        sites = ZebraSites(zcfg)
        new_state = {}
        x = conv_apply(p["stem"], x)
        x, new_state["bn_stem"] = bn_apply(p["bn_stem"], s["bn_stem"], x, train)
        x = relu(x)
        x = sites(x, z)
        for si, bi, c_in, c_out, stride in self._walk():
            nm = f"s{si}b{bi}"
            x, new_state[nm] = _block_apply(p[nm], s[nm], x, stride, train, sites, z)
        x = global_avg_pool(x)
        logits = dense_apply(p["fc"], x)
        return logits, new_state, sites.auxes

    def map_specs(self, in_hw: int | None = None, zcfg: ZebraConfig = ZebraConfig()):
        hw = in_hw or self.in_hw
        specs = []

        def add(c, hw):
            b = site_block(hw, hw, zcfg.block_hw)
            specs.append(MapSpec(c=c, h=hw, w=hw, bits=zcfg.act_bits, block=b))

        add(self.stage_channels[0], hw)
        for si, bi, c_in, c_out, stride in self._walk():
            if stride == 2:
                hw //= 2
            add(c_out, hw)   # post-conv1 ReLU
            add(c_out, hw)   # post-residual ReLU
        return specs


def resnet18(num_classes=10, in_hw=32, width_mult=1.0):
    return ResNet([2, 2, 2, 2], [64, 128, 256, 512], num_classes, in_hw, width_mult)


def resnet56(num_classes=10, in_hw=32, width_mult=1.0):
    return ResNet([9, 9, 9], [16, 32, 64], num_classes, in_hw, width_mult)
