"""VGG-16 (CIFAR variant, conv-BN-ReLU + Zebra after every ReLU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..layers import (bn_apply, bn_init, conv_apply, conv_init, dense_apply,
                      dense_init, max_pool)
from ...core.zebra import ZebraConfig
from ...core.bandwidth import MapSpec
from .common import ZebraSites, relu, site_block

VGG16_PLAN = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
              512, 512, 512, "M", 512, 512, 512, "M"]


class VGG16:
    def __init__(self, num_classes: int = 10, in_hw: int = 32, width_mult: float = 1.0):
        self.num_classes = num_classes
        self.in_hw = in_hw
        self.plan = [c if c == "M" else max(8, int(c * width_mult)) for c in VGG16_PLAN]

    def init(self, key, zcfg: ZebraConfig = ZebraConfig()):
        keys = iter(jax.random.split(key, 64))
        params, state, zebra = {}, {}, {}
        sites = ZebraSites(zcfg)
        c_in, i = 3, 0
        for c in self.plan:
            if c == "M":
                continue
            params[f"conv{i}"] = conv_init(next(keys), c_in, c, 3)
            params[f"bn{i}"], state[f"bn{i}"] = bn_init(c)
            name, tnet = sites.init_site(next(keys), c)
            zebra[name] = tnet
            c_in, i = c, i + 1
        params["fc"] = dense_init(next(keys), c_in, self.num_classes)
        return {"params": params, "state": state, "zebra": zebra}

    def apply(self, variables, x, train: bool, zcfg: ZebraConfig):
        p, s, z = variables["params"], variables["state"], variables.get("zebra")
        sites = ZebraSites(zcfg)
        new_state = {}
        i = 0
        for c in self.plan:
            if c == "M":
                x = max_pool(x)
                continue
            x = conv_apply(p[f"conv{i}"], x)
            x, new_state[f"bn{i}"] = bn_apply(p[f"bn{i}"], s[f"bn{i}"], x, train)
            x = relu(x)
            x = sites(x, z)
            i += 1
        x = jnp.mean(x, axis=(2, 3))
        logits = dense_apply(p["fc"], x)
        return logits, new_state, sites.auxes

    def map_specs(self, in_hw: int | None = None, zcfg: ZebraConfig = ZebraConfig()):
        hw = in_hw or self.in_hw
        specs = []
        for c in self.plan:
            if c == "M":
                hw //= 2
                continue
            b = site_block(hw, hw, zcfg.block_hw)
            specs.append(MapSpec(c=c, h=hw, w=hw, bits=zcfg.act_bits, block=b))
        return specs
