"""FFN blocks: dense (SwiGLU / GELU) and MoE (top-k, sort-based dispatch),
each with a Zebra site on the hidden activation map — the LM integration of
the paper's technique (DESIGN.md §4). All sites execute through the
unified engine (``core.engine.zebra_site``); the dense FFN additionally
supports the ``fused`` backend, where ``w_down`` consumes the keep bitmap
via ``zebra_spmm`` instead of a dense re-matmul over the masked map.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.engine import wants_fused, zebra_site
from ...core.zebra import ZebraConfig, init_token_threshold_net
from ...distributed.ctx import dp_axes, hint, hint_tokens, tp_axis
from ..layers import lecun_normal
from .config import LMConfig


def zebra_cfg_for(cfg: LMConfig, mode: str) -> ZebraConfig:
    backend = cfg.zebra_backend or ("stream" if cfg.use_kernel else "reference")
    return ZebraConfig(enabled=cfg.zebra_enabled, t_obj=cfg.zebra_t_obj,
                       block_seq=cfg.zebra_block_seq, block_ch=cfg.zebra_block_ch,
                       mode=mode, backend=backend, use_tnet=cfg.zebra_tnet,
                       site_backends=tuple(cfg.zebra_site_backends),
                       validation=cfg.zebra_validation)


def eff_block_ch(f: int, cfg: LMConfig) -> int:
    """Channel-block size actually used for a width-f map (fallback: one
    block spanning the whole width when f doesn't divide)."""
    return cfg.zebra_block_ch if f % cfg.zebra_block_ch == 0 else f


def _hidden_site_cfg(cfg: LMConfig, mode: str) -> ZebraConfig:
    zc = zebra_cfg_for(cfg, mode)
    if "ffn_hidden" not in cfg.zebra_sites:
        zc = zc.replace(enabled=False)
    return zc


# ---------------------------------------------------------------------------
# Dense FFN
# ---------------------------------------------------------------------------

def ffn_init(key, cfg: LMConfig, dtype):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    p = {}
    if cfg.act == "swiglu":
        p["w_gate"] = lecun_normal(ks[0], (d, f), dtype)
        p["w_up"] = lecun_normal(ks[1], (d, f), dtype)
    else:  # gelu MLP (whisper)
        p["w_up"] = lecun_normal(ks[1], (d, f), dtype)
        p["b_up"] = jnp.zeros((f,), dtype)
        p["b_down"] = jnp.zeros((d,), dtype)
    p["w_down"] = lecun_normal(ks[2], (f, d), dtype, fan_in=f)
    if cfg.zebra_enabled and "ffn_hidden" in cfg.zebra_sites and cfg.zebra_tnet:
        p["zebra_tnet"] = init_token_threshold_net(ks[3], f, f // eff_block_ch(f, cfg))
    return p


def ffn_apply(p, x, cfg: LMConfig, mode: str):
    cdt = x.dtype
    if cfg.act == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"].astype(cdt)) * (x @ p["w_up"].astype(cdt))
    else:
        h = jax.nn.gelu(x @ p["w_up"].astype(cdt) + p["b_up"].astype(cdt))
    h = hint_tokens(h, "model")           # hidden map d_ff TP-sharded
    zc = _hidden_site_cfg(cfg, mode)
    if wants_fused(zc, "ffn_hidden"):
        # fused backend: w_down consumes the keep bitmap (zebra_spmm skips
        # dead blocks) — the masked hidden map is never re-read densely.
        # Capability resolution (not a mode check here) decides legality:
        # train-mode requests degrade inside wants_fused.
        y, zaux = zebra_site(h, zc, site="ffn_hidden",
                             w=p["w_down"].astype(cdt))
    else:
        h, zaux = zebra_site(h, zc, site="ffn_hidden", tnet=p.get("zebra_tnet"))
        from jax.ad_checkpoint import checkpoint_name
        h = checkpoint_name(h, "ffn_hidden")  # save_acts remat
        y = h @ p["w_down"].astype(cdt)
    if "b_down" in p:
        y = y + p["b_down"].astype(cdt)
    y, xaux = ffn_layer_out_exchange(y, cfg, mode)
    if xaux is not None:
        from ...core.engine import merge_site_aux
        zaux = merge_site_aux(zaux, xaux)
    return y, zaux


def ffn_layer_out_exchange(y, cfg: LMConfig, mode: str):
    """Sequence-parallel compressed TP exchange of the FFN output.

    Inside ``distributed.ctx.comm_context`` (whose owner also owns the
    enclosing ``shard_map`` over the same axis), ``ffn_apply`` treats its
    token rows as the LOCAL sequence shard: the output is masked at the
    ``layer_out`` site, then every shard's map is gathered over the comm
    axis in Zebra stream form — bitmaps first, then the ring-ppermuted
    payload (``collectives.zebra_all_gather``), so each inbound link
    carries only live blocks plus the 1-bit index. Returns the
    full-sequence ``(B, n*S, d)`` output, bitwise-equal to
    ``lax.all_gather`` of the masked shard, plus a SiteAux carrying the
    per-link ``ici_bytes``/``ici_dense_bytes`` pair.

    No comm context (everywhere today outside the collectives tests /
    bench): strict no-op, single-process semantics — returns ``(y,
    None)``. Capability misses (backend without ``comms="compressed"``,
    size-1 axis, non-divisible blocks) degrade to a dense
    ``lax.all_gather`` with the logged reason surfaced on the aux's
    backend label.
    """
    from ...distributed import collectives as coll
    from ...distributed.ctx import comm_axis
    info = comm_axis()
    if info is None:
        return y, None
    axis, n = info
    B, S, d = y.shape
    # constant-T_obj gating at the exchange site: the wire format is the
    # deployed comparator's, so no threshold net regardless of train mode
    zc = zebra_cfg_for(cfg, mode).replace(use_tnet=False)
    if "layer_out" not in cfg.zebra_sites:
        zc = zc.replace(enabled=False)    # lossless transport, no masking
    bs = zc.block_seq if S % zc.block_seq == 0 else 1
    bc = eff_block_ch(d, cfg)
    comms, reason = coll.resolve_comms(zc.backend_for("layer_out"),
                                       rows=B * S, cols=d, bs=bs, bc=bc)
    yz, sa = zebra_site(y, zc, site="layer_out")
    if comms == "compressed":
        g, link = coll.zebra_all_gather(yz.reshape(B * S, d), axis,
                                        bs=bs, bc=bc,
                                        validation=zc.validation,
                                        site="layer_out")
        y_full = (g.reshape(n, B, S, d).transpose(1, 0, 2, 3)
                  .reshape(B, n * S, d))
        sa = coll.attach_link(sa, link)
    else:
        coll.log_comm_degrade("layer_out", zc.backend_for("layer_out"),
                              reason)
        y_full = jax.lax.all_gather(yz, axis, axis=1, tiled=True)
        sa = coll.attach_link(
            sa, coll.dense_link(yz.size * jnp.dtype(yz.dtype).itemsize, n),
            reason=reason)
    return y_full, sa


# ---------------------------------------------------------------------------
# MoE FFN — top-k routing, sort-based dispatch (MegaBlocks-style, EP-ready)
# ---------------------------------------------------------------------------

def moe_init(key, cfg: LMConfig, dtype):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": lecun_normal(ks[0], (d, E), jnp.float32),
        "w_gate": lecun_normal(ks[1], (E, d, f), dtype),
        "w_up": lecun_normal(ks[2], (E, d, f), dtype),
        "w_down": lecun_normal(ks[3], (E, f, d), dtype, fan_in=f),
    }
    if cfg.zebra_enabled and "ffn_hidden" in cfg.zebra_sites and cfg.zebra_tnet:
        p["zebra_tnet"] = init_token_threshold_net(ks[4], f, f // eff_block_ch(f, cfg))
    return p


def moe_apply(p, x, cfg: LMConfig, mode: str, local: bool = False):
    """x: (B, S, d). Sort-based dispatch:

      route -> top-k -> flat (T·k) expert ids -> stable argsort ->
      rank-in-expert via first-occurrence -> capacity-bounded scatter into
      (E, C, d) -> per-expert GEMMs (einsum over stacked expert weights;
      the E axis shards over "model" = expert parallelism) -> gather back.

    Overflow tokens beyond capacity C are dropped (their combine weight is
    effectively 0 — GShard semantics). Returns (y, SiteAux, router_aux).
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, d)
    logits = (xt.astype(jnp.float32) @ p["router"])              # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)              # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # --- load-balancing auxiliary loss (Switch-style) ---
    me = jnp.mean(probs, axis=0)                                 # (E,)
    ce = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], E, dtype=jnp.float32), axis=0)
    router_aux = E * jnp.sum(me * ce)

    cap = int(max(1, round(cfg.capacity_factor * T * k / E)))
    flat_e = expert_idx.reshape(-1)                              # (T·k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank = jnp.arange(T * k) - first                             # rank in expert
    dest = jnp.where(rank < cap, sorted_e * cap + rank, E * cap) # overflow slot
    src_token = order // k

    buf = jnp.zeros((E * cap + 1, d), x.dtype)
    buf = buf.at[dest].set(xt[src_token])
    eb = buf[: E * cap].reshape(E, cap, d)
    if not local:
        eb = hint(eb, tp_axis(), None, None)  # keep dispatch buffer EP-sharded

    cdt = x.dtype
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", eb, p["w_gate"].astype(cdt))) \
        * jnp.einsum("ecd,edf->ecf", eb, p["w_up"].astype(cdt))
    h2d = h.reshape(E * cap, cfg.d_ff)
    hz, zaux = zebra_site(h2d[None], _hidden_site_cfg(cfg, mode),
                          site="ffn_hidden", tnet=p.get("zebra_tnet"))
    h = hz[0].reshape(E, cap, cfg.d_ff)
    y_e = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(cdt))

    # gather back: slot for (token, choice) = dest (E*cap = dropped)
    y_flat = jnp.concatenate([y_e.reshape(E * cap, d),
                              jnp.zeros((1, d), y_e.dtype)], axis=0)
    slot_of = jnp.zeros((T * k,), jnp.int32).at[order].set(dest.astype(jnp.int32))
    per_choice = y_flat[slot_of].reshape(T, k, d)
    y = jnp.sum(per_choice * gate_vals[..., None].astype(y_e.dtype), axis=1)
    return y.reshape(B, S, d), zaux, router_aux


def moe_apply_dp(p, x, cfg: LMConfig, mode: str, mesh, dp_axes_t: tuple):
    """Pure-DP MoE (§Perf, small-expert models): shard_map over the batch
    axes — every device routes/dispatches only its LOCAL tokens against a
    replicated (FSDP-gathered) expert stack. Zero expert-parallel
    communication; capacity is per-shard, so the dispatch buffer is
    1/n_shards the global one. Returns (y, LayerAux): reg/zero_frac are
    shard means, measured bytes are summed (each shard moves its own
    stream)."""
    import jax as _jax
    from jax.sharding import PartitionSpec as P

    from ...core.engine import LayerAux
    from ...distributed.collectives import psum_exact_bytes, shard_map_compat

    def local_fn(p_, x_):
        y, sa, raux = moe_apply(p_, x_, cfg, mode, local=True)
        mean = lambda s: _jax.lax.pmean(s, dp_axes_t)
        la = LayerAux.of_site(sa)
        # psum the per-shard bytes (int32-exact per shard) through the ONE
        # shared exact reducer (collectives.psum_exact_bytes): split at
        # base 2**16 so each int32 leg sum stays far from overflow up to
        # ~32k DP shards, recombined into the (mb_hi, mb_lo) 2**24 pair
        mb_hi, mb_lo = psum_exact_bytes(sa.measured_bytes, dp_axes_t)
        return (y, mean(jnp.float32(sa.reg)),
                mean(la.zf_blocks), la.n_blocks, mb_hi, mb_lo, mean(raux))

    y, reg, zfb, nb, mb_hi, mb_lo, raux = shard_map_compat(
        local_fn, mesh,
        in_specs=(P(), P(dp_axes_t, None, None)),
        out_specs=(P(dp_axes_t, None, None), P(), P(), P(), P(), P(), P()),
    )(p, x)
    return y, LayerAux(reg=reg, zf_blocks=zfb, n_blocks=nb,
                       mb_hi=mb_hi, mb_lo=mb_lo, router_aux=raux)
