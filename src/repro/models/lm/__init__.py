from .config import LMConfig  # noqa: F401
from .model import LM, layer_runs  # noqa: F401
