"""Layer assembly: pattern runs + scan-over-layers + KV/state caches.

``cfg.layer_pattern`` defines a *superlayer* (e.g. gemma-3's 5 local + 1
global). Layers are grouped into runs: ``n_layers // P`` stacked
superlayers executed under ``jax.lax.scan`` (small HLO, fast compiles,
XLA pipelines the per-layer collectives), plus one unrolled remainder.

Every layer returns a ``core.engine.LayerAux`` (named-field scan carry:
zebra reg, weighted zero_frac, block counts, measured transport bytes,
router aux) accumulated across the scan. All Zebra sites execute through
the unified engine; this module contains no direct masking/kernel calls.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from ..layers import lecun_normal, layernorm_apply, layernorm_init, rmsnorm_apply, rmsnorm_init
from ...core.engine import LayerAux, zebra_site
from ...core.zebra import init_token_threshold_net
from ...distributed.ctx import hint_tokens
from . import attention as attn
from .config import LMConfig
from .ffn import ffn_apply, ffn_init, moe_apply, moe_init, zebra_cfg_for
from .rglru import rglru_apply, rglru_decode_step, rglru_init, rglru_init_cache
from .ssm import (ssm_apply, ssm_decode_step, ssm_init, ssm_init_cache,
                  ssm_prefill_state)

Aux = LayerAux


def zero_aux() -> Aux:
    return LayerAux.zero()


def _norm_init(cfg, d=None):
    d = d or cfg.d_model
    return rmsnorm_init(d) if cfg.norm == "rmsnorm" else layernorm_init(d)


def _norm_apply(cfg, p, x):
    return rmsnorm_apply(p, x) if cfg.norm == "rmsnorm" else layernorm_apply(p, x)


# ---------------------------------------------------------------------------
# Per-layer init
# ---------------------------------------------------------------------------

def _attn_proj_init(key, cfg: LMConfig, dtype):
    """Head-major 4-D weights (d, H, hd) so TP shards the head axis."""
    d, hd, nq, nkv = cfg.d_model, cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = {"wq": lecun_normal(ks[0], (d, nq, hd), dtype, fan_in=d),
         "wk": lecun_normal(ks[1], (d, nkv, hd), dtype, fan_in=d),
         "wv": lecun_normal(ks[2], (d, nkv, hd), dtype, fan_in=d),
         "wo": lecun_normal(ks[3], (nq, hd, d), dtype, fan_in=nq * hd)}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nq, hd), dtype)
        p["bk"] = jnp.zeros((nkv, hd), dtype)
        p["bv"] = jnp.zeros((nkv, hd), dtype)
    return p


def init_layer(key, typ: str, cfg: LMConfig, dtype, cross: bool = False) -> dict:
    ks = jax.random.split(key, 8)
    p: dict[str, Any] = {"norm1": _norm_init(cfg)}
    if typ in ("global", "local"):
        p["attn"] = _attn_proj_init(ks[0], cfg, dtype)
    elif typ == "rglru":
        p["rec"] = rglru_init(ks[0], cfg, dtype)
    elif typ == "ssm":
        p["ssm"] = ssm_init(ks[0], cfg, dtype)
    else:
        raise ValueError(typ)
    if cross:
        p["norm_c"] = _norm_init(cfg)
        p["cross"] = _attn_proj_init(ks[1], cfg, dtype)
    if typ != "ssm" and cfg.d_ff > 0:
        p["norm2"] = _norm_init(cfg)
        if cfg.is_moe:
            p["moe"] = moe_init(ks[2], cfg, dtype)
        else:
            p["ffn"] = ffn_init(ks[2], cfg, dtype)
    if cfg.zebra_enabled and "layer_out" in cfg.zebra_sites and cfg.zebra_tnet:
        from .ffn import eff_block_ch
        nblk = cfg.d_model // eff_block_ch(cfg.d_model, cfg)
        p["zebra_out_tnet"] = init_token_threshold_net(ks[3], cfg.d_model, nblk)
    return p


# ---------------------------------------------------------------------------
# Per-layer forward (full sequence)
# ---------------------------------------------------------------------------

def _qkv(p, x, cfg: LMConfig, rope):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if rope is not None:
        cos, sin = rope
        q = attn.apply_rope(q, cos, sin)
        k = attn.apply_rope(k, cos, sin)
    return q, k, v


def _self_attention(p, x, typ, cfg: LMConfig, rope, causal=True):
    B, S, _ = x.shape
    q, k, v = _qkv(p, x, cfg, rope)
    q = hint_tokens(q, "model", None)     # heads TP-sharded, batch DP
    if typ == "local" and S > cfg.window:
        local = (attn.attend_local_scanned if cfg.local_impl == "scanned"
                 else attn.attend_local)
        o = local(q, k, v, window=cfg.window)
    elif S <= cfg.attn_chunk or not causal:
        o = attn.attend_full(q, k, v, causal=causal,
                             window=cfg.window if typ == "local" else 0)
    else:
        o = attn.attend_chunked(q, k, v, causal=causal, chunk=cfg.attn_chunk)
    o = checkpoint_name(o, "attn_out")   # save_acts remat
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))


def _cross_attention(p, x, enc_kv, cfg: LMConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k, v = enc_kv
    o = attn.attend_full(q, k, v, causal=False)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))


def _enc_kv(p, enc_out, cfg: LMConfig):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(enc_out.dtype))
    return k, v


def _layer_out_zebra(p, x, cfg: LMConfig, mode: str):
    zc = zebra_cfg_for(cfg, mode)
    if "layer_out" not in cfg.zebra_sites:
        zc = zc.replace(enabled=False)
    return zebra_site(x, zc, site="layer_out", tnet=p.get("zebra_out_tnet"))


def apply_layer(p, x, typ: str, cfg: LMConfig, mode: str, rope,
                enc_out=None, causal=True) -> tuple[jax.Array, Aux]:
    aux = zero_aux()
    x = hint_tokens(x)          # pin batch sharding at every layer boundary
    h = _norm_apply(cfg, p["norm1"], x)
    if typ in ("global", "local"):
        x = x + _self_attention(p["attn"], h, typ, cfg, rope, causal)
    elif typ == "rglru":
        x = x + rglru_apply(p["rec"], h, cfg)
    elif typ == "ssm":
        x = x + ssm_apply(p["ssm"], h, cfg)
    if "cross" in p and enc_out is not None:
        hc = _norm_apply(cfg, p["norm_c"], x)
        x = x + _cross_attention(p["cross"], hc, _enc_kv(p["cross"], enc_out, cfg), cfg)
    if "ffn" in p or "moe" in p:
        h2 = _norm_apply(cfg, p["norm2"], x)
        if "moe" in p:
            y, moe_aux = _moe(p["moe"], h2, cfg, mode)
            aux = aux + moe_aux
        else:
            y, zaux = ffn_apply(p["ffn"], h2, cfg, mode)
            aux = aux + LayerAux.of_site(zaux)
        x = x + y
    x, zo = _layer_out_zebra(p, x, cfg, mode)
    aux = aux + LayerAux.of_site(zo)
    return x, aux


def _moe(p, h2, cfg: LMConfig, mode: str) -> tuple[jax.Array, LayerAux]:
    """Route to the shard_map'd pure-DP dispatch when the profile asks for
    it and a mesh context is live; plain SPMD dispatch otherwise."""
    if cfg.sharding_profile == "dp":
        from ...distributed.ctx import _MESH, dp_axes
        mesh = _MESH.get()
        if mesh is not None:
            from .ffn import moe_apply_dp
            return moe_apply_dp(p, h2, cfg, mode, mesh, tuple(dp_axes()))
    y, zaux, raux = moe_apply(p, h2, cfg, mode)
    return y, LayerAux.of_site(zaux, raux)


# ---------------------------------------------------------------------------
# Caches + decode / prefill per layer
# ---------------------------------------------------------------------------

def init_layer_cache(typ: str, cfg: LMConfig, batch: int, cache_len: int, dtype):
    if typ in ("global", "local"):
        T = min(cfg.window, cache_len) if typ == "local" else cache_len
        hkv, hd = cfg.n_kv_heads, cfg.head_dim
        return {"k": jnp.zeros((batch, T, hkv, hd), dtype),
                "v": jnp.zeros((batch, T, hkv, hd), dtype)}
    if typ == "rglru":
        return rglru_init_cache(cfg, batch, dtype)
    if typ == "ssm":
        return ssm_init_cache(cfg, batch, dtype)
    raise ValueError(typ)


def _cache_write(cache, new, slot):
    """Write one token's K or V (B,1,Hkv,hd) at ``slot`` — a scalar (every
    lane writes the same position) or (B,) per-lane slots (the slotted
    continuous-batching decode)."""
    new = new.astype(cache.dtype)
    slot = jnp.asarray(slot)
    if slot.ndim == 0:
        return jax.lax.dynamic_update_slice(cache, new, (0, slot, 0, 0))
    T = cache.shape[1]
    hit = jnp.arange(T)[None, :] == slot[:, None]            # (B, T)
    return jnp.where(hit[:, :, None, None], new, cache)


def apply_layer_decode(p, x, cache, typ: str, cfg: LMConfig, pos, rope1,
                       enc_out=None):
    """x (B,1,d); pos scalar or (B,) per-lane. Returns (x, new_cache)."""
    h = _norm_apply(cfg, p["norm1"], x)
    if typ in ("global", "local"):
        q, k, v = _qkv(p["attn"], h, cfg, rope1)
        T = cache["k"].shape[1]
        slot = (pos % T) if typ == "local" else pos
        kc = _cache_write(cache["k"], k, slot)
        vc = _cache_write(cache["v"], v, slot)
        o = attn.attend_decode(q, kc, vc, pos,
                               window=cfg.window if typ == "local" else 0)
        x = x + jnp.einsum("bshk,hkd->bsd", o, p["attn"]["wo"].astype(x.dtype))
        cache = {"k": kc, "v": vc}
    elif typ == "rglru":
        y, cache = rglru_decode_step(p["rec"], h, cache, cfg)
        x = x + y
    elif typ == "ssm":
        y, cache = ssm_decode_step(p["ssm"], h, cache, cfg)
        x = x + y
    if "cross" in p and enc_out is not None:
        hc = _norm_apply(cfg, p["norm_c"], x)
        x = x + _cross_attention(p["cross"], hc, _enc_kv(p["cross"], enc_out, cfg), cfg)
    if "ffn" in p or "moe" in p:
        h2 = _norm_apply(cfg, p["norm2"], x)
        if "moe" in p:
            y, _, _ = moe_apply(p["moe"], h2, cfg, "infer")
        else:
            y, _ = ffn_apply(p["ffn"], h2, cfg, "infer")
        x = x + y
    return x, cache


def apply_layer_prefill(p, x, typ: str, cfg: LMConfig, rope, cache_len: int,
                        enc_out=None):
    """Forward + emit decode cache. Returns (x, cache, aux)."""
    B, S, _ = x.shape
    h = _norm_apply(cfg, p["norm1"], x)
    aux = zero_aux()
    if typ in ("global", "local"):
        q, k, v = _qkv(p["attn"], h, cfg, rope)
        if typ == "local" and S > cfg.window:
            local = (attn.attend_local_scanned if cfg.local_impl == "scanned"
                     else attn.attend_local)
            o = local(q, k, v, window=cfg.window)
        elif S <= cfg.attn_chunk:
            o = attn.attend_full(q, k, v, causal=True,
                                 window=cfg.window if typ == "local" else 0)
        else:
            o = attn.attend_chunked(q, k, v, causal=True, chunk=cfg.attn_chunk)
        x = x + jnp.einsum("bshk,hkd->bsd", o, p["attn"]["wo"].astype(x.dtype))
        if cfg.zebra_enabled and "kv_cache" in cfg.zebra_sites:
            # beyond-paper: Zebra block-compress the cache at the HBM write
            k, v, kv_auxes = attn.zebra_kv_site(k, v, zebra_cfg_for(cfg, "infer"))
            for a in kv_auxes:
                aux = aux + LayerAux.of_site(a)
        if typ == "local":
            T = min(cfg.window, cache_len)
            cache = {"k": k[:, -T:].astype(x.dtype), "v": v[:, -T:].astype(x.dtype)}
            if T > S:
                pad = ((0, 0), (0, T - S), (0, 0), (0, 0))
                cache = {n: jnp.pad(c, pad) for n, c in cache.items()}
        else:
            pad = ((0, 0), (0, cache_len - S), (0, 0), (0, 0))
            cache = {"k": jnp.pad(k, pad).astype(x.dtype),
                     "v": jnp.pad(v, pad).astype(x.dtype)}
    elif typ == "rglru":
        gate = jax.nn.gelu(h @ p["rec"]["w_gate_branch"].astype(x.dtype))
        from .rglru import _causal_conv1d, _gates
        u = _causal_conv1d(h @ p["rec"]["w_rec_branch"].astype(x.dtype),
                           p["rec"]["conv_w"].astype(x.dtype))
        a, b = _gates(p["rec"], u)

        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, ar * bl + br
        _, hseq = jax.lax.associative_scan(combine, (a, b), axis=1)
        y = (hseq.astype(x.dtype) * gate) @ p["rec"]["w_out"].astype(x.dtype)
        x = x + y
        cache = {"h": hseq[:, -1], "conv": (h @ p["rec"]["w_rec_branch"].astype(x.dtype))[:, -(cfg.conv_width - 1):]}
    elif typ == "ssm":
        # run full SSD then rebuild the final state with a 1-step replay of
        # the chunk recurrence (cheap: states are (B,nh,ds,hd))
        y = ssm_apply(p["ssm"], h, cfg)
        x = x + y
        cache = ssm_prefill_state(p["ssm"], h, cfg)
    if "cross" in p and enc_out is not None:
        hc = _norm_apply(cfg, p["norm_c"], x)
        x = x + _cross_attention(p["cross"], hc, _enc_kv(p["cross"], enc_out, cfg), cfg)
    if "ffn" in p or "moe" in p:
        h2 = _norm_apply(cfg, p["norm2"], x)
        if "moe" in p:
            y, zaux, raux = moe_apply(p["moe"], h2, cfg, "infer")
            aux = aux + LayerAux.of_site(zaux, raux)
        else:
            y, zaux = ffn_apply(p["ffn"], h2, cfg, "infer")
            aux = aux + LayerAux.of_site(zaux)
        x = x + y
    x, zo = _layer_out_zebra(p, x, cfg, "infer")
    aux = aux + LayerAux.of_site(zo)
    return x, cache, aux
