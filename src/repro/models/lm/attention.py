"""Attention for the LM stack: GQA + RoPE, three execution paths.

* ``attend_full``  — reference O(S²)-memory masked attention (oracle, short
  sequences, encoder/cross attention).
* ``attend_chunked`` — memory-bounded causal attention: outer loop over
  query chunks, inner checkpointed scan over KV chunks with online softmax
  (flash-attention recurrence in pure JAX). Live memory O(Cq·Ck) per step;
  backward recomputes per-chunk scores (remat), never materializing S².
* ``attend_local`` — *exact* sliding-window attention in banded-chunk form:
  window W == chunk; each query chunk attends [prev, self] chunks with an
  in-band mask. Cost O(S·W), the sub-quadratic path used by gemma-3 local
  layers, recurrentgemma, and long_500k decode.
* ``attend_decode`` — one query token vs a (possibly seq-sharded) KV cache.

Layout: q (B, S, Hq, hd), k/v (B, S, Hkv, hd), GQA via reshape to
(B, S, Hkv, G, hd). All softmax math in fp32.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30   # large-but-finite: keeps all-masked rows NaN-free


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float, positions: jax.Array) -> tuple[jax.Array, jax.Array]:
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs        # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, hd); cos/sin: (S, hd/2) or (B, S, hd/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Reference full attention
# ---------------------------------------------------------------------------

def _gqa_scores(q, k):
    """q (B,S,Hkv,G,hd), k (B,T,Hkv,hd) -> (B,Hkv,G,S,T) fp32."""
    return jnp.einsum("bshgd,bthd->bhgst", q, k, preferred_element_type=jnp.float32)


def attend_full(q, k, v, *, causal: bool, window: int = 0,
                q_offset: int = 0) -> jax.Array:
    B, S, Hq, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, S, Hkv, G, hd) * (hd ** -0.5)
    s = _gqa_scores(qg, k)                                        # (B,Hkv,G,S,T)
    if causal or window:
        qi = jnp.arange(S) + q_offset
        kj = jnp.arange(T)
        ok = jnp.ones((S, T), bool)
        if causal:
            ok &= qi[:, None] >= kj[None, :]
        if window:
            ok &= qi[:, None] - kj[None, :] < window
        s = jnp.where(ok[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgst,bthd->bshgd", p.astype(v.dtype), v)
    return o.reshape(B, S, Hq, hd)


# ---------------------------------------------------------------------------
# Chunked causal attention (online softmax + remat)
# ---------------------------------------------------------------------------

def attend_chunked(q, k, v, *, causal: bool = True, chunk: int = 1024,
                   skip_dead_chunks: bool = False) -> jax.Array:
    """Memory-bounded attention. `skip_dead_chunks` drops fully-masked
    KV chunks from the compute (perf lever; identical numerics)."""
    B, S, Hq, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    Cq = min(chunk, S)
    Ck = min(chunk, T)
    assert S % Cq == 0 and T % Ck == 0, (S, T, chunk)
    nq, nk = S // Cq, T // Ck
    qg = (q.reshape(B, nq, Cq, Hkv, G, hd) * (hd ** -0.5)).astype(q.dtype)
    kc = k.reshape(B, nk, Ck, Hkv, hd)
    vc = v.reshape(B, nk, Ck, Hkv, hd)

    def kv_step(carry, j, qi_blk, i):
        m, l, acc = carry
        kj = kc[:, j]
        vj = vc[:, j]
        s = jnp.einsum("bchgd,bthd->bhgct", qi_blk, kj,
                       preferred_element_type=jnp.float32)
        if causal:
            qpos = i * Cq + jnp.arange(Cq)
            kpos = j * Ck + jnp.arange(Ck)
            ok = qpos[:, None] >= kpos[None, :]
            s = jnp.where(ok[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhgct,bthd->bhgcd", p.astype(vj.dtype), vj)
        acc_new = acc * corr[..., None].astype(acc.dtype) + pv.astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    def q_block(i):
        qi_blk = qg[:, i]                                        # (B,Cq,Hkv,G,hd)
        m0 = jnp.full((B, Hkv, G, Cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, Cq), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, Cq, hd), jnp.float32)
        body = jax.checkpoint(functools.partial(kv_step, qi_blk=qi_blk, i=i))
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(nk))
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.moveaxis(o, 3, 1)                             # (B,Cq,Hkv,G,hd)

    o = jax.lax.map(q_block, jnp.arange(nq))                     # (nq,B,Cq,...)
    o = jnp.moveaxis(o, 0, 1).reshape(B, S, Hkv, G, hd)
    return o.reshape(B, S, Hq, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Exact sliding-window attention, banded-chunk form
# ---------------------------------------------------------------------------

def attend_local(q, k, v, *, window: int) -> jax.Array:
    """Causal sliding window: key j visible iff 0 <= qi - j < window.
    Implemented with chunk size == window over [prev, self] chunk pairs."""
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    W = min(window, S)
    assert S % W == 0, (S, window)
    nc = S // W
    qg = (q.reshape(B, nc, W, Hkv, G, hd) * (hd ** -0.5))
    kc = k.reshape(B, nc, W, Hkv, hd)
    vc = v.reshape(B, nc, W, Hkv, hd)
    pad = jnp.zeros_like(kc[:, :1])
    k2 = jnp.concatenate([jnp.concatenate([pad, kc[:, :-1]], 1), kc], axis=2)
    v2 = jnp.concatenate([jnp.concatenate([pad, vc[:, :-1]], 1), vc], axis=2)
    s = jnp.einsum("bnchgd,bnthd->bnhgct", qg, k2,
                   preferred_element_type=jnp.float32)           # (B,nc,H,G,W,2W)
    qi = jnp.arange(W)[:, None] + W                              # in-pair coords
    kj = jnp.arange(2 * W)[None, :]
    ok = (qi >= kj) & (qi - kj < W)
    first = jnp.arange(2 * W)[None, :] >= W                      # chunk 0 has no prev
    ok0 = ok & first
    mask = jnp.where(jnp.arange(nc)[:, None, None] == 0, ok0[None], ok[None])
    s = jnp.where(mask[None, :, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bnhgct,bnthd->bnchgd", p.astype(v2.dtype), v2)
    return o.reshape(B, S, Hq, hd)


def attend_local_scanned(q, k, v, *, window: int) -> jax.Array:
    """Same sliding-window semantics as attend_local, but lax.map over the
    chunk index with a checkpointed body: live score memory is ONE chunk's
    (B, H, G, W, 2W) instead of all nc chunks at once, and the backward
    recomputes scores per chunk (§Perf memory-term lever)."""
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    W = min(window, S)
    assert S % W == 0, (S, window)
    nc = S // W
    qg = q.reshape(B, nc, W, Hkv, G, hd) * (hd ** -0.5)
    kc = k.reshape(B, nc, W, Hkv, hd)
    vc = v.reshape(B, nc, W, Hkv, hd)
    pad = jnp.zeros_like(kc[:, :1])
    kpad = jnp.concatenate([pad, kc], axis=1)                     # (B,nc+1,..)
    vpad = jnp.concatenate([pad, vc], axis=1)

    qi = jnp.arange(W)[:, None] + W
    kj = jnp.arange(2 * W)[None, :]
    ok = (qi >= kj) & (qi - kj < W)
    ok0 = ok & (kj >= W)                                          # no prev chunk

    @jax.checkpoint
    def body(i):
        k2 = jax.lax.dynamic_slice_in_dim(kpad, i, 2, axis=1)
        v2 = jax.lax.dynamic_slice_in_dim(vpad, i, 2, axis=1)
        k2 = k2.reshape(B, 2 * W, Hkv, hd)
        v2 = v2.reshape(B, 2 * W, Hkv, hd)
        s = jnp.einsum("bchgd,bthd->bhgct", qg[:, i], k2,
                       preferred_element_type=jnp.float32)
        mask = jnp.where(i == 0, ok0, ok)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgct,bthd->bchgd", p.astype(v2.dtype), v2)
        return o

    o = jax.lax.map(body, jnp.arange(nc))                         # (nc,B,W,..)
    o = jnp.moveaxis(o, 0, 1).reshape(B, S, Hq, hd)
    return o


# ---------------------------------------------------------------------------
# Zebra kv_cache site (beyond-paper): block-compress K/V at the HBM write
# ---------------------------------------------------------------------------

def zebra_kv_site(k: jax.Array, v: jax.Array, zc) -> tuple[jax.Array, jax.Array, list]:
    """Apply the engine's ``kv_cache`` Zebra site to freshly-computed K/V
    ``(B, S, Hkv, hd)`` before they are written to the cache. Heads fold
    onto the channel axis so the (block_seq, block_ch) tiles match how the
    cache is actually laid out (and transported — serve.py moves the
    prefill->decode handoff in exactly this block form).

    Returns (k', v', [SiteAux_k, SiteAux_v]).
    """
    from ...core.engine import zebra_site

    B, S = k.shape[0], k.shape[1]
    auxes = []
    out = []
    for t in (k, v):
        tf = t.reshape(B, S, -1)
        tz, aux = zebra_site(tf, zc, site="kv_cache", layout="tokens")
        out.append(tz.reshape(t.shape))
        auxes.append(aux)
    return out[0], out[1], auxes


def gather_kv_shards(k: jax.Array, v: jax.Array, zc) -> tuple[jax.Array, jax.Array, list]:
    """Gather sequence-sharded K/V ``(B, S_local, Hkv, hd)`` into the full
    ``(B, n*S_local, Hkv, hd)`` pair over the active comm axis — in Zebra
    stream form when the ``kv_cache`` site's backend declares the
    ``comms`` capability, dense ``lax.all_gather`` with a logged degrade
    reason otherwise. Heads fold onto the channel axis exactly like
    ``zebra_kv_site`` (the cache/transport layout), so the wire blocks
    are the same (block_seq, block_ch) tiles serve.py already moves.

    No comm context: strict no-op — returns ``(k, v, [])``, the
    single-process semantics of every existing call site.
    """
    from ...core.engine import zebra_site
    from ...distributed import collectives as coll
    from ...distributed.ctx import comm_axis

    info = comm_axis()
    if info is None:
        return k, v, []
    axis, n = info
    B, S, Hkv, hd = k.shape
    D = Hkv * hd
    bs = zc.block_seq if S % zc.block_seq == 0 else 1
    bc = zc.block_ch if D % zc.block_ch == 0 else D
    backend = zc.backend_for("kv_cache")
    comms, reason = coll.resolve_comms(backend, rows=B * S, cols=D,
                                       bs=bs, bc=bc)
    out, auxes = [], []
    for t in (k, v):
        tz, sa = zebra_site(t.reshape(B, S, D), zc, site="kv_cache",
                            layout="tokens")
        if comms == "compressed":
            g, link = coll.zebra_all_gather(tz.reshape(B * S, D), axis,
                                            bs=bs, bc=bc,
                                            validation=zc.validation,
                                            site="kv_cache")
            full = (g.reshape(n, B, S, D).transpose(1, 0, 2, 3)
                    .reshape(B, n * S, Hkv, hd))
            sa = coll.attach_link(sa, link)
        else:
            coll.log_comm_degrade("kv_cache", backend, reason)
            full = jax.lax.all_gather(
                tz.reshape(B, S, Hkv, hd), axis, axis=1, tiled=True)
            sa = coll.attach_link(
                sa, coll.dense_link(tz.size * jnp.dtype(tz.dtype).itemsize, n),
                reason=reason)
        out.append(full)
        auxes.append(sa)
    return out[0], out[1], auxes


# ---------------------------------------------------------------------------
# Decode (single query token vs cache)
# ---------------------------------------------------------------------------

def attend_decode(q, k_cache, v_cache, pos, *, window: int = 0) -> jax.Array:
    """q (B,1,Hq,hd); caches (B,T,Hkv,hd); pos: current index — a scalar
    (whole batch at one position) or (B,) per-lane positions (the slotted
    continuous-batching decode, where every lane is a different request).
    With `window`, the cache is a ring buffer of size T == window."""
    B, _, Hq, hd = q.shape
    T, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, hd) * (hd ** -0.5)
    s = jnp.einsum("bhgd,bthd->bhgt", qg, k_cache,
                   preferred_element_type=jnp.float32)
    idx = jnp.arange(T)
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        if window:
            valid = idx < jnp.minimum(pos + 1, T)                # ring: all live
        else:
            valid = idx <= pos
        valid = valid[None]                                      # (1, T)
    else:
        if window:
            valid = idx[None, :] < jnp.minimum(pos + 1, T)[:, None]
        else:
            valid = idx[None, :] <= pos[:, None]                 # (B, T)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgt,bthd->bhgd", p.astype(v_cache.dtype), v_cache)
    return o.reshape(B, 1, Hq, hd)
