"""The LM model: embedding + pattern runs (scan-over-layers) + head.

API (all pure functions of params):
  init(key)                                  -> params
  forward(params, tokens, mode, enc_feats)   -> (logits, aux)
  loss(params, tokens, mode, enc_feats)      -> (scalar, metrics)   [chunked CE]
  init_cache(batch, cache_len)               -> caches
  prefill(params, tokens, cache_len, ...)    -> (last_logits, caches, aux)
  decode_step(params, token, caches, pos)    -> (logits, caches)

Encoder-decoder (whisper): ``enc_feats`` is the stub frontend output —
precomputed frame embeddings (B, enc_seq, d_model); the encoder is a stack
of non-causal "global" layers; decoder layers carry cross-attention.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from ...distributed.ctx import hint_tokens
from ..layers import rmsnorm_apply, rmsnorm_init, layernorm_apply, layernorm_init
from .attention import rope_frequencies
from .blocks import (apply_layer, apply_layer_decode, apply_layer_prefill,
                     init_layer, init_layer_cache, zero_aux)
from .config import LMConfig


def layer_runs(cfg: LMConfig) -> list[tuple[tuple[str, ...], int]]:
    """[(superlayer pattern, repeat count)] covering all n_layers."""
    P = len(cfg.layer_pattern)
    runs = []
    g, r = divmod(cfg.n_layers, P)
    if g:
        runs.append((tuple(cfg.layer_pattern), g))
    if r:
        runs.append((tuple(cfg.layer_pattern[:r]), 1))
    return runs


class LM:
    def __init__(self, cfg: LMConfig):
        self.cfg = cfg
        self.runs = layer_runs(cfg)
        self.pdt = jnp.dtype(cfg.param_dtype)
        self.cdt = jnp.dtype(cfg.compute_dtype)

    # ------------------------------------------------------------------
    def init(self, key) -> dict:
        cfg = self.cfg
        ks = iter(jax.random.split(key, 1024))
        params: dict[str, Any] = {
            "embed": jax.random.normal(next(ks), (cfg.vocab, cfg.d_model),
                                       self.pdt) * (cfg.d_model ** -0.5),
            "final_norm": (rmsnorm_init(cfg.d_model) if cfg.norm == "rmsnorm"
                           else layernorm_init(cfg.d_model)),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = jax.random.normal(
                next(ks), (cfg.d_model, cfg.vocab), self.pdt) * (cfg.d_model ** -0.5)
        cross = cfg.encoder_layers > 0
        for ri, (pattern, count) in enumerate(self.runs):
            def init_super(k, pattern=pattern):
                sks = jax.random.split(k, len(pattern))
                return {f"sub{j}": init_layer(sks[j], t, cfg, self.pdt, cross)
                        for j, t in enumerate(pattern)}
            if count > 1:
                params[f"run{ri}"] = jax.vmap(init_super)(
                    jnp.stack(jax.random.split(next(ks), count)))
            else:
                params[f"run{ri}"] = init_super(next(ks))
        if cross:
            def init_enc(k):
                return init_layer(k, "global", cfg, self.pdt, cross=False)
            params["encoder"] = jax.vmap(init_enc)(
                jnp.stack(jax.random.split(next(ks), cfg.encoder_layers)))
            params["enc_norm"] = (rmsnorm_init(cfg.d_model) if cfg.norm == "rmsnorm"
                                  else layernorm_init(cfg.d_model))
        return params

    # ------------------------------------------------------------------
    def _norm_f(self, p, x):
        return (rmsnorm_apply(p, x) if self.cfg.norm == "rmsnorm"
                else layernorm_apply(p, x))

    def _maybe_remat(self, f):
        if self.cfg.remat == "block":
            return jax.checkpoint(
                f, policy=jax.checkpoint_policies.nothing_saveable)
        if self.cfg.remat == "save_acts":
            # selective remat (§Perf): keep attention outputs + FFN hidden
            # maps (cheap to store, expensive to recompute); recompute the
            # rest of the block in backward.
            return jax.checkpoint(
                f, policy=jax.checkpoint_policies.save_only_these_names(
                    "attn_out", "ffn_hidden"))
        return f

    def _encode(self, params, enc_feats, mode: str):
        cfg = self.cfg
        x = enc_feats.astype(self.cdt)
        rope = rope_frequencies(cfg.head_dim, cfg.rope_theta,
                                jnp.arange(x.shape[1]))

        def body(carry, lp):
            x, aux = carry
            y, a = apply_layer(lp, x, "global", cfg, mode, rope, causal=False)
            return (y, aux + a), None
        body = self._maybe_remat(body)
        (x, aux), _ = jax.lax.scan(body, (x, zero_aux()), params["encoder"],
                                   unroll=cfg.encoder_layers if cfg.unroll_runs else 1)
        return self._norm_f(params["enc_norm"], x), aux

    def _backbone(self, params, x, mode: str, enc_out=None):
        cfg = self.cfg
        rope = rope_frequencies(cfg.head_dim, cfg.rope_theta,
                                jnp.arange(x.shape[1]))
        aux = zero_aux()
        for ri, (pattern, count) in enumerate(self.runs):
            rp = params[f"run{ri}"]

            def super_fwd(carry, lp, pattern=pattern):
                x, aux = carry
                for j, t in enumerate(pattern):
                    x, a = apply_layer(lp[f"sub{j}"], x, t, cfg, mode, rope,
                                       enc_out=enc_out)
                    aux = aux + a
                return (x, aux), None
            super_fwd = self._maybe_remat(super_fwd)
            if count > 1:
                (x, aux), _ = jax.lax.scan(super_fwd, (x, aux), rp,
                                           unroll=count if cfg.unroll_runs else 1)
            else:
                (x, aux), _ = super_fwd((x, aux), rp)
        return x, aux

    # ------------------------------------------------------------------
    def forward(self, params, tokens, mode: str = "train", enc_feats=None):
        cfg = self.cfg
        x = params["embed"][tokens].astype(self.cdt) * (cfg.d_model ** 0.5)
        x = hint_tokens(x)
        enc_out, enc_aux = (None, zero_aux())
        if cfg.encoder_layers and enc_feats is not None:
            enc_out, enc_aux = self._encode(params, enc_feats, mode)
        x, aux = self._backbone(params, x, mode, enc_out)
        x = self._norm_f(params["final_norm"], x)
        logits = self._project_vocab(params, x)
        return logits, aux + enc_aux

    def _project_vocab(self, params, x):
        w = (params["embed"].T if self.cfg.tie_embeddings
             else params["lm_head"]).astype(self.cdt)
        return hint_tokens(x @ w, "model")      # logits vocab-sharded

    # ------------------------------------------------------------------
    def loss(self, params, tokens, mode: str = "train", enc_feats=None):
        """tokens (B, S+1): next-token CE. ``cfg.ce_chunk`` bounds the
        logits buffer to (B, chunk, V) — the big-vocab memory lever."""
        cfg = self.cfg
        inp, lbl = tokens[:, :-1], tokens[:, 1:]
        x = params["embed"][inp].astype(self.cdt) * (cfg.d_model ** 0.5)
        x = hint_tokens(x)
        enc_out, enc_aux = (None, zero_aux())
        if cfg.encoder_layers and enc_feats is not None:
            enc_out, enc_aux = self._encode(params, enc_feats, mode)
        x, aux = self._backbone(params, x, mode, enc_out)
        x = self._norm_f(params["final_norm"], x)
        aux = aux + enc_aux
        B, S, _ = x.shape

        if cfg.ce_chunk and S % cfg.ce_chunk == 0 and S > cfg.ce_chunk:
            C = cfg.ce_chunk
            nc = S // C
            xc = x.reshape(B, nc, C, -1)
            lc = lbl.reshape(B, nc, C)

            def ce_chunk(tot, i):
                logits = self._project_vocab(params, xc[:, i]).astype(jnp.float32)
                lp = jax.nn.log_softmax(logits, axis=-1)
                nll = -jnp.take_along_axis(lp, lc[:, i][..., None], axis=-1)
                return tot + jnp.sum(nll), None
            ce_chunk = jax.checkpoint(ce_chunk)
            tot, _ = jax.lax.scan(ce_chunk, jnp.float32(0.0), jnp.arange(nc))
            ce = tot / (B * S)
        else:
            logits = self._project_vocab(params, x).astype(jnp.float32)
            lp = jax.nn.log_softmax(logits, axis=-1)
            ce = -jnp.mean(jnp.take_along_axis(lp, lbl[..., None], axis=-1))

        zreg, raux = aux.reg, aux.router_aux
        zero_frac = aux.zero_frac        # block-weighted, div-by-zero guarded
        # constant-threshold mode (zebra_tnet=False): Eq. 1's trainable L2
        # term is identically zero and aux.reg carries the realized
        # zero-block COUNT — a metrics observable, not a loss term
        total = ce + (zreg if cfg.zebra_tnet else 0.0)   # λ=1 fold
        if cfg.is_moe:
            total = total + cfg.router_aux_coef * raux
        metrics = {"ce": ce, "zebra_reg": zreg, "zero_frac": zero_frac,
                   "router_aux": raux,
                   # live on trainable stream-backend sites: f32 display
                   # readout + the exact (hi, lo) legs so the byte count
                   # survives >16 MiB totals (combine on host as
                   # hi * 2**24 + lo)
                   "measured_bytes": aux.measured_bytes,
                   "measured_bytes_hi": aux.mb_hi,
                   "measured_bytes_lo": aux.mb_lo}
        return total, metrics

    # ------------------------------------------------------------------
    def init_cache(self, batch: int, cache_len: int):
        caches = []
        for pattern, count in self.runs:
            sub = {f"sub{j}": init_layer_cache(t, self.cfg, batch, cache_len, self.cdt)
                   for j, t in enumerate(pattern)}
            if count > 1:
                sub = jax.tree_util.tree_map(
                    lambda c: jnp.broadcast_to(c[None], (count,) + c.shape), sub)
            caches.append(sub)
        return caches

    def prefill(self, params, tokens, cache_len: int, enc_feats=None):
        cfg = self.cfg
        x = params["embed"][tokens].astype(self.cdt) * (cfg.d_model ** 0.5)
        enc_out = None
        if cfg.encoder_layers and enc_feats is not None:
            enc_out, _ = self._encode(params, enc_feats, "infer")
        rope = rope_frequencies(cfg.head_dim, cfg.rope_theta,
                                jnp.arange(x.shape[1]))
        caches = []
        aux = zero_aux()
        for ri, (pattern, count) in enumerate(self.runs):
            rp = params[f"run{ri}"]

            def super_pf(carry, lp, pattern=pattern):
                x, aux = carry
                cs = {}
                for j, t in enumerate(pattern):
                    x, c, a = apply_layer_prefill(lp[f"sub{j}"], x, t, cfg, rope,
                                                  cache_len, enc_out)
                    cs[f"sub{j}"] = c
                    aux = aux + a
                return (x, aux), cs
            if count > 1:
                (x, aux), cs = jax.lax.scan(super_pf, (x, aux), rp,
                                            unroll=count if cfg.unroll_runs else 1)
            else:
                (x, aux), cs = super_pf((x, aux), rp)
            caches.append(cs)
        x = self._norm_f(params["final_norm"], x[:, -1:])
        logits = self._project_vocab(params, x)
        return logits[:, 0], (caches, enc_out), aux

    def decode_step(self, params, token, state, pos):
        """token (B,1) int32; pos scalar int32, or (B,) int32 per-lane
        positions (slotted continuous-batching decode — each lane is an
        independent request at its own sequence position). Returns
        (logits (B,V), state)."""
        cfg = self.cfg
        caches, enc_out = state
        x = params["embed"][token].astype(self.cdt) * (cfg.d_model ** 0.5)
        pos = jnp.asarray(pos)
        rope1 = rope_frequencies(cfg.head_dim, cfg.rope_theta,
                                 pos[None] if pos.ndim == 0 else pos[:, None])
        new_caches = []
        for ri, (pattern, count) in enumerate(self.runs):
            rp = params[f"run{ri}"]
            rc = caches[ri]

            def super_dec(x, lp, lc, pattern=pattern):
                ncs = {}
                for j, t in enumerate(pattern):
                    x, c = apply_layer_decode(lp[f"sub{j}"], x, lc[f"sub{j}"], t,
                                              cfg, pos, rope1, enc_out)
                    ncs[f"sub{j}"] = c
                return x, ncs
            if count > 1:
                def body(x, pc):
                    lp, lc = pc
                    x, nc = super_dec(x, lp, lc)
                    return x, nc
                x, ncs = jax.lax.scan(body, x, (rp, rc),
                                      unroll=count if cfg.unroll_runs else 1)
            else:
                x, ncs = super_dec(x, rp, rc)
            new_caches.append(ncs)
        x = self._norm_f(params["final_norm"], x)
        logits = self._project_vocab(params, x)[:, 0]
        return logits, (new_caches, enc_out)
