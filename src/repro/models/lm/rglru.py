"""Griffin recurrent block with RG-LRU (De et al., arXiv:2402.19427).

Block: x -> [linear -> GeLU] gate branch ∥ [linear -> causal conv1d ->
RG-LRU] recurrent branch -> ⊙ -> out linear.

RG-LRU:  r_t = σ(W_a u_t + b_a);  i_t = σ(W_x u_t + b_x)
         log a_t = -c · softplus(Λ) · r_t            (c = 8)
         h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ u_t)
The sequence recurrence is a first-order linear scan -> associative_scan
(O(log S) depth, TPU-friendly). Decode is an O(1) update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..layers import lecun_normal
from .config import LMConfig

_C = 8.0


def rglru_init(key, cfg: LMConfig, dtype):
    d, dl = cfg.d_model, cfg.lru_dim
    ks = jax.random.split(key, 6)
    # Λ init so that a^c in [0.9, 0.999] at r=1 (paper App. A)
    u = jax.random.uniform(ks[0], (dl,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))                    # softplus^-1
    return {
        "w_gate_branch": lecun_normal(ks[1], (d, dl), dtype),
        "w_rec_branch": lecun_normal(ks[2], (d, dl), dtype),
        "conv_w": jax.random.normal(ks[3], (cfg.conv_width, dl), dtype)
                  * (cfg.conv_width ** -0.5),
        "w_a": lecun_normal(ks[4], (dl, dl), dtype),
        "b_a": jnp.zeros((dl,), jnp.float32),
        "w_x": lecun_normal(ks[5], (dl, dl), dtype),
        "b_x": jnp.zeros((dl,), jnp.float32),
        "lam": lam,
        "w_out": lecun_normal(ks[0], (dl, d), dtype, fan_in=dl),
    }


def _gates(p, u):
    r = jax.nn.sigmoid(u @ p["w_a"].astype(u.dtype) + p["b_a"].astype(u.dtype))
    i = jax.nn.sigmoid(u @ p["w_x"].astype(u.dtype) + p["b_x"].astype(u.dtype))
    log_a = (-_C * jax.nn.softplus(p["lam"])[None] * r.astype(jnp.float32))
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 0.0, 1.0)) \
        * (i.astype(jnp.float32) * u.astype(jnp.float32))
    return a, b


def _causal_conv1d(x, w):
    W = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    y = jnp.zeros_like(x)
    for i in range(W):
        y = y + pad[:, i:i + x.shape[1]] * w[i][None, None, :]
    return y


def rglru_apply(p, x, cfg: LMConfig):
    """x (B,S,d) -> (B,S,d). Full-sequence (training / prefill) path."""
    gate = jax.nn.gelu(x @ p["w_gate_branch"].astype(x.dtype))
    u = _causal_conv1d(x @ p["w_rec_branch"].astype(x.dtype),
                       p["conv_w"].astype(x.dtype))
    a, b = _gates(p, u)                                           # (B,S,dl) fp32

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br
    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (h.astype(x.dtype) * gate) @ p["w_out"].astype(x.dtype)
    return y


def rglru_init_cache(cfg: LMConfig, batch: int, dtype) -> dict:
    dl = cfg.lru_dim
    return {"h": jnp.zeros((batch, dl), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, dl), dtype)}


def rglru_decode_step(p, x, cache, cfg: LMConfig):
    """x (B,1,d) -> (y (B,1,d), cache). O(1)."""
    gate = jax.nn.gelu(x @ p["w_gate_branch"].astype(x.dtype))
    u_in = x @ p["w_rec_branch"].astype(x.dtype)                  # (B,1,dl)
    hist = jnp.concatenate([cache["conv"], u_in], axis=1)
    u = jnp.einsum("bwc,wc->bc", hist, p["conv_w"].astype(x.dtype))[:, None]
    a, b = _gates(p, u)                                           # (B,1,dl)
    h = a[:, 0] * cache["h"] + b[:, 0]
    y = (h[:, None].astype(x.dtype) * gate) @ p["w_out"].astype(x.dtype)
    return y, {"h": h, "conv": hist[:, 1:]}
