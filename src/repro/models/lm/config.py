"""LM architecture config — one frozen dataclass drives the whole stack.

``layer_pattern`` is cycled over ``n_layers``; element types:
  "global"  full causal self-attention
  "local"   sliding-window self-attention (window = cfg.window)
  "rglru"   Griffin RG-LRU recurrent block (temporal conv + gated LRU)
  "ssm"     Mamba-2 SSD block
Every layer is followed by its FFN (dense or MoE) except "ssm"/"rglru"
blocks in pure-SSM archs where the block already contains the gated MLP
(Mamba-2 convention: no separate FFN when d_ff == 0).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str = "lm"
    family: str = "dense"            # dense|moe|ssm|hybrid|audio|vlm
    n_layers: int = 4
    d_model: int = 512
    n_heads: int = 8
    n_kv_heads: int = 8
    d_ff: int = 2048
    vocab: int = 32000
    head_dim: int = 0                # 0 => d_model // n_heads
    layer_pattern: tuple[str, ...] = ("global",)
    window: int = 1024               # sliding-window size for "local"
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    act: str = "swiglu"              # swiglu | gelu
    tie_embeddings: bool = True
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # --- Mamba-2 ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    # --- RG-LRU ---
    lru_dim: int = 0                 # 0 => d_model
    conv_width: int = 4
    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    enc_seq: int = 1500              # stub frontend frames
    # --- compute/memory knobs (perf levers, see EXPERIMENTS §Perf) ---
    attn_chunk: int = 1024           # q/kv chunk for chunked attention
    ce_chunk: int = 1024             # 0 = unchunked CE; else seq-chunk size
                                     # (bounds logits to (B,chunk,V) — the
                                     # big-vocab memory lever, §Perf)
    remat: str = "block"             # none | block
    unroll_runs: bool = False        # unroll layer scans (dry-run cost
                                     # analysis: XLA counts while bodies once)
    grad_accum: int = 1              # microbatch accumulation steps inside
                                     # train_step (activation memory / K)
    sharding_profile: str = "tp"     # "tp" (FSDP+TP/EP) | "dp" (pure data
                                     # parallel over data x model — right for
                                     # small-expert MoE, see §Perf granite)
    local_impl: str = "banded"       # "banded" | "scanned" local attention
                                     # (scanned = chunk-scan + remat, bounds
                                     # the score materialization, §Perf)
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # --- Zebra integration (the paper's technique) ---
    zebra_enabled: bool = True
    zebra_t_obj: float = 0.1
    zebra_block_seq: int = 8
    zebra_block_ch: int = 128
    zebra_sites: tuple[str, ...] = ("ffn_hidden",)  # +"layer_out", +"kv_cache"
    use_kernel: bool = False         # legacy switch == zebra_backend="stream"
                                     # (comparator + pack/unpack transport)
    zebra_backend: str = ""          # engine backend for every Zebra site:
                                     # reference | pallas | stream | fused
                                     # ("" = stream if use_kernel else
                                     # reference); train mode always runs
                                     # reference (core.engine)
    zebra_site_backends: tuple[tuple[str, str], ...] = ()
                                     # per-site overrides, e.g.
                                     # (("kv_cache", "stream"),)
    zebra_tnet: bool = True          # learned threshold nets at Zebra sites;
                                     # False = constant-T_obj (deployment-
                                     # matched) training, which the kernel
                                     # backends serve through custom_vjp —
                                     # tnet sites always resolve to reference
    zebra_validation: str = "off"    # stream-integrity level at every
                                     # boundary that ingests a (bitmap,
                                     # payload) stream: off | structural |
                                     # checksum (ZebraConfig.validation /
                                     # compress.integrity)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))
        if self.lru_dim == 0:
            object.__setattr__(self, "lru_dim", self.d_model)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def layer_types(self) -> tuple[str, ...]:
        p = self.layer_pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    @property
    def d_inner(self) -> int:        # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def replace(self, **kw) -> "LMConfig":
        return dataclasses.replace(self, **kw)

    # ----- parameter counting (for MODEL_FLOPS = 6·N·D roofline term) -----
    def param_counts(self) -> dict[str, int]:
        d, hd = self.d_model, self.head_dim
        nq, nkv = self.n_heads, self.n_kv_heads
        emb = self.vocab * d
        out_head = 0 if self.tie_embeddings else self.vocab * d
        per_attn = d * nq * hd + 2 * d * nkv * hd + nq * hd * d
        if self.qkv_bias:
            per_attn += (nq + 2 * nkv) * hd
        if self.act == "swiglu":
            per_ffn_dense = 3 * d * self.d_ff
        else:
            per_ffn_dense = 2 * d * self.d_ff + self.d_ff + d
        total = emb + out_head
        active = total
        for t in self.layer_types:
            if t in ("global", "local"):
                total += per_attn
                active += per_attn
                if self.is_moe:
                    total += self.n_experts * per_ffn_dense + d * self.n_experts
                    active += self.top_k * per_ffn_dense + d * self.n_experts
                elif self.d_ff > 0:
                    total += per_ffn_dense
                    active += per_ffn_dense
            elif t == "rglru":
                dl = self.lru_dim
                blk = 2 * d * dl + dl * d + self.conv_width * dl + 2 * dl * dl + 2 * dl
                total += blk
                active += blk
                if self.d_ff > 0:
                    total += per_ffn_dense
                    active += per_ffn_dense
            elif t == "ssm":
                di, ds, nh = self.d_inner, self.ssm_state, self.ssm_heads
                blk = d * (2 * di + 2 * ds + nh) + di * d + 2 * nh + di
                total += blk
                active += blk
            total += 2 * d  # norms
            active += 2 * d
        if self.encoder_layers:
            enc = self.encoder_layers * (per_attn + per_ffn_dense + 2 * d)
            dec_cross = self.n_layers * (per_attn + d)
            total += enc + dec_cross
            active += enc + dec_cross
        return {"total": int(total), "active": int(active)}
