"""Mamba-2 / SSD block (Dao & Gu, arXiv:2405.21060) — chunked matmul form.

State-space duality: y_t = Σ_{s≤t} C_t·(Π_{r∈(s,t]} e^{A·dt_r})·B_s·dt_s·x_s
+ D·x_t, computed as (intra-chunk quadratic) + (inter-chunk state scan), so
everything is MXU-shaped matmuls except one tiny per-chunk scan. ngroups=1.

Projections are kept *separate* (z, x, B, C, dt) rather than packed, so
tensor parallelism shards the head/d_inner axis cleanly (z/x/dt over
"model"; B/C are per-group states, replicated) — the packed-matrix slicing
of the reference CUDA impl does not transfer to SPMD sharding
(DESIGN.md §2 hardware-adaptation note).

Block: separate in-projections; causal conv1d(width w) + silu on x, B, C;
SSD over heads; y ⊙ silu(z); RMSNorm; out_proj.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..layers import lecun_normal, rmsnorm_apply, rmsnorm_init
from .config import LMConfig


def _causal_conv1d(x: jax.Array, w: jax.Array) -> jax.Array:
    """x (B,S,C), w (W,C) depthwise causal: y[t] = Σ_i w[i]·x[t-W+1+i]."""
    W = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    y = jnp.zeros_like(x)
    for i in range(W):
        y = y + pad[:, i:i + x.shape[1]] * w[i][None, None, :]
    return y


def _segsum(dtA: jax.Array) -> jax.Array:
    """dtA (..., Q) -> L (..., Q, Q): L[i,j] = Σ_{j<r<=i} dtA[r], -inf j>i."""
    Q = dtA.shape[-1]
    cs = jnp.cumsum(dtA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]                    # i,j
    ok = jnp.arange(Q)[:, None] >= jnp.arange(Q)[None, :]
    return jnp.where(ok, diff, -jnp.inf)


def ssm_init(key, cfg: LMConfig, dtype):
    d, di, ds, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(key, 8)
    cw = cfg.conv_width
    return {
        "z_proj": lecun_normal(ks[0], (d, di), dtype),
        "x_proj": lecun_normal(ks[1], (d, di), dtype),
        "b_proj": lecun_normal(ks[2], (d, ds), dtype),
        "c_proj": lecun_normal(ks[3], (d, ds), dtype),
        "dt_proj": lecun_normal(ks[4], (d, nh), dtype),
        "conv_x": jax.random.normal(ks[5], (cw, di), dtype) * (cw ** -0.5),
        "conv_b": jax.random.normal(ks[6], (cw, ds), dtype) * (cw ** -0.5),
        "conv_c": jax.random.normal(ks[7], (cw, ds), dtype) * (cw ** -0.5),
        "A_log": jnp.zeros((nh,), jnp.float32),                   # A = -exp(A_log)
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.full((nh,), -2.0, jnp.float32),            # softplus ~ 0.12
        "out_norm": rmsnorm_init(di, jnp.float32),
        "out_proj": lecun_normal(ks[0], (di, d), dtype, fan_in=di),
    }


def _projections(p, h):
    z = h @ p["z_proj"].astype(h.dtype)
    x = h @ p["x_proj"].astype(h.dtype)
    Bm = h @ p["b_proj"].astype(h.dtype)
    Cm = h @ p["c_proj"].astype(h.dtype)
    dt = h @ p["dt_proj"].astype(h.dtype)
    return z, x, Bm, Cm, dt


def ssm_apply(p, hidden, cfg: LMConfig):
    """hidden (B,S,d) -> (B,S,d). Chunked SSD."""
    B, S, d = hidden.shape
    di, ds, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    Q = min(cfg.ssm_chunk, S)
    while S % Q:                    # largest chunk <= cfg.ssm_chunk dividing S
        Q -= 1
    nc = S // Q
    z, xr, Bm, Cm, dt = _projections(p, hidden)
    xr = jax.nn.silu(_causal_conv1d(xr, p["conv_x"].astype(xr.dtype)))
    Bm = jax.nn.silu(_causal_conv1d(Bm, p["conv_b"].astype(Bm.dtype)))
    Cm = jax.nn.silu(_causal_conv1d(Cm, p["conv_c"].astype(Cm.dtype)))
    xs = xr.reshape(B, S, nh, hd)
    A = -jnp.exp(p["A_log"])                                      # (nh,)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,nh)

    # chunk views
    xc = xs.reshape(B, nc, Q, nh, hd)
    dtc = dt.reshape(B, nc, Q, nh)
    Bc = Bm.reshape(B, nc, Q, ds).astype(jnp.float32)
    Cc = Cm.reshape(B, nc, Q, ds).astype(jnp.float32)
    dtA = dtc * A[None, None, None, :]                            # (B,nc,Q,nh)
    xdt = xc.astype(jnp.float32) * dtc[..., None]

    # --- intra-chunk (quadratic within Q) ---
    L = jnp.exp(_segsum(jnp.moveaxis(dtA, -1, -2)))               # (B,nc,nh,Q,Q)
    G = jnp.einsum("bnis,bnjs->bnij", Cc, Bc)                     # (B,nc,Q,Q)
    M = G[:, :, None] * L                                         # (B,nc,nh,Q,Q)
    Yd = jnp.einsum("bnhij,bnjhp->bnihp", M, xdt)

    # --- chunk states + inter-chunk recurrence ---
    cs = jnp.cumsum(dtA, axis=2)                                  # (B,nc,Q,nh)
    to_end = jnp.exp(cs[:, :, -1:, :] - cs)                       # decay j..end
    St = jnp.einsum("bnjs,bnjh,bnjhp->bnhsp", Bc, to_end, xdt)    # (B,nc,nh,ds,hd)
    chunk_decay = jnp.exp(cs[:, :, -1, :])                        # (B,nc,nh)

    def step(H, inp):
        St_n, dec_n = inp
        H_new = H * dec_n[..., None, None] + St_n
        return H_new, H                                           # emit H_prev
    H0 = jnp.zeros((B, nh, ds, hd), jnp.float32)
    _, Hprev = jax.lax.scan(step, H0,
                            (jnp.moveaxis(St, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    Hprev = jnp.moveaxis(Hprev, 0, 1)                             # (B,nc,nh,ds,hd)
    in_decay = jnp.exp(cs)                                        # decay start..i
    Yo = jnp.einsum("bnis,bnhsp,bnih->bnihp", Cc, Hprev, in_decay)

    y = (Yd + Yo).reshape(B, S, nh, hd) + p["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, S, di).astype(hidden.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm_apply(p["out_norm"], y)
    return y @ p["out_proj"].astype(hidden.dtype)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def ssm_init_cache(cfg: LMConfig, batch: int, dtype) -> dict:
    di, ds, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    w = cfg.conv_width - 1
    return {"H": jnp.zeros((batch, nh, ds, hd), jnp.float32),
            "conv_x": jnp.zeros((batch, w, di), dtype),
            "conv_b": jnp.zeros((batch, w, ds), dtype),
            "conv_c": jnp.zeros((batch, w, ds), dtype)}


def _conv_step(cache_buf, new, w):
    hist = jnp.concatenate([cache_buf, new], axis=1)              # (B,W,C)
    out = jnp.einsum("bwc,wc->bc", hist, w)
    return out, hist[:, 1:]


def ssm_decode_step(p, hidden, cache, cfg: LMConfig):
    """hidden (B,1,d) -> (y (B,1,d), new cache). O(1) recurrent update."""
    B = hidden.shape[0]
    di, ds, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xr, Bm, Cm, dt = _projections(p, hidden)                   # (B,1,·)
    cdt = hidden.dtype
    xo, cx = _conv_step(cache["conv_x"], xr, p["conv_x"].astype(cdt))
    bo, cb = _conv_step(cache["conv_b"], Bm, p["conv_b"].astype(cdt))
    co, cc = _conv_step(cache["conv_c"], Cm, p["conv_c"].astype(cdt))
    xs = jax.nn.silu(xo).reshape(B, nh, hd).astype(jnp.float32)
    Bv = jax.nn.silu(bo).astype(jnp.float32)
    Cv = jax.nn.silu(co).astype(jnp.float32)
    A = -jnp.exp(p["A_log"])
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,nh)
    decay = jnp.exp(dt1 * A[None, :])
    H = cache["H"] * decay[..., None, None] + jnp.einsum(
        "bs,bh,bhp->bhsp", Bv, dt1, xs)
    y = jnp.einsum("bs,bhsp->bhp", Cv, H) + p["D"][None, :, None] * xs
    y = y.reshape(B, 1, di).astype(cdt)
    y = y * jax.nn.silu(z)
    y = rmsnorm_apply(p["out_norm"], y)
    return y @ p["out_proj"].astype(cdt), {"H": H, "conv_x": cx,
                                           "conv_b": cb, "conv_c": cc}


def ssm_prefill_state(p, hidden, cfg: LMConfig):
    """Final SSD state after consuming hidden (B,S,d) — replays only the
    inter-chunk recurrence (matmul-light)."""
    B, S, _ = hidden.shape
    di, ds, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    _, xr_pre, Bm_pre, Cm_pre, dt = _projections(p, hidden)
    xr = jax.nn.silu(_causal_conv1d(xr_pre, p["conv_x"].astype(hidden.dtype)))
    Bm = jax.nn.silu(_causal_conv1d(Bm_pre, p["conv_b"].astype(hidden.dtype)))
    xs = xr.reshape(B, S, nh, hd).astype(jnp.float32)
    A = -jnp.exp(p["A_log"])
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    dtA = dtv * A[None, None, :]
    cs = jnp.cumsum(dtA, axis=1)
    to_end = jnp.exp(cs[:, -1:, :] - cs)
    H = jnp.einsum("bjs,bjh,bjhp->bhsp", Bm.astype(jnp.float32), to_end * dtv, xs)
    w = cfg.conv_width - 1
    return {"H": H, "conv_x": xr_pre[:, -w:], "conv_b": Bm_pre[:, -w:],
            "conv_c": Cm_pre[:, -w:]}
