"""Failure taxonomy + per-class recovery policies.

Every ingest boundary and the step supervisor route failures through ONE
classification so "what went wrong" and "what to do about it" are decided
in one place instead of per ``except`` clause:

=================  =====================================================
class              policy (``policy_for``)
=================  =====================================================
``CorruptStream``  ``recompute-dense`` — the (bitmap, payload) stream
                   failed the wire contract (``compress.integrity``);
                   re-request / recompute the map from its dense source
                   (serve replaces the leaf with the dense cache, the
                   engine and collectives re-run the dense path, restore
                   walks back the checkpoint chain).
``TransientStep``  ``restore-retry`` — a step failed for a reason that a
                   restore + retry plausibly clears (preempted device,
                   transient XLA error). The supervisor restores the
                   newest verified checkpoint with exponential backoff.
``PoisonBatch``    ``skip-batch`` — the *data* is bad (non-finite loss /
                   gradients from one batch); restoring would replay the
                   same batch into the same failure. Log it, skip it,
                   keep the state.
``DeviceLoss``     ``remesh`` — the device topology changed; the state
                   must be re-sharded over the live devices
                   (``ft.supervisor.remesh_state``) before stepping.
``DeadlineExceeded``  ``shed`` — a request blew its SLO (TTL in engine
                   ticks). The scheduler drops it with
                   ``status="shed"``; nothing about the *system* is
                   wrong, so it is logged but NOT counted against
                   ``max_failures``.
``Overload``       ``shed`` — the bounded pending queue overflowed.
                   Same accounting as ``DeadlineExceeded``: load
                   shedding is the system working as designed, not a
                   failure budget event.
=================  =====================================================

Everything else — ``KeyboardInterrupt``, ``SystemExit``, assertion and
programming errors — is *not* a fault: :func:`classify` returns ``None``
and the supervisor re-raises. The old behavior (every ``Exception`` is
retryable) turned typos into max_failures restore loops.
"""
from __future__ import annotations


class FaultError(RuntimeError):
    """Base of the classified failure taxonomy."""


class CorruptStream(FaultError):
    """A (bitmap, payload) stream failed the wire contract on ingest."""


class TransientStep(FaultError):
    """A step failure that restore + retry plausibly clears."""


class PoisonBatch(FaultError):
    """One batch produced non-finite loss/grads — skip it, keep state."""


class DeviceLoss(FaultError):
    """The device topology changed under the job."""


class DeadlineExceeded(FaultError):
    """A request blew its deadline (TTL in engine ticks) — shed it."""


class Overload(FaultError):
    """The bounded pending queue overflowed — shed the newest arrivals."""


POLICIES: dict[type, str] = {
    CorruptStream: "recompute-dense",
    TransientStep: "restore-retry",
    PoisonBatch: "skip-batch",
    DeviceLoss: "remesh",
    DeadlineExceeded: "shed",
    Overload: "shed",
}

# policies that are normal-operation outcomes, not system failures:
# the supervisor logs them but never counts them toward max_failures
SHED_POLICIES = ("shed",)

# Exception text markers that identify a known transient-infrastructure
# failure when the raiser didn't use the taxonomy (e.g. jaxlib's
# XlaRuntimeError). Deliberately narrow: an unrecognized error is a bug
# and must surface, not retry.
_TRANSIENT_MARKERS = ("RESOURCE_EXHAUSTED", "UNAVAILABLE", "DEADLINE_EXCEEDED",
                     "ABORTED", "INTERNAL", "preempt", "socket closed",
                     "connection reset")
_POISON_MARKERS = ("nan", "non-finite", "not finite", "inf loss")


def classify(exc: BaseException) -> type[FaultError] | None:
    """Map an exception onto its fault class, or ``None`` for
    "not a fault — re-raise". Explicit taxonomy instances win; known
    infrastructure errors match by status marker; anything else
    (including ``KeyboardInterrupt``/``SystemExit``, which are not even
    ``Exception``s) is unclassified."""
    if isinstance(exc, FaultError):
        for cls in (CorruptStream, TransientStep, PoisonBatch, DeviceLoss,
                    DeadlineExceeded, Overload):
            if isinstance(exc, cls):
                return cls
        return TransientStep
    if not isinstance(exc, Exception):
        return None                      # KeyboardInterrupt / SystemExit
    msg = f"{type(exc).__name__}: {exc}"
    low = msg.lower()
    if type(exc).__name__ == "XlaRuntimeError" or "jaxlib" in type(exc).__module__:
        if any(m.lower() in low for m in _TRANSIENT_MARKERS):
            return TransientStep
    if isinstance(exc, FloatingPointError) or \
            any(m in low for m in _POISON_MARKERS):
        return PoisonBatch
    if isinstance(exc, (RuntimeError, OSError, ConnectionError)) and \
            any(m.lower() in low for m in _TRANSIENT_MARKERS):
        return TransientStep
    return None


def policy_for(exc: BaseException) -> str | None:
    """The recovery policy name for an exception, or ``None`` (re-raise)."""
    cls = classify(exc)
    return POLICIES[cls] if cls is not None else None
