"""Deterministic fault injection — the chaos harness behind the
robustness claims.

Faults are declared as data (:class:`Fault`), armed with
:func:`inject`, and fire at *taps* compiled into the stream paths:

* :func:`stream_tap` sits where the engine has the raw
  ``(payload, bitmap, n_live)`` triple in hand (between the producer and
  the validator), and corrupts it in-graph;
* :func:`ring_hop_tap` sits inside the collectives' ring scan and zeroes
  the payload arriving at one chosen hop;
* :func:`corrupt_map` corrupts a concrete ``CompressedMap`` host-side
  (serve's jit handoff, checkpointed activation maps);
* :func:`corrupt_file` flips bytes in a checkpoint file on disk;
* :func:`crashing_step` wraps a step function to raise at step N
  (default :class:`~repro.ft.faults.TransientStep`; pass
  ``DeviceLoss`` etc. to exercise the other supervisor policies).

Everything is seedless-deterministic: a fault names its target position
(``arg``) outright, so a test or bench run injects the SAME corruption
every time — no flaky chaos.

Trace-time binding
------------------
The in-graph taps consult the active plan when they are *traced*, and
the corruption (or the identity) is baked into the jaxpr. With no plan
armed a tap adds literally nothing to the graph — the ``validation="off"``
hot path stays byte-identical. The flip side: do not reuse a function
jitted *outside* an :func:`inject` context *inside* one (or vice versa) —
jit caches don't key on the plan. The chaos tests build their jitted
functions inside the context (or run eagerly).

Fault kinds over one stream (all detected by ``compress.integrity``):

=============  ==========================================================
``bitflip``    flip bitmap bit ``arg`` (popcount no longer matches
               ``n_live`` — and the consumer slot map would shift)
``truncate``   zero the last live payload slot (a cut-short transfer;
               live-slot-nonzero invariant)
``nan``        poison one element of live slot ``arg`` with NaN
``value``      add 1.0 to one element of live slot ``arg`` — still
               finite and nonzero, so ONLY the checksum level sees it
``count``      ``n_live += 1`` (corrupt counter; popcount mismatch)
``drop_hop``   zero the payload arriving at ring hop ``arg``
               (:func:`ring_hop_tap` only)
``crash``      raise from the step function at step ``arg``
               (:func:`crashing_step`), or — at site ``"engine_tick"``
               via :func:`crash_tap` — kill the serving engine's tick
               loop at tick ``arg`` (the crash-recoverable-loop chaos
               path: the supervised engine restores its last snapshot
               and re-admits in-flight requests from their paged KV)
=============  ==========================================================
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from .faults import TransientStep

STREAM_KINDS = ("bitflip", "truncate", "nan", "value", "count")
HOP_KINDS = ("drop_hop",)
CRASH_KINDS = ("crash",)
ENGINE_TICK_SITE = "engine_tick"   # crash_tap's site in the serve loop


@dataclasses.dataclass
class Fault:
    """One declared fault. ``site`` matches the tap's site label
    (``"*"`` = any tap); ``arg`` picks the position (bit index, live
    slot, ring hop, step number); ``times`` is how many taps it fires at
    (-1 = every matching tap)."""
    kind: str
    site: str = "*"
    arg: int = 0
    times: int = 1


class FaultPlan:
    """The armed set of faults plus the record of what actually fired.
    ``injected`` is the ground truth the chaos tests compare against
    ``integrity.failures()`` — detection must be 1:1 with injection."""

    def __init__(self, faults: list[Fault]):
        self.faults = list(faults)
        self._remaining = [f.times for f in self.faults]
        self.injected: list[tuple[str, str]] = []

    def take(self, kinds: tuple[str, ...], site: str,
             arg: int | None = None) -> Fault | None:
        """Consume (at trace time) the first live fault matching this
        tap, or None. ``arg`` additionally requires an exact ``f.arg``
        match — crash faults name their target tick and must not fire
        at any other (position-style args keep the default any-match)."""
        for i, f in enumerate(self.faults):
            if f.kind not in kinds or self._remaining[i] == 0:
                continue
            if f.site != "*" and f.site != site:
                continue
            if arg is not None and f.arg != arg:
                continue
            if self._remaining[i] > 0:
                self._remaining[i] -= 1
            return f
        return None

    def note(self, kind: str, site: str) -> None:
        self.injected.append((kind, site))


_ACTIVE: contextvars.ContextVar[FaultPlan | None] = \
    contextvars.ContextVar("repro_fault_plan", default=None)


def active_plan() -> FaultPlan | None:
    return _ACTIVE.get()


@contextlib.contextmanager
def inject(*faults: Fault) -> Iterator[FaultPlan]:
    """Arm a fault plan for the dynamic extent of the block."""
    plan = FaultPlan(list(faults))
    tok = _ACTIVE.set(plan)
    try:
        yield plan
    finally:
        _ACTIVE.reset(tok)


# ---------------------------------------------------------------------------
# In-graph corruption
# ---------------------------------------------------------------------------

def _corrupt_stream(payload: jax.Array, bitmap: jax.Array, n_live: jax.Array,
                    kind: str, arg: int):
    """Apply one fault kind to a traced (payload, bitmap, n_live) triple.
    Every corruption is guarded to actually *bite* (a NaN written into a
    dead slot would be invisible — and would falsely fail the
    detected-iff-injected assertion)."""
    nb = payload.shape[0]
    nl = jnp.asarray(n_live).astype(jnp.int32)
    if kind == "bitflip":
        flat = bitmap.reshape(-1)
        pos = int(arg) % flat.shape[0]
        flipped = (1 - flat[pos].astype(jnp.int32)).astype(flat.dtype)
        bitmap = flat.at[pos].set(flipped).reshape(bitmap.shape)
    elif kind == "count":
        n_live = nl + 1
    elif kind == "truncate":
        last = jnp.maximum(nl - 1, 0)
        dead = jnp.arange(nb, dtype=jnp.int32)[:, None, None] == last
        payload = jnp.where(dead & (nl > 0), jnp.zeros_like(payload), payload)
    elif kind in ("nan", "value"):
        slot = jnp.where(nl > 0, jnp.minimum(jnp.int32(arg), nl - 1),
                         jnp.int32(0))
        bad = (jnp.full((), jnp.nan, payload.dtype) if kind == "nan"
               else payload[slot, 0, 0] + jnp.asarray(1.0, payload.dtype))
        payload = payload.at[slot, 0, 0].set(
            jnp.where(nl > 0, bad, payload[slot, 0, 0]))
    else:
        raise ValueError(f"unknown stream fault kind {kind!r}")
    return payload, bitmap, n_live


def stream_tap(payload: jax.Array, bitmap: jax.Array, n_live: jax.Array,
               *, site: str):
    """Corruption point for one in-flight stream. Identity (and adds
    nothing to the graph) unless a matching fault is armed."""
    plan = active_plan()
    if plan is None:
        return payload, bitmap, n_live
    applied: set[int] = set()
    while True:
        f = plan.take(STREAM_KINDS, site)
        # each armed fault fires at most once per tap invocation — a
        # times=-1 (every-tap) fault is returned by take() forever, and
        # re-corrupting the same position is a no-op loop, not a fault
        if f is None or id(f) in applied:
            return payload, bitmap, n_live
        applied.add(id(f))
        payload, bitmap, n_live = _corrupt_stream(
            payload, bitmap, n_live, f.kind, f.arg)
        plan.note(f.kind, site)


def ring_hop_tap(payload: jax.Array, hop: jax.Array, *, site: str
                 ) -> jax.Array:
    """Corruption point inside a ring scan: zero the payload arriving at
    hop ``arg`` (1-based, matching the collectives' hop numbering).
    ``hop`` is traced — the tap is traced once for the whole scan and
    the ``where`` selects the hop."""
    plan = active_plan()
    if plan is None:
        return payload
    f = plan.take(HOP_KINDS, site)
    if f is None:
        return payload
    plan.note(f.kind, site)
    return jnp.where(jnp.asarray(hop).astype(jnp.int32) == jnp.int32(f.arg),
                     jnp.zeros_like(payload), payload)


# ---------------------------------------------------------------------------
# Host-side corruption (concrete maps / files)
# ---------------------------------------------------------------------------

def corrupt_map(cm: Any, kind: str, *, arg: int = 0) -> Any:
    """Return a corrupted copy of a concrete ``CompressedMap`` — the
    serve-handoff / checkpoint-restore chaos path. Same kinds and
    semantics as :func:`stream_tap` (checksum is carried over UNCHANGED —
    corrupting the stream must break the match, not re-sign it)."""
    from ..compress.stream import pack_bitmap, unpack_bitmap
    payload = np.array(cm.payload)
    n_live = int(np.asarray(cm.n_live))
    nm, nk = cm.m // cm.bs, cm.k // cm.bc
    if kind == "bitflip":
        bitmap = np.array(unpack_bitmap(jnp.asarray(cm.index), nm, nk))
        flat = bitmap.reshape(-1)
        pos = int(arg) % flat.size
        flat[pos] = 1 - int(flat[pos])
        index = np.asarray(pack_bitmap(jnp.asarray(bitmap)))
        return dataclasses.replace(cm, index=jnp.asarray(index))
    if kind == "count":
        return dataclasses.replace(cm, n_live=jnp.int32(n_live + 1))
    if kind == "truncate":
        if n_live > 0:
            payload[n_live - 1] = 0
        return dataclasses.replace(cm, payload=jnp.asarray(payload))
    if kind in ("nan", "value"):
        if n_live > 0:
            slot = min(int(arg), n_live - 1)
            val = (np.nan if kind == "nan"
                   else np.float32(payload[slot, 0, 0]) + np.float32(1.0))
            payload[slot, 0, 0] = np.asarray(val, payload.dtype)
        return dataclasses.replace(cm, payload=jnp.asarray(payload))
    raise ValueError(f"unknown map fault kind {kind!r}")


def corrupt_file(path: str, *, offset: int | None = None) -> None:
    """Flip one byte of a file in place (checkpoint-corruption chaos).
    Default offset: the middle of the file — past any header, inside the
    array data."""
    with open(path, "r+b") as f:
        f.seek(0, 2)
        size = f.tell()
        if size == 0:
            return
        pos = size // 2 if offset is None else int(offset) % size
        f.seek(pos)
        b = f.read(1)
        f.seek(pos)
        f.write(bytes([b[0] ^ 0xFF]))


# ---------------------------------------------------------------------------
# Step-level faults
# ---------------------------------------------------------------------------

def crash_tap(tick: int, *, site: str = ENGINE_TICK_SITE) -> None:
    """Kill point in the serving engine's tick loop: raises
    ``TransientStep`` when the armed plan carries a
    ``Fault("crash", site="engine_tick", arg=<tick>)`` for exactly this
    tick. The supervised engine classifies it, restores its last
    snapshot and re-admits the in-flight lanes from their paged KV —
    the chaos tests assert token parity against the un-crashed run."""
    plan = active_plan()
    if plan is None:
        return
    f = plan.take(CRASH_KINDS, site, arg=int(tick))
    if f is None:
        return
    plan.note(f.kind, site)
    raise TransientStep(f"injected engine crash at {site} tick {int(tick)}")


def crashing_step(step_fn: Callable, crash_at: int,
                  exc: Callable[[], BaseException] | None = None,
                  times: int = 1) -> Callable:
    """Wrap a step function to raise at its ``crash_at``-th call
    (1-based), ``times`` times total. Default exception:
    ``TransientStep`` — the restore-retry supervisor policy."""
    make = exc or (lambda: TransientStep(f"injected crash at call {crash_at}"))
    calls = {"n": 0, "raised": 0}

    def wrapped(*a, **kw):
        calls["n"] += 1
        if calls["n"] >= crash_at and calls["raised"] < times:
            calls["raised"] += 1
            raise make()
        return step_fn(*a, **kw)

    wrapped.calls = calls  # type: ignore[attr-defined]
    return wrapped
