"""Fault-tolerance supervisor (DESIGN.md §5).

Wraps a step loop with:
  * periodic checkpointing (async, atomic) + auto-resume,
  * heartbeat file (external watchdogs / co-hosts read it),
  * straggler detection — step-time z-score over a trailing window; on a
    real multi-host job the same detector runs on the per-host heartbeat
    matrix and the slowest host is evicted / re-sharded around,
  * elastic re-mesh — on device-count change (simulated or real restart),
    the mesh is rebuilt from the live device count and the state is
    re-sharded via device_put with re-derived NamedShardings.

The supervisor is deliberately host-side, framework-agnostic code: the
same loop drives the CPU demo here and a real TPU slice (jax.distributed
initializes per-host; the heartbeat file becomes a shared-store key).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from collections import deque
from typing import Any, Callable

import jax
import numpy as np

from ..checkpoint import CheckpointManager


@dataclasses.dataclass
class FTConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep_last: int = 3
    heartbeat_path: str = ""           # default: <ckpt_dir>/heartbeat.json
    straggler_window: int = 20
    straggler_zscore: float = 4.0
    max_failures: int = 3


class StepSupervisor:
    def __init__(self, cfg: FTConfig):
        self.cfg = cfg
        self.ckpt = CheckpointManager(cfg.ckpt_dir, cfg.keep_last)
        self.hb_path = cfg.heartbeat_path or os.path.join(cfg.ckpt_dir, "heartbeat.json")
        self.times: deque[float] = deque(maxlen=cfg.straggler_window)
        self.straggler_events: list[dict] = []
        self.failures = 0

    # ------------------------------------------------------------------
    def resume_or_init(self, init_fn: Callable[[], Any], like: Any | None = None):
        """Restore the newest valid checkpoint, else initialize fresh."""
        if self.ckpt.latest_step() is not None:
            like = like if like is not None else init_fn()
            step, state, extra = self.ckpt.restore(like)
            return state, step, extra
        return init_fn(), 0, {}

    # ------------------------------------------------------------------
    def heartbeat(self, step: int, metrics: dict | None = None) -> None:
        tmp = self.hb_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"step": int(step), "time": time.time(),
                       "host": jax.process_index(),
                       "metrics": {k: float(v) for k, v in (metrics or {}).items()}}, f)
        os.replace(tmp, self.hb_path)

    def check_straggler(self, dt: float) -> bool:
        """True if this step is a straggler vs the trailing window."""
        if len(self.times) >= self.cfg.straggler_window // 2:
            mu = float(np.mean(self.times))
            sd = float(np.std(self.times)) + 1e-9
            if (dt - mu) / sd > self.cfg.straggler_zscore and dt > 1.5 * mu:
                self.straggler_events.append(
                    {"dt": dt, "mean": mu, "std": sd, "time": time.time()})
                return True
        self.times.append(dt)
        return False

    # ------------------------------------------------------------------
    def run(self, state, step_fn: Callable, data_iter, steps: int,
            start_step: int = 0, loader_state_fn=None,
            on_metrics: Callable | None = None):
        """The supervised loop: step -> heartbeat -> (ckpt) -> straggler
        check. Exceptions restore the last checkpoint (up to max_failures)."""
        step = start_step
        while step < steps:
            batch = next(data_iter)
            t0 = time.time()
            try:
                state, metrics = step_fn(state, batch)
                jax.block_until_ready(metrics["loss"])
            except Exception:  # noqa: BLE001 — node-failure path
                self.failures += 1
                self.ckpt.wait()   # an in-flight async save may be the newest
                                   # restore point — land it before deciding
                if self.failures > self.cfg.max_failures or self.ckpt.latest_step() is None:
                    raise
                step, state, extra = self.ckpt.restore(state)
                if loader_state_fn:
                    data_iter.restore(extra.get("loader_step", step))
                continue
            dt = time.time() - t0
            step += 1
            self.check_straggler(dt)
            if step % 10 == 0 or step == steps:
                self.heartbeat(step, metrics)
            if on_metrics:
                on_metrics(step, {k: float(v) for k, v in metrics.items()})
            if step % self.cfg.ckpt_every == 0 or step == steps:
                extra = {"loader_step": (loader_state_fn() if loader_state_fn
                                         else step)}
                self.ckpt.save(step, state, extra)
        self.ckpt.wait()
        return state, step


# ---------------------------------------------------------------------------
# Elastic re-mesh
# ---------------------------------------------------------------------------

def remesh_state(state, cfg, old_mesh, spec_fn) -> tuple[Any, Any]:
    """Rebuild the mesh from the LIVE device count and re-shard `state`.

    `spec_fn(state, cfg, mesh)` re-derives the PartitionSpec tree — rules
    are axis-NAME based, so any new (data, model) factorization works.
    Returns (new_state, new_mesh)."""
    from ..launch.mesh import make_host_mesh
    model = old_mesh.shape.get("model", 1)
    n = len(jax.devices())
    while model > 1 and (n % model or model > n):
        model //= 2
    new_mesh = make_host_mesh(model=model)
    from ..distributed.sharding import to_shardings
    shardings = to_shardings(spec_fn(state, cfg, new_mesh), new_mesh)
    new_state = jax.device_put(state, shardings)
    return new_state, new_mesh
