"""Fault-tolerance supervisor (DESIGN.md §5).

Wraps a step loop with:
  * periodic checkpointing (async, atomic) + auto-resume,
  * heartbeat file (external watchdogs / co-hosts read it),
  * straggler detection — step-time z-score over a trailing window; on a
    real multi-host job the same detector runs on the per-host heartbeat
    matrix and the slowest host is evicted / re-sharded around,
  * elastic re-mesh — on device-count change (simulated or real restart),
    the mesh is rebuilt from the live device count and the state is
    re-sharded via device_put with re-derived NamedShardings.

The supervisor is deliberately host-side, framework-agnostic code: the
same loop drives the CPU demo here and a real TPU slice (jax.distributed
initializes per-host; the heartbeat file becomes a shared-store key).
"""
from __future__ import annotations

import dataclasses
import json
import logging
import os
import time
from collections import deque
from typing import Any, Callable

import jax
import numpy as np

from ..checkpoint import CheckpointManager
from . import faults as ft_faults
from .faults import DeviceLoss, PoisonBatch

_log = logging.getLogger("repro.ft")


@dataclasses.dataclass
class FTConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep_last: int = 3
    heartbeat_path: str = ""           # default: <ckpt_dir>/heartbeat.json
    straggler_window: int = 20
    straggler_zscore: float = 4.0
    max_failures: int = 3
    failure_decay_steps: int = 25      # consecutive successes that forgive
                                       # one recorded failure — a long job
                                       # with rare transient faults never
                                       # exhausts max_failures
    backoff_base_s: float = 0.05       # restore backoff: base * 2**(k-1),
    backoff_cap_s: float = 2.0         # capped, +- jitter
    backoff_jitter: float = 0.25       # fraction of the delay randomized
    jitter_seed: int = 0               # per-supervisor jitter stream —
                                       # concurrent supervisors (train +
                                       # serve) must not share one and
                                       # re-stampede in lockstep
    max_poison_skips: int = 3          # consecutive poison batches before
                                       # the job is declared sick (re-raise)


class FailurePolicy:
    """The classify -> log -> count -> backoff -> decay core shared by the
    train-loop :class:`StepSupervisor` and the serve engine's supervised
    tick loop (``serve.engine.ServeEngine.run`` with an ``FTConfig``).

    One instance = one failure budget: ``count()`` charges a recorded
    failure against ``cfg.max_failures`` and says whether the budget
    still holds; ``note_success()`` decays it (one failure forgiven per
    ``failure_decay_steps`` consecutive successes). Classes whose policy
    is in ``faults.SHED_POLICIES`` (``DeadlineExceeded``/``Overload``)
    are *logged but never counted* — load shedding is the system working
    as designed, and a storm of shed requests must not exhaust the
    budget that exists to catch crash loops. Backoff delays stay within
    ``backoff_cap_s * (1 + backoff_jitter)`` for any ``jitter_seed``."""

    def __init__(self, cfg: FTConfig):
        self.cfg = cfg
        self.failures = 0
        self.failure_log: list[dict] = []
        self._streak = 0
        self._rng = np.random.default_rng(cfg.jitter_seed)

    def record(self, cls: type, step: int, exc: BaseException) -> str:
        """Append one classified failure to the log; returns its policy
        name (``"shed"`` entries are the caller's cue to skip
        :meth:`count` entirely)."""
        policy = ft_faults.POLICIES[cls]
        self.failure_log.append(
            {"step": step, "class": cls.__name__, "policy": policy,
             "error": f"{type(exc).__name__}: {exc}", "time": time.time()})
        return policy

    def count(self) -> bool:
        """Charge one failure against the budget; False = exhausted."""
        self.failures += 1
        self._streak = 0
        return self.failures <= self.cfg.max_failures

    def note_success(self) -> None:
        self._streak += 1
        if self.failures > 0 and self._streak >= self.cfg.failure_decay_steps:
            self.failures -= 1
            self._streak = 0

    def backoff(self) -> float:
        """Exponential backoff with jitter for the k-th restore since the
        last forgiven failure — herd restarts after a shared-infra blip
        must not re-stampede the same resource in lockstep."""
        k = max(self.failures, 1)
        base = min(self.cfg.backoff_base_s * (2.0 ** (k - 1)),
                   self.cfg.backoff_cap_s)
        jit = 1.0 + self.cfg.backoff_jitter * (2.0 * self._rng.random() - 1.0)
        return max(base * jit, 0.0)


class StepSupervisor:
    def __init__(self, cfg: FTConfig):
        self.cfg = cfg
        self.ckpt = CheckpointManager(cfg.ckpt_dir, cfg.keep_last)
        self.hb_path = cfg.heartbeat_path or os.path.join(cfg.ckpt_dir, "heartbeat.json")
        self.times: deque[float] = deque(maxlen=cfg.straggler_window)
        self.straggler_events: list[dict] = []
        self.policy = FailurePolicy(cfg)   # classify/backoff/decay core,
                                           # shared with the serve loop
        self.skipped_batches: list[dict] = []

    # failure-budget state lives on the shared FailurePolicy; these
    # properties keep the supervisor's public surface (tests, callers)
    @property
    def failures(self) -> int:
        return self.policy.failures

    @failures.setter
    def failures(self, v: int) -> None:
        self.policy.failures = v

    @property
    def failure_log(self) -> list[dict]:
        return self.policy.failure_log

    # ------------------------------------------------------------------
    def resume_or_init(self, init_fn: Callable[[], Any], like: Any | None = None):
        """Restore the newest valid checkpoint, else initialize fresh."""
        if self.ckpt.latest_step() is not None:
            like = like if like is not None else init_fn()
            step, state, extra = self.ckpt.restore(like)
            return state, step, extra
        return init_fn(), 0, {}

    # ------------------------------------------------------------------
    def heartbeat(self, step: int, metrics: dict | None = None) -> None:
        tmp = self.hb_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"step": int(step), "time": time.time(),
                       "host": jax.process_index(),
                       "metrics": {k: float(v) for k, v in (metrics or {}).items()}}, f)
        os.replace(tmp, self.hb_path)

    def check_straggler(self, dt: float) -> bool:
        """True if this step is a straggler vs the trailing window.

        The straggler's dt still enters the window: excluding it meant a
        genuine sustained slowdown (new neighbor, thermal throttle) was
        compared against the stale fast window forever — every step
        flagged, the detector never re-baselined."""
        flagged = False
        if len(self.times) >= self.cfg.straggler_window // 2:
            mu = float(np.mean(self.times))
            sd = float(np.std(self.times)) + 1e-9
            if (dt - mu) / sd > self.cfg.straggler_zscore and dt > 1.5 * mu:
                self.straggler_events.append(
                    {"dt": dt, "mean": mu, "std": sd, "time": time.time()})
                flagged = True
        self.times.append(dt)
        return flagged

    # ------------------------------------------------------------------
    def _backoff(self) -> float:
        return self.policy.backoff()

    def run(self, state, step_fn: Callable, data_iter, steps: int,
            start_step: int = 0, loader_state_fn=None,
            on_metrics: Callable | None = None,
            on_device_loss: Callable | None = None):
        """The supervised loop: step -> heartbeat -> (ckpt) -> straggler
        check. Failures route through the ``ft.faults`` taxonomy:

        * unclassified exceptions (typos, ``KeyboardInterrupt``) re-raise
          immediately — they are bugs, not faults;
        * ``PoisonBatch`` (incl. an in-band non-finite loss) skips the
          batch with a log entry and KEEPS the state — a restore would
          replay the same batch into the same failure;
        * ``DeviceLoss`` calls ``on_device_loss(state) -> state`` (the
          caller's remesh hook) and retries the same step, else re-raises;
        * everything else (``TransientStep``/``CorruptStream``) restores
          the newest verified checkpoint after an exponential backoff
          with jitter, up to ``max_failures``.

        ``failures`` decays by one per ``failure_decay_steps`` consecutive
        successes, so a week-long job with an occasional blip never
        exhausts the budget that exists to catch crash loops."""
        step = start_step
        poison_run = 0
        while step < steps:
            batch = next(data_iter)
            t0 = time.time()
            try:
                new_state, metrics = step_fn(state, batch)
                jax.block_until_ready(metrics["loss"])
                loss = float(np.asarray(metrics["loss"]))
                if not np.isfinite(loss):
                    raise PoisonBatch(f"non-finite loss {loss} at step {step}")
                state = new_state  # commit only on a finite loss — a poison
                                   # batch's update is discarded, not kept
            except Exception as e:  # noqa: BLE001 — classified below
                cls = ft_faults.classify(e)
                if cls is None:
                    raise              # a bug, not a fault
                pol = self.policy.record(cls, step, e)
                if pol in ft_faults.SHED_POLICIES:
                    step += 1          # shed: logged, never counted — the
                    continue           # work unit is dropped by design
                if cls is PoisonBatch:
                    poison_run += 1
                    self.skipped_batches.append(
                        {"step": step, "error": str(e)})
                    _log.warning("poison batch at step %d skipped (%s) — "
                                 "state kept, %d/%d consecutive",
                                 step, e, poison_run,
                                 self.cfg.max_poison_skips)
                    if poison_run > self.cfg.max_poison_skips:
                        raise          # every batch is poison: data is sick
                    step += 1          # the batch is consumed; the step is
                    continue           # a logged no-op, not a retry loop
                if cls is DeviceLoss and on_device_loss is not None:
                    _log.warning("device loss at step %d: re-meshing (%s)",
                                 step, e)
                    state = on_device_loss(state)
                    self.policy._streak = 0
                    continue           # retry the step on the new mesh
                within_budget = self.policy.count()
                self.ckpt.wait()   # an in-flight async save may be the newest
                                   # restore point — land it before deciding
                if not within_budget or self.ckpt.latest_step() is None:
                    raise
                delay = self._backoff()
                _log.warning("%s at step %d (%s): restoring after %.2fs "
                             "(failure %d/%d)", cls.__name__, step, e, delay,
                             self.failures, self.cfg.max_failures)
                if delay:
                    time.sleep(delay)
                step, state, extra = self.ckpt.restore(state)
                if loader_state_fn:
                    data_iter.restore(extra.get("loader_step", step))
                continue
            dt = time.time() - t0
            step += 1
            poison_run = 0
            self.policy.note_success()
            self.check_straggler(dt)
            if step % 10 == 0 or step == steps:
                self.heartbeat(step, metrics)
            if on_metrics:
                on_metrics(step, {k: float(v) for k, v in metrics.items()})
            if step % self.cfg.ckpt_every == 0 or step == steps:
                extra = {"loader_step": (loader_state_fn() if loader_state_fn
                                         else step)}
                self.ckpt.save(step, state, extra)
        self.ckpt.wait()
        return state, step


# ---------------------------------------------------------------------------
# Elastic re-mesh
# ---------------------------------------------------------------------------

def remesh_state(state, cfg, old_mesh, spec_fn) -> tuple[Any, Any]:
    """Rebuild the mesh from the LIVE device count and re-shard `state`.

    `spec_fn(state, cfg, mesh)` re-derives the PartitionSpec tree — rules
    are axis-NAME based, so any new (data, model) factorization works.
    Returns (new_state, new_mesh)."""
    from ..launch.mesh import make_host_mesh
    model = old_mesh.shape.get("model", 1)
    n = len(jax.devices())
    while model > 1 and (n % model or model > n):
        model //= 2
    new_mesh = make_host_mesh(model=model)
    from ..distributed.sharding import to_shardings
    shardings = to_shardings(spec_fn(state, cfg, new_mesh), new_mesh)
    new_state = jax.device_put(state, shardings)
    return new_state, new_mesh
