"""Per-boundary circuit breaker over the compressed-stream ingest paths.

PR 8 gave every ingest boundary per-item recovery: a corrupt page (or
handoff leaf, or ring hop) degrades to ITS dense source and everything
else stays compressed. That is the right call for a blip — but a
*persistently* sick boundary (flaky link, bad DMA engine) re-pays
compress + validate + fallback on every single item forever. The
breaker is the aggregate policy on top: after ``trip_after`` classified
``CorruptStream`` detections inside a sliding ``window`` of ticks at
one site, the whole site trips to its dense path *wholesale* — no
compression, no per-item validation, no fallback machinery — then
probes the compressed path again on a decayed (exponential-backoff)
schedule and closes once ``close_after`` consecutive probes pass.

State machine (per site)::

    closed ──(trip_after failures in window)──▶ open
    open ──(next_probe reached; one item allowed)──▶ half_open
    half_open ──(probe fails)──▶ open   (probe interval *= probe_backoff)
    half_open ──(close_after consecutive passes)──▶ closed

The clock is the caller's *tick* counter (engine ticks in serve, call
counts elsewhere), not wall time — chaos runs stay deterministic.

Wiring: the serve engine owns a :class:`BreakerBoard` (one breaker per
site label, shared clock) and threads it into its
:class:`~repro.serve.pool.PagedKVPool`; boundaries without an engine in
scope (``launch.serve.validate_state_ingest``, the collectives'
``resolve_comms``) consult the ambient board armed with
:func:`breaker_scope`, mirroring ``ft.inject``'s contextvar idiom.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from collections import deque
from typing import Iterator

CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"


@dataclasses.dataclass
class BreakerConfig:
    trip_after: int = 3        # failures inside `window` ticks that trip
    window: int = 16           # sliding detection window, in ticks
    probe_after: int = 4       # ticks from trip to the first half-open probe
    probe_backoff: float = 2.0 # failed probe multiplies the next wait ...
    probe_cap: int = 64        # ... up to this many ticks between probes
    close_after: int = 2       # consecutive probe passes that close


class CircuitBreaker:
    """One boundary's breaker. All methods take the caller's ``now``
    tick; the breaker never reads a clock of its own."""

    def __init__(self, site: str, cfg: BreakerConfig | None = None):
        self.site = site
        self.cfg = cfg or BreakerConfig()
        self.state = CLOSED
        self._fail_ticks: deque[int] = deque()
        self._probe_wait = float(self.cfg.probe_after)
        self._next_probe = 0
        self._passes = 0           # consecutive half-open probe passes
        # counters (monotone; surfaced in snapshot()/label())
        self.trips = 0             # closed -> open transitions
        self.probes = 0            # half-open items with a recorded verdict
        self.probe_passes = 0
        self.probe_fails = 0
        self.skipped = 0           # items sent dense while open
        self.failures_seen = 0     # every recorded failure, any state

    # ------------------------------------------------------------------
    def allow(self, now: int) -> bool:
        """May this item take the compressed path at tick ``now``?
        ``False`` = the site is open: take the dense path wholesale,
        skipping per-item validate + fallback. The first item at or past
        the probe deadline is the half-open probe and IS allowed."""
        if self.state == OPEN:
            if now >= self._next_probe:
                self.state = HALF_OPEN
                return True
            self.skipped += 1
            return False
        return True                # closed or half_open (probing)

    def record_success(self, now: int) -> None:
        if self.state == HALF_OPEN:
            self.probes += 1
            self.probe_passes += 1
            self._passes += 1
            if self._passes >= self.cfg.close_after:
                self.state = CLOSED
                self._fail_ticks.clear()
                self._probe_wait = float(self.cfg.probe_after)
        # closed: nothing to do — old failures age out by tick, below

    def record_failure(self, now: int) -> None:
        self.failures_seen += 1
        if self.state == HALF_OPEN:
            # failed probe: back to open on the decayed schedule
            self.probes += 1
            self.probe_fails += 1
            self._passes = 0
            self._probe_wait = min(self._probe_wait * self.cfg.probe_backoff,
                                   float(self.cfg.probe_cap))
            self._next_probe = now + int(self._probe_wait)
            self.state = OPEN
            return
        if self.state == OPEN:     # racing items in the same tick
            return
        self._fail_ticks.append(now)
        while self._fail_ticks and now - self._fail_ticks[0] > self.cfg.window:
            self._fail_ticks.popleft()
        if len(self._fail_ticks) >= self.cfg.trip_after:
            self.state = OPEN
            self.trips += 1
            self._passes = 0
            self._probe_wait = float(self.cfg.probe_after)
            self._next_probe = now + int(self._probe_wait)
            self._fail_ticks.clear()

    # ------------------------------------------------------------------
    def snapshot(self) -> dict:
        return {"site": self.site, "state": self.state, "trips": self.trips,
                "probes": self.probes, "probe_passes": self.probe_passes,
                "probe_fails": self.probe_fails, "skipped": self.skipped,
                "failures_seen": self.failures_seen}

    def label(self) -> str:
        """SiteAux-style compact label, e.g. ``page:open(trips=1,probes=2)``."""
        return (f"{self.site}:{self.state}(trips={self.trips},"
                f"probes={self.probes},skipped={self.skipped})")


class BreakerBoard:
    """Per-site breakers behind one shared tick clock.

    The owner advances the clock (``advance(tick)`` in the serve engine,
    ``tick()`` at call-counted boundaries); every consult then reads
    ``now``. Sites materialize lazily on first consult, so wiring a
    board in is free for boundaries that never fail."""

    def __init__(self, cfg: BreakerConfig | None = None):
        self.cfg = cfg or BreakerConfig()
        self.now = 0
        self.breakers: dict[str, CircuitBreaker] = {}

    def get(self, site: str) -> CircuitBreaker:
        br = self.breakers.get(site)
        if br is None:
            br = self.breakers[site] = CircuitBreaker(site, self.cfg)
        return br

    # -- clock ----------------------------------------------------------
    def advance(self, now: int) -> None:
        self.now = max(self.now, int(now))

    def tick(self) -> None:
        self.now += 1

    # -- consults -------------------------------------------------------
    def allow(self, site: str) -> bool:
        return self.get(site).allow(self.now)

    def record_success(self, site: str) -> None:
        self.get(site).record_success(self.now)

    def record_failure(self, site: str) -> None:
        self.get(site).record_failure(self.now)

    # -- reporting ------------------------------------------------------
    def snapshot(self) -> dict[str, dict]:
        return {s: b.snapshot() for s, b in sorted(self.breakers.items())}

    def labels(self) -> list[str]:
        return [b.label() for _, b in sorted(self.breakers.items())]

    def tripped_sites(self) -> list[str]:
        return sorted(s for s, b in self.breakers.items() if b.trips > 0)

    @property
    def trips(self) -> int:
        return sum(b.trips for b in self.breakers.values())

    @property
    def probes(self) -> int:
        return sum(b.probes for b in self.breakers.values())


_ACTIVE_BOARD: contextvars.ContextVar[BreakerBoard | None] = \
    contextvars.ContextVar("repro_breaker_board", default=None)


def active_board() -> BreakerBoard | None:
    return _ACTIVE_BOARD.get()


@contextlib.contextmanager
def breaker_scope(board: BreakerBoard) -> Iterator[BreakerBoard]:
    """Arm a board for boundaries that have no engine in scope (the
    collectives' ``resolve_comms``, ``validate_state_ingest``)."""
    tok = _ACTIVE_BOARD.set(board)
    try:
        yield board
    finally:
        _ACTIVE_BOARD.reset(tok)
