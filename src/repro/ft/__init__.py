from .supervisor import FTConfig, StepSupervisor, remesh_state  # noqa: F401
from .faults import (  # noqa: F401
    CorruptStream,
    DeviceLoss,
    FaultError,
    PoisonBatch,
    TransientStep,
    classify,
    policy_for,
)
from .inject import (  # noqa: F401
    Fault,
    FaultPlan,
    active_plan,
    corrupt_file,
    corrupt_map,
    crashing_step,
    inject,
    ring_hop_tap,
    stream_tap,
)
