from .supervisor import FTConfig, StepSupervisor, remesh_state  # noqa: F401
