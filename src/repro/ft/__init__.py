from .supervisor import (  # noqa: F401
    FailurePolicy,
    FTConfig,
    StepSupervisor,
    remesh_state,
)
from .faults import (  # noqa: F401
    CorruptStream,
    DeadlineExceeded,
    DeviceLoss,
    FaultError,
    Overload,
    PoisonBatch,
    TransientStep,
    classify,
    policy_for,
)
from .breaker import (  # noqa: F401
    BreakerBoard,
    BreakerConfig,
    CircuitBreaker,
    active_board,
    breaker_scope,
)
from .inject import (  # noqa: F401
    Fault,
    FaultPlan,
    active_plan,
    corrupt_file,
    corrupt_map,
    crash_tap,
    crashing_step,
    inject,
    ring_hop_tap,
    stream_tap,
)
