from . import sharding  # noqa: F401
from .ctx import sharding_hints, hint, dp_axes  # noqa: F401
