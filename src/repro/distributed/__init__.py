from . import sharding  # noqa: F401
from .ctx import (comm_axis, comm_context, dp_axes, hint,  # noqa: F401
                  sharding_hints)

# collectives is imported lazily by its users (models/lm, benchmarks) to
# keep `import repro.distributed` free of core.engine — the package init
# must stay cheap for the XLA_FLAGS-ordering-sensitive launchers.
