"""Shard-aware compressed collectives: move the Zebra (bitmap, payload)
stream across mesh axes instead of dense tensors.

The paper's argument is about a bandwidth wall, not about DRAM
specifically — the blocks that are zero in HBM are zero on the wire, so
at multi-device scale the interconnect (ICI/DCN) is the same boundary
Eq. 2/3 attacks. Every collective here follows one wire protocol:

1. **Index exchange** — one ``lax.all_gather`` of the tiny ``(nm, nk)``
   keep bitmaps (the Eq. 3 term: 1 bit/block on the physical wire; the
   host-mesh realization moves int8 flags, the accounting charges the
   packed form every other transport in the repo charges).
2. **Payload exchange** — ``n - 1`` ring hops of ``lax.ppermute`` over
   the payload buffer. Per hop, each inbound link carries ONE shard's
   compressed stream; over the full ring every device's link carries
   every other shard's stream exactly once.
3. **Reconstruction** — each arriving shard's dense map is rebuilt from
   ITS bitmap via the consumer-order slot map (``kernels/schedule.py``'s
   prefix-sum pass — the same ONE slot map the Pallas kernels
   scalar-prefetch), so the gather is bitwise-equal to ``lax.all_gather``
   of the dense masked map.

Accounting follows the repo's HBM precedent (``CompressedMap``): the
*physically moved* buffer is worst-case sized (ring hops need static
shapes), but the *accounted* bytes are the live stream — payload slots
that would cross a real link plus the packed index — via the same
``core.engine.stream_bytes`` rule every compressed backend uses, so HBM
and ICI byte models cannot drift apart. ``LinkBytes`` carries the pair
(moved, dense-equivalent) per inbound link; ``compress/meter.py``'s
``record_link`` reconciles it against Eq. 2/3 exactly.

Degrade contract mirrors ``core.engine``: a layer exchange runs
compressed only when the site's backend declares the ``comms``
capability (``core.backends``) AND the axis/shape situation supports it
(:func:`resolve_comms`); otherwise it falls back to a dense
``lax.all_gather`` with the reason logged once and surfaced on the
``SiteAux`` backend label — never a silent rewrite.

Everything here must run inside ``shard_map`` over a mesh with the
target axis; :func:`shard_map_compat` papers over the jax version drift
(``jax.shard_map``/``check_vma`` vs ``jax.experimental.shard_map``/
``check_rep``). Model code never calls these directly — it goes through
``distributed.ctx.comm_context`` + the layer hooks in ``models/lm``.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from ..compress.stream import nonzero_bitmap
from ..core.engine import MB_BASE, SiteAux, stream_bytes
from ..kernels.ref import zebra_unpack_ref
from ..kernels.schedule import slot_map
from .ctx import comm_axis

_log = logging.getLogger("repro.collectives")
_DEGRADE_LOGGED: set[tuple[str, str, str]] = set()

RING_SITE = "ring"   # ft.breaker site label for the collectives hop boundary


# ---------------------------------------------------------------------------
# shard_map / axis-size compat
# ---------------------------------------------------------------------------

def shard_map_compat(f, mesh, in_specs, out_specs):
    """``jax.shard_map`` across jax versions: the public alias (with the
    ``check_vma`` rename) landed after the 0.4.x line; fall back to
    ``jax.experimental.shard_map.shard_map(check_rep=False)``. Replica
    checking stays off either way — the collectives here use
    ``lax.axis_index``, which is per-shard by construction."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        try:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=False)
        except TypeError:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def axis_size(axis) -> int:
    """Static shard count of a mesh axis inside shard_map (``lax.psum``
    of a Python scalar constant-folds to a Python int at trace time)."""
    return int(lax.psum(1, axis))


# ---------------------------------------------------------------------------
# Per-link byte accounting
# ---------------------------------------------------------------------------

class LinkBytes(NamedTuple):
    """Bytes ONE inbound link of this device carried for one collective.

    ``moved``  what actually crossed: compressed stream bytes on the
               compressed path, the dense size on a degraded exchange.
    ``dense``  dense-equivalent bytes the same exchange would move with
               ``lax.all_gather``/psum of the uncompressed map.
    Both int32 (per-exchange counts; cross-layer accumulation rides the
    exact ``LayerAux`` (hi, lo) pair)."""
    moved: jax.Array
    dense: jax.Array


def zero_link() -> LinkBytes:
    return LinkBytes(jnp.int32(0), jnp.int32(0))


def add_links(a: LinkBytes, b: LinkBytes) -> LinkBytes:
    return LinkBytes(a.moved + b.moved, a.dense + b.dense)


def attach_link(aux: SiteAux, link: LinkBytes, *,
                reason: str | None = None) -> SiteAux:
    """Fold one exchange's per-link bytes into a ``SiteAux``. A degraded
    (dense) exchange surfaces its reason on the backend label —
    ``"<backend>+dense-comms(<reason>)"`` — following the engine's
    ``reference(<reason>)`` convention."""
    label = (aux.backend if reason is None
             else f"{aux.backend}+dense-comms({reason})")
    return dataclasses.replace(
        aux,
        ici_bytes=jnp.asarray(aux.ici_bytes).astype(jnp.int32) + link.moved,
        ici_dense_bytes=(jnp.asarray(aux.ici_dense_bytes).astype(jnp.int32)
                         + link.dense),
        backend=label)


def dense_link(nbytes_per_shard, n: int) -> LinkBytes:
    """The LinkBytes of a degraded (dense) all-gather: every inbound link
    carries the other ``n - 1`` shards' dense maps."""
    b = jnp.int32((n - 1) * int(nbytes_per_shard))
    return LinkBytes(b, b)


# ---------------------------------------------------------------------------
# Payload pack (the jnp realization of the consumer-order contract)
# ---------------------------------------------------------------------------

def _pack_consumer_order(x2: jax.Array, bitmap: jax.Array, bs: int, bc: int
                         ) -> tuple[jax.Array, jax.Array]:
    """(M, K) map + (nm, nk) bitmap -> worst-case (nb, bs, bc) payload in
    the repo-wide consumer slot order, plus n_live. Slots come from the
    SAME ``kernels.schedule.slot_map`` prefix-sum pass the Pallas kernels
    scalar-prefetch; a dead block's slot aliases the next live slot of
    its column, so dead blocks must scatter with ``mode="drop"`` (a
    plain set would overwrite live data)."""
    M, K = x2.shape
    nm, nk = M // bs, K // bc
    nb = nm * nk
    keep, slot = slot_map(bitmap)
    blocks = (x2.reshape(nm, bs, nk, bc).transpose(0, 2, 1, 3)
              .reshape(nb, bs, bc))
    tgt = jnp.where(keep != 0, slot, jnp.int32(nb))      # dead -> dropped
    payload = jnp.zeros((nb, bs, bc), x2.dtype).at[tgt].set(
        blocks, mode="drop")
    return payload, jnp.sum(keep).astype(jnp.int32)


# ---------------------------------------------------------------------------
# zebra_all_gather — the compressed TP activation exchange
# ---------------------------------------------------------------------------

def zebra_all_gather(x2: jax.Array, axis, *, bs: int, bc: int,
                     bitmap: jax.Array | None = None, tiled: bool = False,
                     validation: str = "off", live_nonzero: bool = True,
                     site: str = "all_gather"
                     ) -> tuple[jax.Array, LinkBytes]:
    """All-gather a block-sparse (M, K) shard in Zebra stream form.

    Wire protocol: ONE ``lax.all_gather`` of the (nm, nk) bitmaps (the
    index exchange), then ``n - 1`` ring ``ppermute`` hops of the
    consumer-order payload; each arriving shard's dense map is rebuilt
    from its own bitmap's slot map. Bitwise-equal to ``lax.all_gather``
    of the dense map whenever each shard's dead blocks (per its bitmap)
    are exact zeros — always true for the default ``nonzero_bitmap``
    and for any Zebra-masked map under its keep bitmap.

    ``validation`` (a ``compress.integrity`` level) checks every
    arriving hop's stream against its own gathered bitmap (+ its
    producer checksum at the ``checksum`` level) before trusting it. A
    failed hop anywhere on the ring makes EVERY device — the ok flags
    are made uniform with a psum first, collectives inside ``lax.cond``
    require one branch ring-wide — retry the whole exchange as a dense
    ``lax.all_gather`` of the shard still in hand (``ft.faults`` policy
    "recompute-dense" + dense-comms retry), firing
    ``integrity.note_failure`` once per device. The retry traffic is
    accounted on top of the wasted compressed attempt.

    Returns ``(gathered, LinkBytes)``: ``(n, M, K)`` stacked like
    ``lax.all_gather`` (or ``(n*M, K)`` with ``tiled=True``), plus the
    per-inbound-link accounting — over the ring each link carries every
    other shard's stream exactly once::

        moved = sum_{s != self} n_live_s * bs * bc * itemsize
                                + ceil(nm * nk / 8)
        dense = (n - 1) * M * K * itemsize
    """
    from ..compress import integrity
    from ..ft.inject import ring_hop_tap

    M, K = x2.shape
    if M % bs or K % bc:
        raise ValueError(f"zebra_all_gather: shard ({M}, {K}) not divisible "
                         f"by blocks ({bs}, {bc}) — resolve_comms should "
                         f"have degraded this exchange to dense")
    nm, nk = M // bs, K // bc
    if bitmap is None:
        bitmap = nonzero_bitmap(x2, bs, bc)
    n = axis_size(axis)
    item = jnp.dtype(x2.dtype).itemsize
    if n == 1:
        return (x2 if tiled else x2[None]), zero_link()
    tag = f"ring:{site}"

    payload, _ = _pack_consumer_order(x2, bitmap, bs, bc)
    bitmaps = lax.all_gather(bitmap, axis)               # (n, nm, nk)
    counts = bitmaps.astype(jnp.int32).sum(axis=(1, 2))  # per-shard n_live
    csums = None
    if validation == "checksum":
        my_csum = integrity.stream_checksum(payload, bitmap,
                                            counts[lax.axis_index(axis)])
        csums = lax.all_gather(my_csum, axis)            # (n,)
    idx = lax.axis_index(axis)
    perm = [(i, (i + 1) % n) for i in range(n)]

    def hop(carry, h):
        # after hop h (1-based), this device holds shard (idx - h) % n
        pl, ok = carry
        pl = lax.ppermute(pl, axis, perm)
        pl = ring_hop_tap(pl, h, site=tag)
        src = (idx - h) % n
        if validation != "off":
            ok = ok & integrity.check_stream(
                pl, bitmaps[src], counts[src], level=validation,
                checksum=None if csums is None else csums[src],
                live_nonzero=live_nonzero)
        return (pl, ok), (zebra_unpack_ref(pl, bitmaps[src], bs, bc), src)

    (_, ok), (shards, srcs) = lax.scan(hop, (payload, jnp.bool_(True)),
                                       jnp.arange(1, n))
    out = jnp.zeros((n, M, K), x2.dtype).at[idx].set(x2)
    out = out.at[srcs].set(shards)

    streams = stream_bytes(counts, bs, bc, x2.dtype, nm * nk)
    moved = (jnp.sum(streams) - streams[idx]).astype(jnp.int32)
    dense = jnp.int32((n - 1) * M * K * item)
    if validation != "off":
        # any corrupt hop anywhere -> the whole ring retries dense
        ok_ring = lax.psum(ok.astype(jnp.int32), axis) == n

        def retry_dense():
            jax.debug.callback(lambda t=tag: integrity.note_failure(t))
            return lax.all_gather(x2, axis)

        out = lax.cond(ok_ring, lambda: out, retry_dense)
        moved = jnp.where(ok_ring, moved, moved + dense)
    return (out.reshape(n * M, K) if tiled else out), LinkBytes(moved, dense)


# ---------------------------------------------------------------------------
# zebra_psum_stream / zebra_reduce_scatter — payload-form reductions
# ---------------------------------------------------------------------------

def zebra_psum_stream(g2: jax.Array, axis, *, bs: int, bc: int,
                      bitmap: jax.Array | None = None,
                      validation: str = "off", site: str = "psum"
                      ) -> tuple[jax.Array, jax.Array, LinkBytes]:
    """psum of hard-masked maps (``g * bitmap`` — the activation-gradient
    form under the hard grad mode) that never densifies mid-flight.

    The index exchange gathers every shard's bitmap; their union sets
    the payload capacity. Each shard packs its map at the UNION layout
    (blocks dead in its own map contribute exact-zero slots), so the
    ``n - 1`` ring hops can add arriving payloads slot-for-slot — the
    reduction stays in payload form and is expanded ONCE at the end.
    Exact whenever each shard's off-bitmap blocks are exact zeros;
    floating-point summation order is the ring order (own shard first),
    which differs from ``lax.psum``'s tree — integer-valued data sums
    bitwise-equal, generic f32 agrees to normal accumulation-order
    tolerance.

    Returns ``(summed dense map, union bitmap, LinkBytes)`` with::

        moved = (n - 1) * (union_live * bs * bc * itemsize
                           + ceil(nm * nk / 8))
        dense = (n - 1) * M * K * itemsize

    (both sides modeled as the same gather-and-reduce ring: full
    buffers circulate, the reduction rides the ring in stream form).

    ``validation`` checks each ARRIVING payload (at hop h the traveling
    buffer is one shard's original union-capacity stream) before it is
    added: finiteness at ``structural``; + the producer's gathered
    checksum at ``checksum`` level — which is the level that sees a
    dropped hop here, since a zeroed union-capacity payload is
    structurally legal (slots live in the union may be zero locally,
    the ``live_nonzero`` invariant does not apply). On any failure the
    whole ring retries as a dense ``lax.psum``."""
    from ..compress import integrity
    from ..ft.inject import ring_hop_tap

    M, K = g2.shape
    if M % bs or K % bc:
        raise ValueError(f"zebra_psum_stream: shard ({M}, {K}) not "
                         f"divisible by blocks ({bs}, {bc})")
    nm, nk = M // bs, K // bc
    if bitmap is None:
        bitmap = nonzero_bitmap(g2, bs, bc)
    n = axis_size(axis)
    item = jnp.dtype(g2.dtype).itemsize
    if n == 1:
        return g2, bitmap.astype(jnp.int8), zero_link()
    tag = f"ring:{site}"

    bitmaps = lax.all_gather(bitmap, axis)               # (n, nm, nk)
    union = (bitmaps.astype(jnp.int32).sum(axis=0) > 0).astype(jnp.int8)
    payload, _ = _pack_consumer_order(g2, union, bs, bc)
    u_live = jnp.sum(union.astype(jnp.int32))
    idx = lax.axis_index(axis)
    csums = None
    if validation == "checksum":
        csums = lax.all_gather(
            integrity.stream_checksum(payload, union, u_live), axis)
    perm = [(i, (i + 1) % n) for i in range(n)]

    def hop(carry, h):
        pl, acc, ok = carry
        pl = lax.ppermute(pl, axis, perm)
        pl = ring_hop_tap(pl, h, site=tag)
        if validation != "off":
            ok = ok & integrity.check_stream(
                pl, union, u_live, level=validation,
                checksum=None if csums is None else csums[(idx - h) % n],
                live_nonzero=False)
        return (pl, acc + pl, ok), None

    (_, acc, ok), _ = lax.scan(hop, (payload, payload, jnp.bool_(True)),
                               jnp.arange(1, n))
    y = zebra_unpack_ref(acc, union, bs, bc)

    moved = ((n - 1) * stream_bytes(u_live, bs, bc, g2.dtype, nm * nk)
             ).astype(jnp.int32)
    dense = jnp.int32((n - 1) * M * K * item)
    if validation != "off":
        ok_ring = lax.psum(ok.astype(jnp.int32), axis) == n

        def retry_dense():
            jax.debug.callback(lambda t=tag: integrity.note_failure(t))
            return lax.psum(g2, axis)

        y = lax.cond(ok_ring, lambda: y, retry_dense)
        moved = jnp.where(ok_ring, moved, moved + dense)
    return y, union, LinkBytes(moved, dense)


def zebra_reduce_scatter(g2: jax.Array, axis, *, bs: int, bc: int,
                         bitmap: jax.Array | None = None,
                         validation: str = "off",
                         site: str = "reduce_scatter"
                         ) -> tuple[jax.Array, LinkBytes]:
    """Reduce-scatter over block rows: psum in payload form, each device
    keeps its ``M // n`` row chunk (must be bs-aligned, so chunks never
    straddle blocks). Accounted as a ring reduce-scatter — each inbound
    link carries the traveling partial of every chunk except the home
    chunk, at union capacity restricted to that chunk's block rows::

        moved = sum_{c != home} (union_live_c * bs * bc * itemsize
                                 + ceil(nb_c / 8))
        dense = (n - 1) * (M // n) * K * itemsize
    """
    M, K = g2.shape
    n = axis_size(axis)
    if n == 1:
        return g2, zero_link()
    if M % (n * bs):
        raise ValueError(
            f"zebra_reduce_scatter: M={M} must divide into {n} bs-aligned "
            f"chunks (bs={bs}) — resolve_comms should have degraded")
    Ml = M // n
    y, union, _ = zebra_psum_stream(g2, axis, bs=bs, bc=bc, bitmap=bitmap,
                                    validation=validation, site=site)
    idx = lax.axis_index(axis)
    out = lax.dynamic_slice_in_dim(y, idx * Ml, Ml, axis=0)

    nm_l, nk = Ml // bs, K // bc
    chunk_counts = union.reshape(n, nm_l, nk).astype(jnp.int32).sum((1, 2))
    chunk_streams = stream_bytes(chunk_counts, bs, bc, g2.dtype, nm_l * nk)
    moved = (jnp.sum(chunk_streams) - chunk_streams[idx]).astype(jnp.int32)
    item = jnp.dtype(g2.dtype).itemsize
    dense = jnp.int32((n - 1) * Ml * K * item)
    return out, LinkBytes(moved, dense)


# ---------------------------------------------------------------------------
# psum_exact_bytes — the shared exact-byte reduction (ffn / MoE / meter)
# ---------------------------------------------------------------------------

def psum_exact_bytes(nbytes, axes) -> tuple[jax.Array, jax.Array]:
    """Exact cross-shard sum of per-shard int32 byte counts, returned as
    the engine's f32 ``(hi, lo)`` base-2**24 pair (``LayerAux`` form).

    The psum runs on int32 legs split at base 2**16: each leg's sum
    stays far from int32 overflow up to ~32k shards, keeping the
    accounting exact end-to-end — an f32 psum would round as soon as
    the total crossed 16 MiB, an unsplit int32 psum overflows at ~128
    shards of 2 GiB maps. Recombination into the 2**24 pair happens in
    int32 (exact), then each leg casts to f32 (each < 2**24: exact).
    Extracted from the hand-rolled pair in ``models/lm/ffn.py`` so ffn,
    MoE and the per-link meter share ONE rule."""
    mb = jnp.asarray(nbytes).astype(jnp.int32)
    hi16 = lax.psum(mb // 65536, axes)
    lo16 = lax.psum(mb % 65536, axes)
    rem = (hi16 % 256) * 65536 + lo16
    hi = (hi16 // 256 + rem // MB_BASE).astype(jnp.float32)
    lo = (rem % MB_BASE).astype(jnp.float32)
    return hi, lo


# ---------------------------------------------------------------------------
# Capability resolution for layer exchanges
# ---------------------------------------------------------------------------

def resolve_comms(backend_name: str, *, rows: int, cols: int,
                  bs: int, bc: int) -> tuple[str | None, str | None]:
    """Decide how a layer exchange runs: ``("compressed", None)``,
    ``("dense", reason)``, or ``(None, None)`` when no comm context is
    active (no exchange at all — the single-process semantics every
    existing call site keeps).

    Mirrors the engine's ``_resolve_backend`` contract: the site's
    backend must declare the ``comms="compressed"`` capability
    (``core.backends``), the axis must actually be sharded, and the
    shard must tile into whole (bs, bc) blocks. Anything else degrades
    to a dense ``lax.all_gather`` with an explicit, logged reason."""
    info = comm_axis()
    if info is None:
        return None, None
    _, n = info
    from ..core.backends import backend_spec
    spec = backend_spec(backend_name)
    if spec.comms != "compressed":
        return "dense", "comms-capability"
    if n <= 1:
        return "dense", "single-device"
    if rows % bs or cols % bc:
        return "dense", "non-divisible"
    from ..ft.breaker import active_board
    board = active_board()
    if board is not None and not board.allow(RING_SITE):
        # per-boundary circuit breaker (ft.breaker): repeated classified
        # CorruptStream detections on the ring hop trip the whole
        # exchange to dense until a half-open probe passes
        return "dense", "breaker-open"
    return "compressed", None


def log_comm_degrade(site: str, backend: str, reason: str) -> None:
    key = (site, backend, reason)
    if key not in _DEGRADE_LOGGED:
        _DEGRADE_LOGGED.add(key)
        _log.info("compressed comms at %r: backend %r degraded to dense "
                  "all_gather (%s)", site, backend, reason)
