"""Sharding rules: param-tree path names -> PartitionSpec.

Conventions (DESIGN.md §5):
  * "data"  — batch + FSDP (ZeRO-3) parameter sharding
  * "model" — TP (attention heads, d_ff), EP (experts), vocab, KV-seq
  * "pod"   — pure DP only (cross-pod = one gradient all-reduce)

Rules are keyed on leaf *names* (with parent-context checks) and applied to
the trailing dims, so run-stacked leaves (leading superlayer axis) get a
None prepended automatically. Optimizer/compression state mirrors params
because the same rules fire on the mirrored subtrees.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.lm.config import LMConfig


def _kv_axis(cfg: LMConfig, mesh: Mesh):
    m = mesh.shape.get("model", 1)
    return "model" if (cfg.n_kv_heads and cfg.n_kv_heads % m == 0) else None


def _axis_ok(shape, template, mesh):
    """Drop axis names whose mesh size doesn't divide the dim."""
    out = []
    for dim, ax in zip(shape[-len(template):], template):
        if ax is None:
            out.append(None)
            continue
        size = int(np.prod([mesh.shape[a] for a in (ax if isinstance(ax, tuple) else (ax,))]))
        out.append(ax if dim % size == 0 else None)
    return tuple(out)


def spec_for(path_names: tuple[str, ...], shape: tuple[int, ...],
             cfg: LMConfig, mesh: Mesh) -> P:
    n = path_names
    name = n[-1]
    kv = _kv_axis(cfg, mesh)
    pure_dp = getattr(cfg, "sharding_profile", "tp") == "dp"

    def t(*template):
        if pure_dp:   # pure data parallel: no TP/EP — "model" carries batch
            template = tuple(None if a == "model" else a for a in template)
        template = _axis_ok(shape, template, mesh)
        return P(*((None,) * (len(shape) - len(template)) + template))

    # --- embeddings / head ---
    if name == "embed":
        return t("model", None)
    if name == "lm_head":
        return t(None, "model")
    # --- zebra threshold nets ---
    if "zebra_tnet" in n or "zebra_out_tnet" in n:
        return t("model", None) if name == "w" else t(None)
    # --- norms ---
    if name in ("scale", "bias") and len(n) >= 2 and n[-2] == "out_norm":
        return t("model")
    if name in ("scale", "bias"):
        return t(None)
    # --- attention ---
    if name == "wq":
        return t("data", "model", None)
    if name in ("wk", "wv"):
        return t("data", kv, None)
    if name == "wo":
        return t("model", None, "data")
    if name == "bq":
        return t("model", None)
    if name in ("bk", "bv"):
        return t(kv, None)
    # --- FFN dense vs MoE (by ndim: MoE weights carry a leading E) ---
    if name in ("w_gate", "w_up"):
        if "moe" in n:
            return t("model", "data", None)
        return t("data", "model")
    if name == "w_down":
        if "moe" in n:
            return t("model", None, "data")
        return t("model", "data")
    if name in ("b_up",):
        return t("model")
    if name in ("b_down",):
        return t(None)
    if name == "router":
        return t("data", None)
    # --- Mamba-2 ---
    if name in ("z_proj", "x_proj", "dt_proj"):
        return t("data", "model")
    if name in ("b_proj", "c_proj"):
        return t("data", None)
    if name == "conv_x":
        return t(None, "model")
    if name in ("conv_b", "conv_c"):
        return t(None, None)
    if name in ("A_log", "D", "dt_bias"):
        return t("model")
    if name == "out_proj":
        return t("model", "data")
    # --- RG-LRU ---
    if name in ("w_gate_branch", "w_rec_branch"):
        return t("data", "model")
    if name in ("w_a", "w_x"):
        return t(None, "model")
    if name in ("b_a", "b_x", "lam"):
        return t("model")
    if name == "w_out":
        return t("model", "data")
    if name == "conv_w":
        return t(None, "model")
    return P()   # replicate anything unknown


def _names(path) -> tuple[str, ...]:
    return tuple(str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p))))
                 for p in path)


def param_specs(tree, cfg: LMConfig, mesh: Mesh):
    """PartitionSpec pytree matching `tree` (params / grads / opt state)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: spec_for(_names(path), leaf.shape, cfg, mesh), tree)


def param_shardings(tree, cfg: LMConfig, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), param_specs(tree, cfg, mesh))


# ---------------------------------------------------------------------------
# Activation / batch / cache specs
# ---------------------------------------------------------------------------

def dp(mesh, cfg: LMConfig | None = None) -> tuple[str, ...]:
    axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    if cfg is not None and getattr(cfg, "sharding_profile", "tp") == "dp":
        axes = axes + ("model",)     # pure DP: batch over every axis
    return axes


def batch_spec(mesh, ndim: int, batch: int | None = None,
               cfg: LMConfig | None = None) -> P:
    """Shard dim0 (global batch) over the DP axes, replicate the rest.
    Axes that don't divide `batch` are dropped (e.g. long_500k batch=1)."""
    axes = dp(mesh, cfg)
    if batch is not None:
        while axes and batch % int(np.prod([mesh.shape[a] for a in axes])):
            axes = axes[1:]     # drop the outermost (pod) axis first
    return P(axes if axes else None, *([None] * (ndim - 1)))


def cache_spec_for(path_names, shape, cfg: LMConfig, mesh: Mesh) -> P:
    name = path_names[-1]
    kv = _kv_axis(cfg, mesh)
    pure_dp = getattr(cfg, "sharding_profile", "tp") == "dp"

    def t(*template):
        if pure_dp:
            template = tuple(None if a == "model" else a for a in template)
        template = _axis_ok(shape, template, mesh)
        return P(*((None,) * (len(shape) - len(template)) + template))

    d = dp(mesh, cfg)
    if name in ("k", "v"):            # (B, T, Hkv, hd): split-K over seq
        return t(d, "model", None, None)
    if name == "H":                   # (B, nh, ds, hd)
        return t(d, "model", None, None)
    if name == "conv_x":              # (B, w, di)
        return t(d, None, "model")
    if name in ("conv_b", "conv_c"):
        return t(d, None, None)
    if name == "h":                   # (B, dl)
        return t(d, "model")
    if name == "conv":                # rglru ring (B, w, dl)
        return t(d, None, "model")
    return P()


def cache_specs(cache_tree, cfg: LMConfig, mesh: Mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: cache_spec_for(_names(path), leaf.shape, cfg, mesh),
        cache_tree)


def to_shardings(spec_tree, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
