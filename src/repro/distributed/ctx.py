"""Sharding-hint context: lets model code drop `with_sharding_constraint`
hints (e.g. the MoE dispatch buffer must stay expert-sharded) without
threading a mesh through every call signature. No-op outside a context."""
from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_MESH = contextvars.ContextVar("repro_mesh", default=None)
_DP = contextvars.ContextVar("repro_dp_axes", default=None)
_TP = contextvars.ContextVar("repro_tp_axis", default="model")
_COMM = contextvars.ContextVar("repro_comm_axis", default=None)


@contextlib.contextmanager
def sharding_hints(mesh, dp: tuple | None = None, tp: str | None = "model"):
    """dp: axes carrying the batch (default: pod+data). tp: the tensor-
    parallel axis referenced by trailing hints, or None for pure-DP."""
    tok = _MESH.set(mesh)
    tok2 = _DP.set(dp)
    tok3 = _TP.set(tp)
    try:
        yield
    finally:
        _MESH.reset(tok)
        _DP.reset(tok2)
        _TP.reset(tok3)


def hint(x, *spec):
    """Apply a PartitionSpec constraint if a mesh context is active and the
    spec is valid for this mesh (unknown axes and axes that don't divide
    the dimension degrade to None)."""
    mesh = _MESH.get()
    if mesh is None:
        return x
    names = set(mesh.axis_names)

    def clean(s, dim):
        if s is None:
            return None
        axes = tuple(a for a in (s if isinstance(s, (tuple, list)) else (s,))
                     if a in names)
        while axes:
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            if dim % size == 0:
                break
            axes = axes[1:]
        if not axes:
            return None
        return axes if len(axes) > 1 else axes[0]

    spec = tuple(clean(s, d) for s, d in zip(spec, x.shape))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


@contextlib.contextmanager
def comm_context(axis: str, size: int):
    """Declare the mesh axis layer collectives exchange over (and its
    STATIC shard count, captured here because the context is read inside
    ``shard_map`` bodies where the mesh object is out of reach). With the
    context active, ``ffn_apply`` / ``gather_kv_shards`` treat their
    token rows as the local sequence shard and return the gathered
    full-sequence output — in Zebra stream form when
    ``distributed.collectives.resolve_comms`` allows, dense with a
    logged reason otherwise. No context (the default everywhere today):
    every layer exchange is a no-op, single-process semantics. The
    caller owns the enclosing ``shard_map`` over the same axis
    (``collectives.shard_map_compat``)."""
    tok = _COMM.set((axis, int(size)))
    try:
        yield
    finally:
        _COMM.reset(tok)


def comm_axis() -> tuple[str, int] | None:
    """The active (axis name, static size) comm declaration, or None."""
    return _COMM.get()


def dp_axes():
    override = _DP.get()
    if override is not None:
        return override
    mesh = _MESH.get()
    if mesh is None:
        return ("data",)
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def tp_axis():
    """Active tensor-parallel axis name, or None under the pure-DP profile."""
    return _TP.get()


def hint_tokens(x, *trailing):
    """Batch-sharded activation constraint: dim0 over the DP axes, given
    trailing spec for the last len(trailing) dims, None between. A
    trailing "model" resolves to the active TP axis (None in pure-DP)."""
    mesh = _MESH.get()
    if mesh is None:
        return x
    tp = _TP.get()
    trailing = tuple(tp if t == "model" else t for t in trailing)
    mid = (None,) * (x.ndim - 1 - len(trailing))
    return hint(x, dp_axes(), *mid, *trailing)
