"""Memory-bandwidth accounting — paper Eq. (2)-(5) and Table V.

All sizes in *bits* unless a function says bytes. The paper assumes
layer-by-layer accelerator processing: every conv layer's activation map is
written to external DRAM and read back by the next layer, so total
"required bandwidth" = Σ_layers map_size (Table V reports this per image).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class MapSpec:
    """One activation map written to DRAM/HBM."""
    c: int
    h: int
    w: int
    bits: int = 16        # B in Eq. 2
    block: int = 4        # block_size (per side)

    @property
    def elems(self) -> int:
        return self.c * self.h * self.w

    @property
    def map_bits(self) -> int:
        return self.elems * self.bits

    @property
    def n_blocks(self) -> int:
        return self.c * (self.h // self.block) * (self.w // self.block)

    @property
    def index_bits(self) -> int:
        """Eq. 3: one bit per block => C*W*H / block_size^2 bits."""
        return self.n_blocks


@dataclasses.dataclass(frozen=True)
class TokenMapSpec:
    """LM-layout map (S, D) with (bs x bc) tile blocks (DESIGN.md §2)."""
    s: int
    d: int
    bits: int = 16
    block_seq: int = 8
    block_ch: int = 128

    @property
    def elems(self) -> int:
        return self.s * self.d

    @property
    def map_bits(self) -> int:
        return self.elems * self.bits

    @property
    def n_blocks(self) -> int:
        return (self.s // self.block_seq) * (self.d // self.block_ch)

    @property
    def index_bits(self) -> int:
        return self.n_blocks


def stored_bits(spec, zero_frac: float) -> float:
    """Eq. 2 (+3): surviving data bits + index bits actually written."""
    return spec.map_bits * (1.0 - zero_frac) + spec.index_bits


def reduced_bandwidth_pct(specs: Sequence, zero_fracs: Sequence[float]) -> float:
    """Paper's 'Reduced bandwidth (%)' — net saving incl. index overhead."""
    base = sum(s.map_bits for s in specs)
    with_zebra = sum(stored_bits(s, z) for s, z in zip(specs, zero_fracs))
    return 100.0 * (1.0 - with_zebra / base)


def index_overhead_pct(specs: Sequence) -> float:
    """Table V: bandwidth overhead of block indices vs required bandwidth."""
    base = sum(s.map_bits for s in specs)
    idx = sum(s.index_bits for s in specs)
    return 100.0 * idx / base


def required_bandwidth_bytes(specs: Sequence) -> float:
    return sum(s.map_bits for s in specs) / 8.0


def conv_flops(c_in: int, h: int, w: int, k: int, c_out: int, stride: int = 1) -> float:
    """Eq. 4 (paper's convention): C*W*H*F*F*O / s."""
    return c_in * h * w * k * k * c_out / stride


def zebra_overhead_flops(c: int, h: int, w: int) -> float:
    """Eq. 5: one max-compare per element of the map."""
    return float(c * h * w)


def overhead_ratio(c_in: int, h: int, w: int, k: int, c_out: int, stride: int = 1) -> float:
    """Zebra compute overhead / conv compute (shows negligibility)."""
    return zebra_overhead_flops(c_in, h, w) / conv_flops(c_in, h, w, k, c_out, stride)
