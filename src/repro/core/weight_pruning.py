"""Magnitude weight pruning (Han et al., NeurIPS'15) — the unstructured
partner method in Tables II-IV. Prune the smallest-|w| fraction of every
conv / dense weight of a well-trained model, then retrain with the mask
fixed (paper §III.A: "do weight pruning on a well-trained model and use
the remaining weights to train with our method").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..utils import PyTree


def _is_weight(path, leaf) -> bool:
    name = str(getattr(path[-1], "key", getattr(path[-1], "name", "")))
    return name in ("w", "kernel") and leaf.ndim >= 2


def magnitude_masks(params: PyTree, prune_frac: float, per_layer: bool = True) -> PyTree:
    """0/1 keep-masks, same tree structure as params (None for non-weights)."""
    if per_layer:
        def mk(path, leaf):
            if not _is_weight(path, leaf):
                return None
            thr = jnp.quantile(jnp.abs(leaf.astype(jnp.float32)), prune_frac)
            return (jnp.abs(leaf) > thr).astype(leaf.dtype)
        return jax.tree_util.tree_map_with_path(mk, params)
    # global threshold across all weights
    mags = [jnp.abs(l.reshape(-1).astype(jnp.float32))
            for p, l in jax.tree_util.tree_leaves_with_path(params) if _is_weight(p, l)]
    thr = jnp.quantile(jnp.concatenate(mags), prune_frac)

    def mk(path, leaf):
        if not _is_weight(path, leaf):
            return None
        return (jnp.abs(leaf) > thr).astype(leaf.dtype)
    return jax.tree_util.tree_map_with_path(mk, params)


def apply_masks(params: PyTree, masks: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda p, m: p if m is None else p * m, params, masks,
        is_leaf=lambda x: x is None)


def sparsity(masks: PyTree) -> float:
    tot = kept = 0
    for m in jax.tree_util.tree_leaves(masks):
        if m is not None:
            tot += int(m.size)
            kept += float(jnp.sum(m))
    return 1.0 - kept / max(tot, 1)
