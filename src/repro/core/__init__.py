"""Zebra core — the paper's primary contribution (+ partner pruning methods)."""
from .zebra import (  # noqa: F401
    ZebraConfig,
    init_threshold_net,
    init_token_threshold_net,
    zebra_cnn,
    zebra_tokens,
    zebra_infer_bitmap_nchw,
    zebra_infer_bitmap_tokens,
    collect_zebra_loss,
    mean_zero_frac,
)
from .backends import (  # noqa: F401
    BackendSpec,
    backend_names,
    backend_spec,
)
from .engine import (  # noqa: F401
    LayerAux,
    SiteAux,
    nchw_stream_dims,
    register_engine_backend,
    site_block,
    wants_fused,
    zebra_site,
)
from .bandwidth import (  # noqa: F401
    MapSpec,
    TokenMapSpec,
    stored_bits,
    reduced_bandwidth_pct,
    index_overhead_pct,
    required_bandwidth_bytes,
    conv_flops,
    zebra_overhead_flops,
    overhead_ratio,
)
from . import slimming, weight_pruning  # noqa: F401
