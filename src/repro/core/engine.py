"""Unified Zebra site engine — ONE backend-dispatched execution path for
every activation site in the repo (CNN maps, LM FFN hidden maps, layer
outputs, KV caches), in BOTH training and inference.

The paper's pipeline is ``comparator -> block mask -> compressed DRAM
stream``; this module is the single code path that realizes it. Model code
never calls ``zebra_cnn`` / ``zebra_tokens`` / the Pallas kernels / the
stream codec directly — it calls :func:`zebra_site` and the engine picks
the execution backend from ``ZebraConfig.backend`` (with per-site
overrides via ``ZebraConfig.site_backends``):

``reference``
    Pure-jnp masking (``core.zebra``). The only backend that can serve
    threshold *nets* (per-sample learned thresholds + the Eq. 1
    regularizer); also the degrade target for every capability miss.
``pallas``
    The fused comparator kernel (``kernels.zebra_mask``): one VMEM pass
    computes block maxima, compares against T_obj and zeroes dead blocks.
    Bitwise-identical to reference — and *trainable*: in train mode the
    launch is wrapped in ``jax.custom_vjp`` (``kernels.grad``) whose
    backward implements the hard/STE/soft gradient modes.
``stream``
    ``zebra_mask_pack`` -> ``zebra_unpack``: the two-phase parallel
    producer (supertiled comparator pass + XLA exclusive scan +
    parallel pack pass) hands only the compressed ``(payload, bitmap)``
    stream to the expander — the dense masked map is never materialized
    by the producer, and no map is too big (the comparator pass tiles
    under ``tiles_for``; there is no whole-payload VMEM residency).
    ``SiteAux.measured_bytes`` reports the observed stream length
    (payload + packed index, the Eq. 2/3 observable). Numerically
    identical to reference — and trainable through the same custom_vjp,
    so the bytes observable stays live during training.
``fused``
    ``zebra_mask_pack`` -> ``zebra_spmm_cs``: the downstream matmul
    reads live blocks straight from the compressed payload via the
    bitmap's prefix-sum slot map in ``(stm, stk)`` supertile steps
    (``tiles_for(kind="gemm")``) and *skips* dead K-blocks in
    whole-supertile chunks without ever unpacking (dynamic feature-map
    pruning, Liang et al. 2018 style). Needs the downstream weight
    ``w``; used by the dense FFN ``w_down``. Byte accounting is the
    same ``stream_bytes`` helper as stream. Infer-only (the
    payload-consuming GEMM has no backward rule) — train-mode requests
    degrade to reference.

Capability resolution. Which backend actually executes is decided by the
:mod:`core.backends` registry: each :class:`~repro.core.backends.
BackendSpec` declares ``trainable`` / ``emits_stream`` / ``consumes_w``
/ ``vmem_bounded``, and :func:`zebra_site` resolves the site's
(mode, threshold-net, shape) situation against those capabilities. A
request the backend cannot serve degrades to ``reference`` with an
explicit reason — logged once per (site, backend, reason) and surfaced
in ``SiteAux.backend`` as ``"reference(<reason>)"``; there are no
implicit rewrites. The current reasons:

``tnet``             train mode with a threshold net: per-sample learned
                     thresholds (and their Eq. 1 gradient) are jnp-only.
``not-trainable``    train mode on a backend without a custom_vjp
                     backward (``fused``).
``degenerate-rows``  token maps whose S doesn't divide ``block_seq``
                     (e.g. single-token decode) degrade to ``bs=1`` — a
                     one-row "block" has no skippable HBM tile, so
                     kernel dispatch would be pure overhead.
``vmem-bounded``     a backend declaring ``vmem_bounded`` asked to run a
                     map bigger than ``vmem_budget_bytes``. The built-in
                     compressed backends self-tile (declare False); the
                     reason exists for registered backends that cannot.

Layouts. ``tokens`` maps ``(..., S, D)`` tile into ``(block_seq,
block_ch)`` VMEM blocks. ``nchw`` maps ``(B, C, H, W)`` use the paper's
spatial ``b x b`` blocks per channel; the engine flattens them onto the
kernels' 2-D ``(M, K)`` tile grid as ``(B*C*H, W)`` with ``bs = bc = b``
— every ``(b, b)`` tile of that matrix is exactly one spatial block of
one channel (H, W divide by b, so tiles never straddle planes). NCHW
blocks shrink to the largest divisor of (H, W) (paper: "block size 2
when the map goes to 2x2") and stay on the selected backend.

New backends register through :func:`register_engine_backend` — model
code needs no changes, which is the structural point of the registry.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax

from . import backends
from .backends import BackendSpec, backend_names, backend_spec
from .zebra import (ZebraConfig, effective_tnet, require_tnet, zebra_cnn,
                    zebra_tokens)

_log = logging.getLogger("repro.engine")
_DEGRADE_LOGGED: set[tuple[str, str, str]] = set()


# ---------------------------------------------------------------------------
# The uniform per-site aux struct
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SiteAux:
    """What one Zebra site reports, uniformly across backends.

    ``reg``             Eq. 1 regularizer term: threshold-net L2 pull in
                        tnet-train mode; the realized zero-block count
                        (zero_frac · n_blocks, stop-gradiented) in
                        constant-threshold train mode; 0 in infer mode.
    ``zero_frac``       fraction of blocks masked to zero at this site.
    ``measured_bytes``  observed transport bytes (payload + packed index)
                        for the whole input, exact int32; 0 for backends
                        that move the map dense (reference/pallas) or do
                        not run.
    ``n_blocks``        static per-sample block count (0 when disabled),
                        the weight used by ``mean_zero_frac``.
    ``thresholds``      train-mode threshold-net outputs (None otherwise).
    ``backend``         which backend actually executed (static). A
                        capability degrade is surfaced here as
                        ``"reference(<reason>)"``; a degraded layer
                        exchange appends ``"+dense-comms(<reason>)"``.
    ``ici_bytes``       interconnect bytes this site's layer exchanges
                        put on ONE inbound link (compressed stream on
                        the compressed path, dense size on a degraded
                        exchange); 0 outside a comm context. Attached by
                        ``distributed.collectives.attach_link``.
    ``ici_dense_bytes`` dense-equivalent per-link bytes of the same
                        exchanges (the ``lax.all_gather`` baseline the
                        compression is measured against).

    Supports dict-style access (``aux["zero_frac"]``, ``aux.get(...)``)
    so it is a drop-in for the legacy per-site aux dicts.
    """
    reg: Any = 0.0
    zero_frac: Any = 0.0
    measured_bytes: Any = 0.0
    n_blocks: Any = 0
    thresholds: Any = None
    backend: str = "reference"
    ici_bytes: Any = 0
    ici_dense_bytes: Any = 0

    def tree_flatten(self):
        return ((self.reg, self.zero_frac, self.measured_bytes,
                 self.n_blocks, self.thresholds, self.ici_bytes,
                 self.ici_dense_bytes), (self.backend,))

    @classmethod
    def tree_unflatten(cls, aux, children):
        reg, zf, mb, nb, thr, ici, icid = children
        return cls(reg=reg, zero_frac=zf, measured_bytes=mb, n_blocks=nb,
                   thresholds=thr, backend=aux[0], ici_bytes=ici,
                   ici_dense_bytes=icid)

    # legacy dict-style access (pre-engine aux shape)
    def __getitem__(self, key: str):
        return getattr(self, key)

    def get(self, key: str, default=None):
        return getattr(self, key, default)

    @classmethod
    def empty(cls, backend: str = "disabled") -> "SiteAux":
        return cls(reg=jnp.float32(0.0), zero_frac=jnp.float32(0.0),
                   measured_bytes=jnp.int32(0), n_blocks=0,
                   thresholds=None, backend=backend,
                   ici_bytes=jnp.int32(0), ici_dense_bytes=jnp.int32(0))


MB_BASE = 16777216             # 2**24 — f32 integers are exact below this
_MB_BASE = float(MB_BASE)


def add_byte_pair(hi_a, lo_a, hi_b, lo_b):
    """Add two (hi, lo) base-2**24 byte pairs exactly.

    The lo legs are added in int32: each is an exact integer < 2**24, but
    their f32 SUM can land between representable values above 2**24 (odd
    sums round) — the carry must be extracted from an exact sum. The ONE
    carry rule; LayerAux.__add__ and the train-step microbatch
    accumulator both use it. Inputs coerce through jnp.asarray so a
    defaulted Python-float leg (e.g. LayerAux ici fields a constructor
    left at 0.0) adds exactly like a jnp scalar."""
    lo = jnp.asarray(lo_a).astype(jnp.int32) + jnp.asarray(lo_b).astype(jnp.int32)
    hi = (jnp.asarray(hi_a, jnp.float32) + jnp.asarray(hi_b, jnp.float32)
          + (lo // jnp.int32(MB_BASE)).astype(jnp.float32))
    return hi, (lo % jnp.int32(MB_BASE)).astype(jnp.float32)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class LayerAux:
    """Site aux accumulated across layers/sites — the scan-carry form.

    f32 scalars so it rides ``jax.lax.scan`` carries and jit boundaries.
    ``zf_blocks`` is Σ zero_frac·n_blocks, so ``zero_frac`` (the
    property) is the block-count-weighted mean with a guard for the
    no-divisible-leaf / no-site case (n_blocks == 0 -> 0, no div/0).

    Measured bytes ride the carry as the exact f32 pair ``(mb_hi,
    mb_lo)`` with base 2**24: per-site counts are int32-exact, but a
    single f32 accumulator would start rounding as soon as the running
    total crossed 16 MiB. The pair keeps accumulation exact to 2**48
    bytes; read it back with :meth:`measured_bytes_exact` (host) — the
    in-graph ``measured_bytes`` property is a display convenience that
    rounds above 16 MiB.

    Interconnect bytes (``SiteAux.ici_bytes`` / ``ici_dense_bytes``,
    attached by the compressed collectives) accumulate through the same
    pair scheme — ``(ici_hi, ici_lo)`` for what layer exchanges actually
    put on one inbound link, ``(ici_dense_hi, ici_dense_lo)`` for the
    dense-equivalent baseline. They total across ALL exchanges a layer
    ran; per-axis breakdown lives in ``compress.meter.BandwidthMeter``
    link records (the axis is host-side metadata, not a carry). The
    fields default to 0.0 so pre-existing constructors stay valid —
    ``add_byte_pair`` coerces, and ``zero()``/``of_site`` produce jnp
    scalars so scan carries keep a consistent pytree.
    """
    reg: jax.Array
    zf_blocks: jax.Array
    n_blocks: jax.Array
    mb_hi: jax.Array
    mb_lo: jax.Array
    router_aux: jax.Array
    ici_hi: Any = 0.0
    ici_lo: Any = 0.0
    ici_dense_hi: Any = 0.0
    ici_dense_lo: Any = 0.0

    def tree_flatten(self):
        return ((self.reg, self.zf_blocks, self.n_blocks,
                 self.mb_hi, self.mb_lo, self.router_aux,
                 self.ici_hi, self.ici_lo,
                 self.ici_dense_hi, self.ici_dense_lo), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @classmethod
    def zero(cls) -> "LayerAux":
        z = jnp.float32(0.0)
        return cls(z, z, z, z, z, z, z, z, z, z)

    @classmethod
    def of_site(cls, site: SiteAux, router_aux=0.0) -> "LayerAux":
        nb = jnp.float32(site.n_blocks)
        base = jnp.int32(_MB_BASE)

        def pair(v):
            v = jnp.asarray(v).astype(jnp.int32)
            return ((v // base).astype(jnp.float32),
                    (v % base).astype(jnp.float32))

        mb_hi, mb_lo = pair(site.measured_bytes)
        ici_hi, ici_lo = pair(site.ici_bytes)
        icid_hi, icid_lo = pair(site.ici_dense_bytes)
        return cls(reg=jnp.float32(site.reg),
                   zf_blocks=jnp.float32(site.zero_frac) * nb,
                   n_blocks=nb,
                   mb_hi=mb_hi, mb_lo=mb_lo,
                   router_aux=jnp.float32(router_aux),
                   ici_hi=ici_hi, ici_lo=ici_lo,
                   ici_dense_hi=icid_hi, ici_dense_lo=icid_lo)

    def __add__(self, other: "LayerAux") -> "LayerAux":
        hi, lo = add_byte_pair(self.mb_hi, self.mb_lo,
                               other.mb_hi, other.mb_lo)
        ihi, ilo = add_byte_pair(self.ici_hi, self.ici_lo,
                                 other.ici_hi, other.ici_lo)
        dhi, dlo = add_byte_pair(self.ici_dense_hi, self.ici_dense_lo,
                                 other.ici_dense_hi, other.ici_dense_lo)
        return LayerAux(self.reg + other.reg,
                        self.zf_blocks + other.zf_blocks,
                        self.n_blocks + other.n_blocks,
                        hi, lo,
                        self.router_aux + other.router_aux,
                        ihi, ilo, dhi, dlo)

    @property
    def zero_frac(self) -> jax.Array:
        return jnp.clip(self.zf_blocks / jnp.maximum(self.n_blocks, 1.0),
                        0.0, 1.0)

    @property
    def measured_bytes(self) -> jax.Array:
        """In-graph f32 readout (rounds above 16 MiB — display only)."""
        return self.mb_hi * jnp.float32(_MB_BASE) + self.mb_lo

    def measured_bytes_exact(self) -> int:
        """Exact host-side readout of the accumulated byte pair."""
        return int(float(self.mb_hi)) * int(_MB_BASE) + int(float(self.mb_lo))

    @property
    def ici_bytes(self) -> jax.Array:
        """In-graph f32 readout of per-link interconnect bytes (display)."""
        return (jnp.asarray(self.ici_hi, jnp.float32) * jnp.float32(_MB_BASE)
                + jnp.asarray(self.ici_lo, jnp.float32))

    @property
    def ici_dense_bytes(self) -> jax.Array:
        return (jnp.asarray(self.ici_dense_hi, jnp.float32)
                * jnp.float32(_MB_BASE)
                + jnp.asarray(self.ici_dense_lo, jnp.float32))

    def ici_bytes_exact(self) -> tuple[int, int]:
        """Exact host-side (moved, dense-equivalent) per-link totals."""
        moved = (int(float(self.ici_hi)) * int(_MB_BASE)
                 + int(float(self.ici_lo)))
        dense = (int(float(self.ici_dense_hi)) * int(_MB_BASE)
                 + int(float(self.ici_dense_lo)))
        return moved, dense


# ---------------------------------------------------------------------------
# Block-layout helpers
# ---------------------------------------------------------------------------

def site_block(h: int, w: int, want: int) -> int:
    """Largest block size <= want dividing both map sides (paper §II.A:
    shrink when the map is smaller than the block, e.g. 2 for 2x2 maps)."""
    b = min(want, h, w)
    while h % b or w % b:
        b -= 1
    return max(b, 1)


def nchw_stream_dims(shape: tuple[int, ...], block_hw: int
                     ) -> tuple[int, int, int] | None:
    """(B, C, H, W) -> (M, K, b): the 2-D tile-grid view whose (b, b)
    tiles are exactly the paper's spatial blocks. None if not 4-D."""
    if len(shape) != 4:
        return None
    B, C, H, W = shape
    b = site_block(H, W, block_hw)
    return B * C * H, W, b


def _tokens_blocks(x: jax.Array, cfg: ZebraConfig) -> tuple[int, int, bool]:
    """Effective (bs, bc) for a (..., S, D) map + whether bs degenerated."""
    S, D = x.shape[-2], x.shape[-1]
    bs = cfg.block_seq if S % cfg.block_seq == 0 else 1
    bc = cfg.block_ch if D % cfg.block_ch == 0 else D
    return bs, bc, (bs == 1 and cfg.block_seq > 1)


def _index_bytes(n_blocks_total: int) -> int:
    return (n_blocks_total + 7) // 8


def stream_bytes(n_live: jax.Array, bs: int, bc: int, dtype,
                 n_blocks_total: int) -> jax.Array:
    """Observed stream length (Eq. 2/3): live payload + packed index.

    The ONE byte-accounting rule shared by every compressed backend —
    ``n_live`` is the producer kernel's counter output, so stream and
    fused cannot drift apart in how they reconcile against Eq. 2/3.
    Integer arithmetic: exact (the sub-1-byte reconciliation bound must
    hold per site) for payloads up to 2 GiB; float32 would already round
    above 16 MiB. Cross-site accumulation stays exact via the
    ``LayerAux`` (mb_hi, mb_lo) pair.
    """
    item = jnp.dtype(dtype).itemsize
    return (n_live.astype(jnp.int32) * (bs * bc * item)
            + _index_bytes(n_blocks_total))


def merge_site_aux(a: SiteAux, b: SiteAux) -> SiteAux:
    """Fold two sites' aux into ONE SiteAux: block-weighted zero_frac,
    summed reg/measured/ici legs, joined backend label. For call sites
    whose public contract is a single aux but that execute an auxiliary
    site — e.g. ``ffn_apply`` masking its layer output for the
    compressed TP exchange under a comm context. Thresholds keep ``a``'s
    (the primary site's) outputs — the auxiliary site never runs a
    threshold net."""
    na, nb = int(a.n_blocks), int(b.n_blocks)
    nt = max(na + nb, 1)
    zf = (jnp.float32(a.zero_frac) * na + jnp.float32(b.zero_frac) * nb) / nt
    as_i32 = lambda v: jnp.asarray(v).astype(jnp.int32)
    return SiteAux(
        reg=a.reg + b.reg, zero_frac=zf,
        measured_bytes=as_i32(a.measured_bytes) + as_i32(b.measured_bytes),
        n_blocks=na + nb, thresholds=a.thresholds,
        backend=f"{a.backend}+{b.backend}",
        ici_bytes=as_i32(a.ici_bytes) + as_i32(b.ici_bytes),
        ici_dense_bytes=(as_i32(a.ici_dense_bytes)
                         + as_i32(b.ici_dense_bytes)))


# ---------------------------------------------------------------------------
# Backend implementations — each maps (x2 (M, K), bs, bc, cfg) -> (y2, aux)
# ---------------------------------------------------------------------------

def _kernel_statics(variant: str, x2: jax.Array, bs: int, bc: int,
                    cfg: ZebraConfig):
    """Static launch config for ``kernels.grad.launch_forward`` — the ONE
    forward pipeline shared by infer dispatch and the custom_vjp train
    path, so the two cannot drift apart. The two-phase producer tiles
    its comparator pass with the same ``tiles_for`` supertile as the
    mask variant, so no map is ever over budget (the old
    whole-payload-resident producer needed a fits-VMEM degrade here)."""
    from ..kernels import supertile as st
    from ..kernels.grad import KernelStatics
    M, K = x2.shape
    item = jnp.dtype(x2.dtype).itemsize
    tm, tk = cfg.tiles_for(M, K, bs, bc, x2.dtype)
    gtm, gtk = cfg.tiles_for(M, K, bs, bc, x2.dtype, kind="gather")
    pw = st.pack_window((M // bs) * (K // bc), bs, bc, item,
                        int(cfg.vmem_budget_bytes))
    return KernelStatics(variant=variant, t_obj=cfg.t_obj, bs=bs, bc=bc,
                         tm=tm, tk=tk, gtm=gtm, gtk=gtk, pw=pw,
                         grad_mode=cfg.grad_mode,
                         soft_temp=cfg.soft_temp, interpret=cfg.interpret)


def _run_pallas(x2: jax.Array, bs: int, bc: int, cfg: ZebraConfig):
    from ..kernels.grad import launch_forward
    y2, bitmap, _ = launch_forward(x2, _kernel_statics("mask", x2, bs, bc, cfg))
    return y2, bitmap, jnp.int32(0)


def _mask_pack(x2: jax.Array, bs: int, bc: int, cfg: ZebraConfig):
    """Two-phase parallel producer: compressed stream out, the dense
    masked map never materialized; comparator pass tiled by tiles_for,
    pack pass windowed under the same budget."""
    from ..kernels import supertile as st
    from ..kernels.mask_pack import zebra_mask_pack
    M, K = x2.shape
    tm, tk = cfg.tiles_for(M, K, bs, bc, x2.dtype)
    window = st.pack_window((M // bs) * (K // bc), bs, bc,
                            jnp.dtype(x2.dtype).itemsize,
                            int(cfg.vmem_budget_bytes))
    return zebra_mask_pack(x2, t_obj=cfg.t_obj, bs=bs, bc=bc, tm=tm, tk=tk,
                           window=window, interpret=cfg.interpret)


def _run_stream(x2: jax.Array, bs: int, bc: int, cfg: ZebraConfig):
    """mask_pack -> unpack with only the (payload, bitmap) stream between
    producer and expander. Any map size fits: the producer's comparator
    pass tiles under cfg.tiles_for, the pack pass touches one payload
    slot window per step (no whole-payload VMEM residency)."""
    from ..kernels.grad import launch_forward
    y2, bitmap, n_live = launch_forward(
        x2, _kernel_statics("stream", x2, bs, bc, cfg))
    return y2, bitmap, stream_bytes(n_live, bs, bc, x2.dtype, bitmap.size)


def _run_fused(x2: jax.Array, w: jax.Array, bs: int, bc: int,
               cfg: ZebraConfig) -> tuple[jax.Array, jax.Array, jax.Array]:
    """mask_pack -> payload-consuming GEMM: the consumer reads each K
    column's live blocks as one contiguous run of the consumer-ordered
    payload through the static prefetch schedule (kernels.schedule) —
    dead blocks are skipped, the dense map is never unpacked. The full
    cached plan (cfg.gemm_plan_for: kernel-form supertile + the
    scheduled capacity ladder, tightened by cfg.zero_frac_hint) is
    threaded through, so repeated site launches hit the plan cache.
    Returns (x' @ w, bitmap, fetched bytes)."""
    from ..kernels.spmm_cs import zebra_spmm_cs
    M, K = x2.shape
    payload, bitmap, n_live = _mask_pack(x2, bs, bc, cfg)
    plan = cfg.gemm_plan_for(M, K, bs, bc, x2.dtype, n=w.shape[-1])
    out = zebra_spmm_cs(payload, w, bitmap, bs=bs, bc=bc, bn=plan.bn,
                        stm=plan.stm, stk=plan.stk, caps=plan.caps,
                        zero_frac_hint=cfg.zero_frac_hint,
                        interpret=cfg.interpret)
    measured = stream_bytes(n_live, bs, bc, x2.dtype, bitmap.size)
    return out.astype(x2.dtype), bitmap, measured


# ---------------------------------------------------------------------------
# Infer-path dispatch table — (x2, bs, bc, cfg, w) -> (y2, bitmap,
# measured_bytes, n_cols|None). n_cols None = map-shaped output.
# ---------------------------------------------------------------------------

def _impl_pallas(x2, bs, bc, cfg, w=None):
    y2, bitmap, measured = _run_pallas(x2, bs, bc, cfg)
    return y2, bitmap, measured, None


def _impl_stream(x2, bs, bc, cfg, w=None):
    y2, bitmap, measured = _run_stream(x2, bs, bc, cfg)
    return y2, bitmap, measured, None


def _impl_fused(x2, bs, bc, cfg, w=None):
    if w is None:                       # no downstream weight: mask-only
        return _impl_pallas(x2, bs, bc, cfg)
    out, bitmap, measured = _run_fused(x2, w, bs, bc, cfg)
    return out, bitmap, measured, w.shape[-1]


_INFER_IMPLS: dict[str, Callable] = {
    "pallas": _impl_pallas,
    "stream": _impl_stream,
    "fused": _impl_fused,
}


# ---------------------------------------------------------------------------
# Validated ingest (cfg.validation != "off") — the wire contract enforced
# at the producer -> consumer boundary, with recompute-from-dense recovery
# ---------------------------------------------------------------------------

def _validated_stream_impl(x2: jax.Array, bs: int, bc: int, cfg: ZebraConfig,
                           w: jax.Array | None = None, *, site: str = ""):
    """The stream/fused pipeline with the ``compress.integrity`` contract
    checked between producer and consumer: mask_pack -> (chaos tap) ->
    ``check_stream`` -> unpack / payload GEMM, with a ``lax.cond``
    recovery branch that recomputes from the dense map still in hand
    (``ft.faults`` policy "recompute-dense" — the dense source of an
    engine-internal stream is x2 itself). The recovery branch fires
    ``integrity.note_failure`` via ``jax.debug.callback`` so detections
    are observable from outside the jit. Checksum level seals the stream
    BEFORE the tap — corruption in flight must break the fold."""
    from ..compress import integrity
    from ..ft.inject import stream_tap
    from ..kernels.ref import zebra_mask_ref, zebra_unpack_ref

    level = cfg.validation
    tag = f"engine:{site or 'map'}"
    M, K = x2.shape
    payload, bitmap, n_live = _mask_pack(x2, bs, bc, cfg)
    csum = (integrity.stream_checksum(payload, bitmap, n_live)
            if level == "checksum" else None)
    payload, bitmap, n_live = stream_tap(payload, bitmap, n_live, site=tag)
    ok = integrity.check_stream(payload, bitmap, n_live, level=level,
                                checksum=csum,
                                live_nonzero=cfg.t_obj > 0)

    def recover_mask():
        jax.debug.callback(lambda t=tag: integrity.note_failure(t))
        return zebra_mask_ref(x2, cfg.t_obj, bs, bc)

    if w is None:
        y2, bm = lax.cond(
            ok,
            lambda: (zebra_unpack_ref(payload, bitmap, bs, bc),
                     bitmap.astype(jnp.int8)),
            recover_mask)
        n_cols = None
    else:
        from ..kernels.spmm_cs import zebra_spmm_cs
        plan = cfg.gemm_plan_for(M, K, bs, bc, x2.dtype, n=w.shape[-1])

        def consume():
            out = zebra_spmm_cs(payload, w, bitmap, bs=bs, bc=bc, bn=plan.bn,
                                stm=plan.stm, stk=plan.stk, caps=plan.caps,
                                zero_frac_hint=cfg.zero_frac_hint,
                                interpret=cfg.interpret)
            return out.astype(x2.dtype), bitmap.astype(jnp.int8)

        def recover():
            y, keep = recover_mask()
            return ((y.astype(jnp.float32) @ w.astype(jnp.float32))
                    .astype(x2.dtype), keep)

        y2, bm = lax.cond(ok, consume, recover)
        n_cols = w.shape[-1]
    n_keep = jnp.sum(bm.astype(jnp.int32))
    measured = stream_bytes(n_keep, bs, bc, x2.dtype, bm.size)
    return y2, bm, measured, n_cols


_VALIDATED_BACKENDS = ("stream", "fused")


def register_engine_backend(spec: BackendSpec, infer_impl: Callable,
                            forward_variant: Callable | None = None
                            ) -> BackendSpec:
    """Register a new execution backend end-to-end: declare its
    capabilities in the :mod:`core.backends` registry and provide the
    infer-path impl ``(x2, bs, bc, cfg, w) -> (y2, bitmap,
    measured_bytes, n_cols|None)``. A ``trainable`` spec must also bring
    its forward pipeline ``(x2, statics) -> (y2, bitmap, n_live)`` —
    registered under ``spec.grad_variant`` so train mode dispatches the
    same launches through the shared custom_vjp (``kernels.grad``) —
    unless it reuses a built-in variant. Model code needs no changes —
    every site already dispatches through :func:`zebra_site` by name."""
    from ..kernels import grad
    if forward_variant is not None:
        grad.register_forward_variant(spec.grad_variant, forward_variant)
    elif spec.trainable and spec.name != "reference" \
            and not grad.has_forward_variant(spec.grad_variant):
        raise ValueError(
            f"backend {spec.name!r} declares trainable=True with unknown "
            f"grad_variant {spec.grad_variant!r}; pass forward_variant= or "
            f"reuse a built-in variant")
    backends.register_backend(spec)
    _INFER_IMPLS[spec.name] = infer_impl
    return spec


# ---------------------------------------------------------------------------
# Capability resolution
# ---------------------------------------------------------------------------

def _resolve_backend(spec: BackendSpec, *, mode: str, tnet,
                     degenerate: bool, over_budget: bool = False
                     ) -> tuple[str, str | None]:
    """Map one site's situation onto a backend the spec can serve.

    Returns ``(final backend name, degrade reason | None)`` — the single
    place train/infer/shape legality is decided (no implicit rules at
    call sites). ``over_budget`` only matters for backends declaring
    ``vmem_bounded``: their whole-map working set must fit
    ``vmem_budget_bytes`` (the built-in compressed backends self-tile
    and declare False, so they never degrade here)."""
    if spec.name == "reference":
        return "reference", None
    if mode == "train" and not spec.trainable:
        return "reference", "not-trainable"
    if mode == "train" and tnet is not None:
        return "reference", "tnet"      # learned per-sample thresholds + the
                                        # Eq. 1 threshold gradient are jnp-only
    if degenerate:
        return "reference", "degenerate-rows"
    if spec.vmem_bounded and over_budget:
        return "reference", "vmem-bounded"
    return spec.name, None


def _log_degrade(site: str, requested: str, reason: str) -> None:
    key = (site, requested, reason)
    if key not in _DEGRADE_LOGGED:
        _DEGRADE_LOGGED.add(key)
        _log.info("zebra_site %r: backend %r degraded to reference (%s)",
                  site, requested, reason)


def wants_fused(cfg: ZebraConfig, site: str = "") -> bool:
    """True when this site should hand its downstream weight to the
    engine: the configured backend consumes ``w`` AND the capability
    resolution keeps it (a train-mode request on a non-trainable
    w-consumer degrades, so the caller keeps its dense matmul and remat
    annotations)."""
    if not cfg.enabled:
        return False
    spec = backend_spec(cfg.backend_for(site))
    if not spec.consumes_w or spec.name == "reference":
        return False
    final, _ = _resolve_backend(spec, mode=cfg.mode, tnet=None,
                                degenerate=False)
    return final == spec.name


# ---------------------------------------------------------------------------
# The engine entry point
# ---------------------------------------------------------------------------

def zebra_site(x: jax.Array, cfg: ZebraConfig, *, site: str = "",
               layout: str = "tokens", tnet: dict | None = None,
               w: jax.Array | None = None) -> tuple[jax.Array, SiteAux]:
    """Execute one Zebra activation site through the configured backend.

    x       ``tokens``: (..., S, D) activation map (leading dims = batch);
            ``nchw``: (B, C, H, W) CNN map.
    site    name used for per-site backend overrides (cfg.site_backends).
    tnet    threshold-net params (tnet-train sites resolve to reference).
    w       downstream weight (K, N) — only for backends whose spec
            declares ``consumes_w``; the site then returns ``mask(x) @ w``
            instead of the masked map.

    Works in train and infer mode on every backend: train-mode kernel
    dispatch goes through ``kernels.grad.zebra_kernel_trainable``
    (custom_vjp), so ``jax.grad`` through a pallas/stream site equals the
    reference path. Capability misses degrade to reference with the
    reason in ``SiteAux.backend`` (see module docstring).

    Returns ``(y, SiteAux)``. Without ``w``, y is the masked map (bitwise
    identical across reference/pallas/stream). With ``w`` (fused), y is
    the downstream product with dead blocks skipped.
    """
    spec = backend_spec(cfg.backend_for(site))
    if w is not None and not spec.consumes_w:
        raise ValueError(
            f"backend {spec.name!r} does not consume a downstream weight "
            f"(site={site!r}); apply the matmul at the call site instead")
    if not cfg.enabled:
        return (x if w is None else x @ w), SiteAux.empty()
    tnet = effective_tnet(cfg, tnet)
    require_tnet(cfg, tnet, site)

    # ---- layout -> 2-D tile grid + effective blocks -----------------------
    if layout == "nchw":
        B, C, H, W = x.shape
        b = site_block(H, W, cfg.block_hw)
        cfg = cfg.replace(block_hw=b)
        bs = bc = b
        dims = (B * C * H, W)
        nb_sample = C * (H // b) * (W // b)
        degenerate = False
    elif layout == "tokens":
        if x.ndim == 2:                 # bare (M, K) map: one-sample batch
            y, aux = zebra_site(x[None], cfg, site=site, layout=layout,
                                tnet=tnet, w=w)
            return y[0], aux
        bs, bc, degenerate = _tokens_blocks(x, cfg)
        cfg = cfg.replace(block_seq=bs, block_ch=bc)
        S, D = x.shape[-2], x.shape[-1]
        dims = (x.size // D, D)
        nb_sample = (S // bs) * (D // bc)
    else:
        raise ValueError(f"unknown layout {layout!r}")

    over_budget = (spec.vmem_bounded and
                   dims[0] * dims[1] * jnp.dtype(x.dtype).itemsize
                   > cfg.vmem_budget_bytes)
    backend, reason = _resolve_backend(spec, mode=cfg.mode, tnet=tnet,
                                       degenerate=degenerate,
                                       over_budget=over_budget)
    if reason is not None:
        _log_degrade(site, spec.name, reason)
    label = backend if reason is None else f"{backend}({reason})"

    # ---- reference: the jnp path (threshold nets live here) ---------------
    if backend == "reference":
        fn = zebra_cnn if layout == "nchw" else zebra_tokens
        y, aux = fn(x, cfg, tnet)
        if w is not None:               # w-consuming request degraded here
            y = y @ w
        return y, SiteAux(reg=aux["reg"], zero_frac=aux["zero_frac"],
                          measured_bytes=jnp.int32(0),
                          n_blocks=aux["n_blocks"],
                          thresholds=aux["thresholds"], backend=label)

    # ---- kernel backends on the flattened (M, K) grid ---------------------
    x2 = x.reshape(dims)
    if cfg.mode == "train":
        # trainable kernel path: custom_vjp forward = the same kernel
        # pipeline infer dispatches, backward = the configured gradient
        # mode (kernels.grad)
        from ..kernels.grad import zebra_kernel_trainable
        statics = _kernel_statics(spec.grad_variant, x2, bs, bc, cfg)
        y2, _, _ = zebra_kernel_trainable(x2, statics)
        # Observables are recomputed from the stop-gradient'd masked map,
        # NOT from the launch's bitmap/n_live outputs: integer custom_vjp
        # outputs materialize float0 tangents under jax.checkpoint'd layer
        # bodies (remat) that downstream arithmetic cannot consume. Live
        # blocks keep their values bitwise, so blockmax(|y|) >= t_obj IS
        # the kernel's keep bitmap (dead blocks are exact zeros).
        yd = jax.lax.stop_gradient(y2)
        ydb = yd.reshape(dims[0] // bs, bs, dims[1] // bc, bc)
        keep = (jnp.max(jnp.abs(ydb), axis=(1, 3))
                >= jnp.asarray(cfg.t_obj, yd.dtype))
        measured = (stream_bytes(jnp.sum(keep.astype(jnp.int32)), bs, bc,
                                 x2.dtype, keep.size)
                    if spec.emits_stream else jnp.int32(0))
        y = y2.reshape(x.shape)
        zero_frac = 1.0 - jnp.mean(keep.astype(jnp.float32))
        # realized Eq. 1 observable under the deployed constant thresholds
        reg = zero_frac * nb_sample
        return y, SiteAux(reg=reg, zero_frac=zero_frac,
                          measured_bytes=measured, n_blocks=nb_sample,
                          thresholds=None, backend=label)

    if cfg.validation != "off" and backend in _VALIDATED_BACKENDS:
        y2, bitmap, measured, n_cols = _validated_stream_impl(
            x2, bs, bc, cfg, w if backend == "fused" else None, site=site)
    else:
        y2, bitmap, measured, n_cols = _INFER_IMPLS[backend](x2, bs, bc, cfg, w)
    y = (y2.reshape(x.shape) if n_cols is None
         else y2.reshape(*x.shape[:-1], n_cols))
    zero_frac = 1.0 - jnp.mean(bitmap.astype(jnp.float32))
    return y, SiteAux(reg=jnp.float32(0.0), zero_frac=zero_frac,
                      measured_bytes=measured, n_blocks=nb_sample,
                      thresholds=None, backend=label)
