"""Unified Zebra site engine — ONE backend-dispatched execution path for
every activation site in the repo (CNN maps, LM FFN hidden maps, layer
outputs, KV caches).

The paper's pipeline is ``comparator -> block mask -> compressed DRAM
stream``; this module is the single code path that realizes it. Model code
never calls ``zebra_cnn`` / ``zebra_tokens`` / the Pallas kernels / the
stream codec directly — it calls :func:`zebra_site` and the engine picks
the execution backend from ``ZebraConfig.backend`` (with per-site
overrides via ``ZebraConfig.site_backends``):

``reference``
    Pure-jnp masking (``core.zebra``). The only backend with training
    semantics: threshold nets, the Eq. 1 regularizer and the hard/ste/soft
    gradient modes live here, so ``mode="train"`` always runs reference
    regardless of the configured backend.
``pallas``
    The fused comparator kernel (``kernels.zebra_mask``): one VMEM pass
    computes block maxima, compares against T_obj and zeroes dead blocks.
    Infer only; bitwise-identical to reference.
``stream``
    ``zebra_mask_pack`` -> ``zebra_unpack``: TWO launches, with only the
    compressed ``(payload, bitmap)`` stream between them — the dense
    masked map is never materialized by the producer.
    ``SiteAux.measured_bytes`` reports the observed stream length
    (payload + packed index, the Eq. 2/3 observable). Numerically
    identical to reference — but the bytes are real.
``fused``
    ``zebra_mask_pack`` -> ``zebra_spmm_cs``: TWO launches; the
    downstream matmul reads live blocks straight from the compressed
    payload via the bitmap's prefix-sum slot map and *skips* dead
    K-blocks without ever unpacking (dynamic feature-map pruning, Liang
    et al. 2018 style). Needs the downstream weight ``w``; used by the
    dense FFN ``w_down``. Byte accounting is the same ``stream_bytes``
    helper as stream (live payload + index is exactly what the GEMM
    fetches from HBM), fed by the producer's ``n_live`` counter.

Layouts. ``tokens`` maps ``(..., S, D)`` tile into ``(block_seq,
block_ch)`` VMEM blocks. ``nchw`` maps ``(B, C, H, W)`` use the paper's
spatial ``b x b`` blocks per channel; the engine flattens them onto the
kernels' 2-D ``(M, K)`` tile grid as ``(B*C*H, W)`` with ``bs = bc = b``
— every ``(b, b)`` tile of that matrix is exactly one spatial block of
one channel (H, W divide by b, so tiles never straddle planes). That one
reshape is what gives CNN maps real compressed transport.

Block adaptation mirrors the historical per-site behavior: NCHW blocks
shrink to the largest divisor of (H, W) (paper: "block size 2 when the
map goes to 2x2") and stay on the selected backend; token maps whose S
doesn't divide by ``block_seq`` (e.g. single-token decode) degrade to
``bs=1`` and fall back to ``reference`` — a one-row "block" has no
skippable HBM tile, so kernel dispatch would be pure overhead.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from .zebra import ZebraConfig, zebra_cnn, zebra_tokens

BACKENDS = ("reference", "pallas", "stream", "fused")


# ---------------------------------------------------------------------------
# The uniform per-site aux struct
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SiteAux:
    """What one Zebra site reports, uniformly across backends.

    ``reg``             Eq. 1 regularizer term (0 outside train/reference).
    ``zero_frac``       fraction of blocks masked to zero at this site.
    ``measured_bytes``  observed transport bytes (payload + packed index)
                        for the whole input; 0 for backends that move the
                        map dense (reference/pallas) or do not run.
    ``n_blocks``        static per-sample block count (0 when disabled),
                        the weight used by ``mean_zero_frac``.
    ``thresholds``      train-mode thresholds (None in infer mode).
    ``backend``         which backend actually executed (static).

    Supports dict-style access (``aux["zero_frac"]``, ``aux.get(...)``)
    so it is a drop-in for the legacy per-site aux dicts.
    """
    reg: Any = 0.0
    zero_frac: Any = 0.0
    measured_bytes: Any = 0.0
    n_blocks: Any = 0
    thresholds: Any = None
    backend: str = "reference"

    def tree_flatten(self):
        return ((self.reg, self.zero_frac, self.measured_bytes,
                 self.n_blocks, self.thresholds), (self.backend,))

    @classmethod
    def tree_unflatten(cls, aux, children):
        reg, zf, mb, nb, thr = children
        return cls(reg=reg, zero_frac=zf, measured_bytes=mb, n_blocks=nb,
                   thresholds=thr, backend=aux[0])

    # legacy dict-style access (pre-engine aux shape)
    def __getitem__(self, key: str):
        return getattr(self, key)

    def get(self, key: str, default=None):
        return getattr(self, key, default)

    @classmethod
    def empty(cls, backend: str = "disabled") -> "SiteAux":
        return cls(reg=jnp.float32(0.0), zero_frac=jnp.float32(0.0),
                   measured_bytes=jnp.float32(0.0), n_blocks=0,
                   thresholds=None, backend=backend)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class LayerAux:
    """Site aux accumulated across layers/sites — the scan-carry form.

    Five f32 scalars so it rides ``jax.lax.scan`` carries and jit
    boundaries. ``zf_blocks`` is Σ zero_frac·n_blocks, so ``zero_frac``
    (the property) is the block-count-weighted mean with a guard for the
    no-divisible-leaf / no-site case (n_blocks == 0 -> 0, no div-by-zero).
    """
    reg: jax.Array
    zf_blocks: jax.Array
    n_blocks: jax.Array
    measured_bytes: jax.Array
    router_aux: jax.Array

    def tree_flatten(self):
        return ((self.reg, self.zf_blocks, self.n_blocks,
                 self.measured_bytes, self.router_aux), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @classmethod
    def zero(cls) -> "LayerAux":
        z = jnp.float32(0.0)
        return cls(z, z, z, z, z)

    @classmethod
    def of_site(cls, site: SiteAux, router_aux=0.0) -> "LayerAux":
        nb = jnp.float32(site.n_blocks)
        return cls(reg=jnp.float32(site.reg),
                   zf_blocks=jnp.float32(site.zero_frac) * nb,
                   n_blocks=nb,
                   measured_bytes=jnp.float32(site.measured_bytes),
                   router_aux=jnp.float32(router_aux))

    def __add__(self, other: "LayerAux") -> "LayerAux":
        return LayerAux(self.reg + other.reg,
                        self.zf_blocks + other.zf_blocks,
                        self.n_blocks + other.n_blocks,
                        self.measured_bytes + other.measured_bytes,
                        self.router_aux + other.router_aux)

    @property
    def zero_frac(self) -> jax.Array:
        return jnp.clip(self.zf_blocks / jnp.maximum(self.n_blocks, 1.0),
                        0.0, 1.0)


# ---------------------------------------------------------------------------
# Block-layout helpers
# ---------------------------------------------------------------------------

def site_block(h: int, w: int, want: int) -> int:
    """Largest block size <= want dividing both map sides (paper §II.A:
    shrink when the map is smaller than the block, e.g. 2 for 2x2 maps)."""
    b = min(want, h, w)
    while h % b or w % b:
        b -= 1
    return max(b, 1)


def nchw_stream_dims(shape: tuple[int, ...], block_hw: int
                     ) -> tuple[int, int, int] | None:
    """(B, C, H, W) -> (M, K, b): the 2-D tile-grid view whose (b, b)
    tiles are exactly the paper's spatial blocks. None if not 4-D."""
    if len(shape) != 4:
        return None
    B, C, H, W = shape
    b = site_block(H, W, block_hw)
    return B * C * H, W, b


def _tokens_blocks(x: jax.Array, cfg: ZebraConfig) -> tuple[int, int, bool]:
    """Effective (bs, bc) for a (..., S, D) map + whether bs degenerated."""
    S, D = x.shape[-2], x.shape[-1]
    bs = cfg.block_seq if S % cfg.block_seq == 0 else 1
    bc = cfg.block_ch if D % cfg.block_ch == 0 else D
    return bs, bc, (bs == 1 and cfg.block_seq > 1)


def _index_bytes(n_blocks_total: int) -> int:
    return (n_blocks_total + 7) // 8


def stream_bytes(n_live: jax.Array, bs: int, bc: int, dtype,
                 n_blocks_total: int) -> jax.Array:
    """Observed stream length (Eq. 2/3): live payload + packed index.

    The ONE byte-accounting rule shared by every compressed backend —
    ``n_live`` is the producer kernel's counter output, so stream and
    fused cannot drift apart in how they reconcile against Eq. 2/3.
    Integer arithmetic: exact (the sub-1-byte reconciliation bound must
    hold per site) for payloads up to 2 GiB; float32 would already round
    above 16 MiB.
    """
    item = jnp.dtype(dtype).itemsize
    return (n_live.astype(jnp.int32) * (bs * bc * item)
            + _index_bytes(n_blocks_total))


# ---------------------------------------------------------------------------
# Backend implementations — each maps (x2 (M, K), bs, bc, cfg) -> (y2, aux)
# ---------------------------------------------------------------------------

def _run_pallas(x2: jax.Array, bs: int, bc: int, cfg: ZebraConfig):
    from ..kernels.zebra_mask import zebra_mask
    M, K = x2.shape
    tm, tk = cfg.tiles_for(M, K, bs, bc, x2.dtype)
    y2, bitmap = zebra_mask(x2, t_obj=cfg.t_obj, bs=bs, bc=bc, tm=tm, tk=tk,
                            interpret=cfg.interpret)
    return y2, bitmap, jnp.float32(0.0)


def _producer_fits_vmem(x2: jax.Array, cfg: ZebraConfig) -> bool:
    """zebra_mask_pack keeps the whole worst-case payload (== the map
    size) VMEM-resident across its grid; maps beyond the budget take the
    tiled multi-launch pipeline instead."""
    return x2.size * jnp.dtype(x2.dtype).itemsize <= cfg.vmem_budget_bytes


def _mask_pack(x2: jax.Array, bs: int, bc: int, cfg: ZebraConfig):
    """Single-pass producer: one launch, compressed stream out, the dense
    masked map never materialized."""
    from ..kernels.mask_pack import zebra_mask_pack
    return zebra_mask_pack(x2, t_obj=cfg.t_obj, bs=bs, bc=bc,
                           interpret=cfg.interpret)


def _run_stream(x2: jax.Array, bs: int, bc: int, cfg: ZebraConfig):
    """mask_pack -> unpack: 2 launches, (payload, bitmap) in between.
    Over-budget maps degrade to the tiled mask -> pack -> unpack pipeline
    (3 launches, comparator tiles from cfg.tiles_for) — same stream, same
    byte accounting, the producer just can't hold the payload in VMEM."""
    from ..kernels.pack import zebra_pack, zebra_unpack
    if _producer_fits_vmem(x2, cfg):
        payload, bitmap, n_live = _mask_pack(x2, bs, bc, cfg)
    else:
        y2, bitmap, _ = _run_pallas(x2, bs, bc, cfg)
        payload, n_live = zebra_pack(y2, bitmap, bs=bs, bc=bc,
                                     interpret=cfg.interpret)
    y2 = zebra_unpack(payload, bitmap, bs=bs, bc=bc, interpret=cfg.interpret)
    return y2, bitmap, stream_bytes(n_live, bs, bc, x2.dtype, bitmap.size)


def _run_fused(x2: jax.Array, w: jax.Array, bs: int, bc: int,
               cfg: ZebraConfig) -> tuple[jax.Array, jax.Array, jax.Array]:
    """mask_pack -> payload-consuming GEMM: 2 launches, the GEMM reads live
    blocks straight from the compressed payload (dead K-blocks skipped,
    never unpacked). Over-budget maps degrade to tiled mask -> zebra_spmm
    (n_live then comes from the bitmap; same stream_bytes rule).
    Returns (x' @ w, bitmap, fetched bytes)."""
    if _producer_fits_vmem(x2, cfg):
        from ..kernels.spmm_cs import zebra_spmm_cs
        payload, bitmap, n_live = _mask_pack(x2, bs, bc, cfg)
        out = zebra_spmm_cs(payload, w, bitmap, bs=bs, bc=bc,
                            interpret=cfg.interpret)
    else:
        from ..kernels.zebra_spmm import zebra_spmm
        y2, bitmap, _ = _run_pallas(x2, bs, bc, cfg)
        out = zebra_spmm(y2, w, bitmap, bs=bs, bc=bc, interpret=cfg.interpret)
        n_live = jnp.sum(bitmap.astype(jnp.int32))
    measured = stream_bytes(n_live, bs, bc, x2.dtype, bitmap.size)
    return out.astype(x2.dtype), bitmap, measured


# ---------------------------------------------------------------------------
# The engine entry point
# ---------------------------------------------------------------------------

def wants_fused(cfg: ZebraConfig, site: str = "") -> bool:
    """True when this site should hand its downstream weight to the engine
    (infer-mode fused dispatch). Train mode always materializes the masked
    map (reference), so callers keep their dense matmul there."""
    return (cfg.enabled and cfg.mode != "train"
            and cfg.backend_for(site) == "fused")


def zebra_site(x: jax.Array, cfg: ZebraConfig, *, site: str = "",
               layout: str = "tokens", tnet: dict | None = None,
               w: jax.Array | None = None) -> tuple[jax.Array, SiteAux]:
    """Execute one Zebra activation site through the configured backend.

    x       ``tokens``: (..., S, D) activation map (leading dims = batch);
            ``nchw``: (B, C, H, W) CNN map.
    site    name used for per-site backend overrides (cfg.site_backends).
    tnet    threshold-net params (train mode, reference backend only).
    w       downstream weight (K, N) — required by the fused backend,
            which then returns ``mask(x) @ w`` instead of the masked map.

    Returns ``(y, SiteAux)``. Without ``w``, y is the masked map (bitwise
    identical across reference/pallas/stream). With ``w`` (fused), y is
    the downstream product with dead blocks skipped.
    """
    backend = cfg.backend_for(site)
    if backend not in BACKENDS:
        raise ValueError(f"unknown zebra backend {backend!r} "
                         f"(site={site!r}); expected one of {BACKENDS}")
    if w is not None and backend != "fused":
        raise ValueError("w is only consumed by the fused backend; apply "
                         "the downstream matmul at the call site instead")
    if not cfg.enabled:
        return (x if w is None else x @ w), SiteAux.empty()
    if cfg.mode == "train":
        backend = "reference"           # gradients + threshold nets are jnp
                                        # (w degrades to a dense matmul there)

    # ---- layout -> 2-D tile grid + effective blocks -----------------------
    if layout == "nchw":
        B, C, H, W = x.shape
        b = site_block(H, W, cfg.block_hw)
        cfg = cfg.replace(block_hw=b)
        bs = bc = b
        dims = (B * C * H, W)
        nb_sample = C * (H // b) * (W // b)
        degenerate = False
    elif layout == "tokens":
        if x.ndim == 2:                 # bare (M, K) map: one-sample batch
            y, aux = zebra_site(x[None], cfg, site=site, layout=layout,
                                tnet=tnet, w=w)
            return y[0], aux
        bs, bc, degenerate = _tokens_blocks(x, cfg)
        cfg = cfg.replace(block_seq=bs, block_ch=bc)
        S, D = x.shape[-2], x.shape[-1]
        dims = (x.size // D, D)
        nb_sample = (S // bs) * (D // bc)
    else:
        raise ValueError(f"unknown layout {layout!r}")

    if backend != "reference" and degenerate:
        backend = "reference"           # 1-row decode tiles: nothing to skip

    # ---- reference: the jnp path (train semantics live here) --------------
    if backend == "reference":
        fn = zebra_cnn if layout == "nchw" else zebra_tokens
        y, aux = fn(x, cfg, tnet)
        if w is not None:               # fused request degraded to reference
            y = y @ w
        return y, SiteAux(reg=aux["reg"], zero_frac=aux["zero_frac"],
                          measured_bytes=jnp.float32(0.0),
                          n_blocks=aux["n_blocks"],
                          thresholds=aux["thresholds"], backend="reference")

    # ---- kernel backends on the flattened (M, K) grid ---------------------
    x2 = x.reshape(dims)
    if backend == "pallas":
        y2, bitmap, measured = _run_pallas(x2, bs, bc, cfg)
        y = y2.reshape(x.shape)
    elif backend == "stream":
        y2, bitmap, measured = _run_stream(x2, bs, bc, cfg)
        y = y2.reshape(x.shape)
    else:  # fused
        if w is None:                   # no downstream weight: mask-only
            y2, bitmap, measured = _run_pallas(x2, bs, bc, cfg)
            y = y2.reshape(x.shape)
        else:
            y2, bitmap, measured = _run_fused(x2, w, bs, bc, cfg)
            y = y2.reshape(*x.shape[:-1], w.shape[-1])
    zero_frac = 1.0 - jnp.mean(bitmap.astype(jnp.float32))
    return y, SiteAux(reg=jnp.float32(0.0), zero_frac=zero_frac,
                      measured_bytes=measured, n_blocks=nb_sample,
                      thresholds=None, backend=backend)
