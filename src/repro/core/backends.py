"""BackendSpec registry — the capability contract of the Zebra site engine.

Every execution backend the engine can dispatch to declares what it is
*able* to do, and ``core.engine.zebra_site`` resolves each site's
(mode, layout, shape, threshold-net) situation against those declared
capabilities instead of a scattered chain of implicit rules. A request
the backend cannot serve degrades to ``reference`` with an explicit
reason that is logged once and surfaced in ``SiteAux.backend`` as
``"reference(<reason>)"`` — never a silent rewrite.

Capabilities:

``trainable``
    The backend has training semantics: its kernel launches are wrapped
    in ``jax.custom_vjp`` (``kernels.grad``) whose backward implements
    the hard/STE/soft gradient modes, numerically equal to the reference
    path. Only constant-``T_obj`` thresholds are kernel-trainable —
    sites with a threshold net (per-sample learned thresholds) always
    resolve to reference via the capability check.
``emits_stream``
    The backend moves the compressed ``(payload, 1-bit index)`` stream,
    so ``SiteAux.measured_bytes`` is a live observable.
``consumes_w``
    The backend may take the downstream weight ``w`` and return the
    product instead of the masked map.
``vmem_bounded``
    The backend's whole-map working set must fit ``ZebraConfig.
    vmem_budget_bytes``; the engine degrades bigger maps to reference
    with reason ``"vmem-bounded"`` (as it once gated the old
    whole-payload-resident producer). The built-in compressed backends
    now self-tile — the two-phase producer's comparator pass and the
    supertiled consumers size their windows from ``ZebraConfig.
    tiles_for`` under the budget — so they declare False; the flag
    serves registered backends that cannot self-tile.
``payload_order``
    The slot-order contract of the compressed payload the backend emits
    or consumes. ``"consumer"`` is the GEMM-consumable supertile order
    of ``kernels.schedule`` — slots grouped by K-block column, columns
    ascending, block rows ascending within a column, live slots
    contiguous in ``[0, n_live)`` — which lets the consumer read each K
    column's operand as ONE contiguous slot run through a static
    prefetch schedule (zero dynamic-window gathers on the hot path).
    ``None`` for backends that move no payload. Every ``emits_stream``
    backend must declare an order: the payload is an interchange format
    (producer, expander, consumer, codec all address it), so an
    undeclared order is a registration error, not a default.
``grad_variant``
    Which ``kernels.grad`` forward variant implements this backend's
    trainable path (``"mask"`` | ``"stream"``; None = jnp autodiff).
``comms``
    How this backend's maps cross mesh axes in layer exchanges
    (``distributed/collectives.py``). ``"compressed"`` declares that the
    backend's payload contract extends to the interconnect: TP
    layer-output / KV-shard gathers move the (bitmap, payload) stream
    with per-link byte accounting instead of dense ``lax.all_gather``.
    Only stream-emitting backends may declare it — the payload IS the
    wire format, so a dense-map backend claiming compressed comms is a
    registration error. ``None`` (reference/pallas): exchanges under a
    comm context run dense with an explicit, logged degrade reason
    (``resolve_comms``), never silently.

Registering a new backend (say, a sharded one) is
``core.engine.register_engine_backend(spec, infer_impl)`` — no model
code changes: model layers only ever call ``zebra_site``.
"""
from __future__ import annotations

import dataclasses


PAYLOAD_ORDERS = ("consumer",)
COMM_MODES = ("compressed",)


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    name: str
    trainable: bool
    emits_stream: bool
    consumes_w: bool
    vmem_bounded: bool
    grad_variant: str | None = None
    payload_order: str | None = None
    comms: str | None = None


_REGISTRY: dict[str, BackendSpec] = {}


def register_backend(spec: BackendSpec) -> BackendSpec:
    if spec.trainable and spec.name != "reference" and spec.grad_variant is None:
        raise ValueError(
            f"backend {spec.name!r}: trainable kernel backends must declare "
            f"a kernels.grad variant (grad_variant)")
    if spec.emits_stream and spec.payload_order is None:
        raise ValueError(
            f"backend {spec.name!r}: stream-emitting backends must declare "
            f"the payload slot order (payload_order), e.g. 'consumer' — the "
            f"payload is an interchange format and its order is part of the "
            f"contract")
    if spec.payload_order is not None and spec.payload_order not in PAYLOAD_ORDERS:
        raise ValueError(
            f"backend {spec.name!r}: unknown payload_order "
            f"{spec.payload_order!r}; expected one of {PAYLOAD_ORDERS}")
    if spec.comms is not None and spec.comms not in COMM_MODES:
        raise ValueError(
            f"backend {spec.name!r}: unknown comms mode {spec.comms!r}; "
            f"expected one of {COMM_MODES}")
    if spec.comms == "compressed" and not spec.emits_stream:
        raise ValueError(
            f"backend {spec.name!r}: comms='compressed' requires "
            f"emits_stream=True — the (bitmap, payload) stream IS the wire "
            f"format of the compressed collectives")
    _REGISTRY[spec.name] = spec
    return spec


def backend_spec(name: str) -> BackendSpec:
    """Resolve a backend name; raises with the known set on a bad name."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown zebra backend {name!r}; expected one of "
                         f"{backend_names()}") from None


def backend_names() -> tuple[str, ...]:
    return tuple(_REGISTRY)


def validate_backend(name: str) -> str:
    backend_spec(name)
    return name


# ---------------------------------------------------------------------------
# The built-in backends (impls live in core.engine / kernels.grad)
# ---------------------------------------------------------------------------

register_backend(BackendSpec(
    "reference", trainable=True, emits_stream=False, consumes_w=True,
    vmem_bounded=False))
register_backend(BackendSpec(
    "pallas", trainable=True, emits_stream=False, consumes_w=False,
    vmem_bounded=False, grad_variant="mask"))
register_backend(BackendSpec(
    "stream", trainable=True, emits_stream=True, consumes_w=False,
    vmem_bounded=False, grad_variant="stream", payload_order="consumer",
    comms="compressed"))
register_backend(BackendSpec(
    "fused", trainable=False, emits_stream=True, consumes_w=True,
    vmem_bounded=False, payload_order="consumer", comms="compressed"))
