"""Zebra — Zero-Block Regularization of activation maps (Shih & Chang, ISCAS'20).

The paper's contribution, as a composable JAX module.

Two activation layouts are supported:

* **CNN maps** ``(B, C, H, W)`` — faithful reproduction: non-overlapping
  spatial ``b×b`` blocks per channel, block importance = block max, one
  threshold per (layer, channel) produced by a GAP+FC threshold network
  (training) or the constant ``T_obj`` (inference). Paper §II.A/§II.B.
* **Token maps** ``(B, S, D)`` — the TPU adaptation (DESIGN.md §2): blocks
  are ``(block_seq × block_ch)`` tiles, shaped like VMEM tiles so that a
  zero block is a skippable HBM transfer. Importance uses ``max(|x|)``
  because RMSNorm'd activations are unbounded/signed (post-ReLU maps are
  non-negative, where ``max(|x|) == max(x)`` — so the CNN path stays
  faithful).

Training-mode gradient semantics (paper-faithful default ``grad_mode=
"hard"``): the mask is a hard 0/1 gate under ``stop_gradient``; thresholds
receive gradient *only* from the L2 regularizer pulling them to ``T_obj``
(Eq. 1), surviving blocks receive the task gradient. ``"ste"`` and
``"soft"`` are beyond-paper trainability variants.

Constant-threshold training (``tnet=None`` in train mode, or
``use_tnet=False``): the deployed ``T_obj`` comparator is the forward
gate for *all* gradient modes — the mode only selects the backward
surrogate — so train-time gating matches inference masking exactly.
This is the semantics the kernel backends reproduce via ``custom_vjp``
(``kernels.grad``); the reg slot reports the realized zero-block count.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Aux = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ZebraConfig:
    enabled: bool = True
    t_obj: float = 0.1           # target threshold T_obj (Eq. 1), in [0, 1]
    block_hw: int = 4            # spatial b for CNN maps (paper: 4 / 8 / 2)
    block_seq: int = 8           # token-block rows for LM maps (VMEM sublane)
    block_ch: int = 128          # channel-block cols for LM maps (VMEM lane)
    lambda_ce: float = 1.0       # λ weighting the CE term in Eq. 1
    mode: str = "train"          # "train" (threshold net) | "infer" (T_obj)
    grad_mode: str = "hard"      # "hard" (paper) | "ste" | "soft"
    soft_temp: float = 0.05
    use_tnet: bool = True        # train with a learned threshold net; False
                                 # = constant-T_obj (deployment-matched)
                                 # training, which the kernel backends can
                                 # serve through jax.custom_vjp
    act_bits: int = 16           # B in Eq. 2 (bf16 activations on TPU)
    # --- site-engine execution (core.engine) ---
    backend: str = "reference"   # reference | pallas | stream | fused
    site_backends: tuple[tuple[str, str], ...] = ()  # per-site overrides
    interpret: bool = True       # Pallas interpret mode (CPU containers)
    vmem_budget_bytes: int = 8 * 1024 * 1024
                                 # per-launch VMEM working-set cap the tile
                                 # chooser (tiles_for) sizes comparator
                                 # tiles AND GEMM/gather supertiles against
                                 # (~half a 16 MB core)
    zero_frac_hint: float | None = None
                                 # expected zero-block fraction at this
                                 # site (e.g. the paper's ~0.64 operating
                                 # point). Threaded into the cached
                                 # gemm_plan chooser, where it tightens
                                 # the scheduled consumers' capacity
                                 # ladder; never changes kernel-form
                                 # supertiles (numerics stay hint-free)
    validation: str = "off"      # stream-integrity level at every boundary
                                 # that consumes a (bitmap, payload) stream
                                 # (compress.integrity): "off" (hot path
                                 # untouched) | "structural" (popcount /
                                 # finite / live-slot invariants +
                                 # recompute-from-dense recovery) |
                                 # "checksum" (+ uint32 fold carried
                                 # in-band, catches finite value flips)

    def __post_init__(self):
        # config-time validation against the capability registry — a typo'd
        # backend fails where the config is built, not at first dispatch
        from .backends import validate_backend
        from ..compress.integrity import validate_level
        if self.backend:
            validate_backend(self.backend)
        for _, name in self.site_backends:
            if name:
                validate_backend(name)
        validate_level(self.validation)

    def replace(self, **kw) -> "ZebraConfig":
        return dataclasses.replace(self, **kw)

    def backend_for(self, site: str = "") -> str:
        """Resolve the execution backend for one named site."""
        return dict(self.site_backends).get(site, self.backend) or "reference"

    def tiles_for(self, M: int, K: int, bs: int, bc: int, dtype, *,
                  kind: str = "comparator", n: int | None = None):
        """VMEM-budget/dtype-aware supertile chooser for an (M, K) map
        with (bs, bc) Zebra blocks — the ONE tiling policy every kernel
        launch goes through, so producers and consumers cannot disagree.

        ``kind="comparator"`` (default): tile (tm, tk) for the bitmap /
        masking passes. The pass holds an input tile and an output tile
        in VMEM (2 * tm * tk * itemsize bytes; the bitmap tile is
        negligible), so the chooser takes the widest block-aligned tk
        that leaves at least one block row within ``vmem_budget_bytes``,
        then the tallest block-aligned tm that fits — bf16 maps get
        twice the f32 tile. Never shrinks below one (bs, bc) block; XLA
        pads sub-tile maps.

        ``kind="gemm"``: GEMM supertile (stm, stk, bn) for the
        block-skipping consumers (``zebra_spmm`` / ``zebra_spmm_cs``)
        against a (K, ``n``) weight — block-count divisors of the map
        sides (no ragged payload windows) capped per step, accounting
        for the activation windows, the (stk, bn) weight window and the
        fp32 accumulator/output under the same budget. Routed through
        the cached ``supertile.gemm_plan`` chooser (with
        ``zero_frac_hint``), so repeated site launches hit the plan
        cache; the engine's fused path reads the full plan (including
        the scheduled capacity ladder) via ``gemm_plan_for``.

        ``kind="gather"``: supertile (stm, stk) for the payload
        expander (``zebra_unpack``).
        """
        from ..kernels import supertile as st
        item = jnp.dtype(dtype).itemsize
        if kind == "gemm":
            plan = self.gemm_plan_for(M, K, bs, bc, dtype, n=n)
            return plan.stm, plan.stk, plan.bn
        if kind == "gather":
            return st.gather_supertiles(M, K, bs, bc, item,
                                        int(self.vmem_budget_bytes))
        if kind != "comparator":
            raise ValueError(f"unknown tile kind {kind!r}")
        return st.comparator_tiles(M, K, bs, bc, item,
                                   int(self.vmem_budget_bytes))

    def gemm_plan_for(self, M: int, K: int, bs: int, bc: int, dtype, *,
                      n: int | None = None):
        """The full cached GEMM plan (kernel-form supertile + the
        scheduled consumers' capacity ladder) for an (M, K) x (K, n)
        site under this config's budget and ``zero_frac_hint``."""
        from ..kernels import supertile as st
        if n is None:
            raise ValueError("kind='gemm' needs the weight width n")
        return st.gemm_plan(M, K, n, bs, bc, jnp.dtype(dtype).itemsize,
                            int(self.vmem_budget_bytes),
                            zero_frac=self.zero_frac_hint)


# ---------------------------------------------------------------------------
# Threshold network: T_{l,c} = FC(GAP(x))  (paper Fig. 2)
# ---------------------------------------------------------------------------

def init_threshold_net(key: jax.Array, channels: int, dtype=jnp.float32) -> dict:
    """One per Zebra site. FC maps GAP features -> per-channel thresholds."""
    w = jax.random.normal(key, (channels, channels), dtype) * (channels ** -0.5)
    b = jnp.zeros((channels,), dtype)
    return {"w": w, "b": b}


def _thresholds_from_net(tnet: dict, gap: jax.Array) -> jax.Array:
    """gap: (B, C) -> per-sample, per-channel thresholds (B, C)."""
    return gap @ tnet["w"] + tnet["b"]


def init_token_threshold_net(key: jax.Array, d: int, n_ch_blocks: int,
                             dtype=jnp.float32) -> dict:
    """LM variant (DESIGN.md §2): the FC emits one threshold per *channel
    block* (d_ff can be 22k wide — a C×C FC would be 0.5B params/layer)."""
    w = jax.random.normal(key, (d, n_ch_blocks), dtype) * (d ** -0.5)
    b = jnp.zeros((n_ch_blocks,), dtype)
    return {"w": w, "b": b}


# ---------------------------------------------------------------------------
# Block partition + masking
# ---------------------------------------------------------------------------

def _block_reduce_max_nchw(x: jax.Array, b: int) -> jax.Array:
    """(B,C,H,W) -> per-block max (B,C,H//b,W//b). H,W must divide by b."""
    B, C, H, W = x.shape
    xb = x.reshape(B, C, H // b, b, W // b, b)
    return jnp.max(jnp.abs(xb), axis=(3, 5))


def _block_reduce_max_bsd(x: jax.Array, bs: int, bc: int) -> jax.Array:
    """(B,S,D) -> per-block max (B,S//bs,D//bc)."""
    B, S, D = x.shape
    xb = x.reshape(B, S // bs, bs, D // bc, bc)
    return jnp.max(jnp.abs(xb), axis=(2, 4))


def _expand_mask_nchw(mask_blocks: jax.Array, b: int) -> jax.Array:
    m = jnp.repeat(mask_blocks, b, axis=2)
    return jnp.repeat(m, b, axis=3)


def _expand_mask_bsd(mask_blocks: jax.Array, bs: int, bc: int) -> jax.Array:
    m = jnp.repeat(mask_blocks, bs, axis=1)
    return jnp.repeat(m, bc, axis=2)


def _apply_gate(x: jax.Array, keep: jax.Array, blockmax: jax.Array,
                thr: jax.Array, cfg: ZebraConfig, expand,
                surrogate_only: bool = False) -> jax.Array:
    """Gate x by the block keep-mask under the configured gradient mode.

    ``surrogate_only`` (constant-threshold / deployment-matched training):
    the *value* is always the deployed hard mask — the gradient mode only
    picks the backward surrogate, so the train-time gating function is
    exactly the inference comparator (and exactly what the kernel
    backends' custom_vjp computes, see ``kernels.grad``).
    """
    if cfg.grad_mode == "soft" and cfg.mode == "train":
        gate = jax.nn.sigmoid((blockmax - thr) / cfg.soft_temp)
        if surrogate_only:
            # value: hard mask; dy/dx: the sigmoid surrogate gate
            mask = expand(jax.lax.stop_gradient(keep)).astype(x.dtype)
            ge = expand(jax.lax.stop_gradient(gate)).astype(x.dtype)
            return x * ge + jax.lax.stop_gradient(x * mask - x * ge)
        return x * expand(gate).astype(x.dtype)
    mask = expand(jax.lax.stop_gradient(keep)).astype(x.dtype)
    y = x * mask
    if cfg.grad_mode == "ste" and cfg.mode == "train":
        # value: masked; gradient wrt x: identity (lets pruned blocks recover)
        y = y + (x - jax.lax.stop_gradient(x)) * (1.0 - mask)
    return y


def _reg_loss(thr: jax.Array, t_obj: float) -> jax.Array:
    """Σ_c ||T_obj − T_c||², averaged over the batch dim (Eq. 1 second term)."""
    per_sample = jnp.sum(jnp.square(t_obj - thr.astype(jnp.float32)), axis=-1)
    return jnp.mean(per_sample)


def effective_tnet(cfg: ZebraConfig, tnet):
    """``use_tnet=False`` is authoritative: gate with the constant T_obj
    even if legacy net params are passed (their Eq. 1 L2 term is excluded
    from the loss in that mode, so gating with them would silently train
    un-regularized thresholds)."""
    return tnet if cfg.use_tnet else None


def require_tnet(cfg: ZebraConfig, tnet, site: str = "") -> None:
    """Train mode with ``use_tnet=True`` must receive threshold-net params:
    silently training the constant-T_obj gate instead would change the
    objective. The ONE guard shared by zebra_cnn/zebra_tokens and the
    engine."""
    if cfg.mode == "train" and tnet is None and cfg.use_tnet:
        at = f" at site {site!r}" if site else ""
        raise ValueError(
            f"train mode expects threshold-net params{at} (use_tnet=True); "
            f"pass tnet, or set use_tnet=False for constant-threshold "
            f"(kernel-trainable) training")


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def zebra_cnn(x: jax.Array, cfg: ZebraConfig, tnet: dict | None = None) -> tuple[jax.Array, Aux]:
    """Zebra over a (B, C, H, W) activation map. Returns (masked x, aux).

    aux: reg (scalar), zero_frac (scalar in [0,1]), n_blocks, thresholds.
    """
    if not cfg.enabled:
        return x, {"reg": jnp.float32(0.0), "zero_frac": jnp.float32(0.0),
                   "n_blocks": 0, "thresholds": None}
    B, C, H, W = x.shape
    b = cfg.block_hw
    if H % b or W % b:
        raise ValueError(f"map {H}x{W} not divisible by block {b}")
    tnet = effective_tnet(cfg, tnet)
    require_tnet(cfg, tnet)
    blockmax = _block_reduce_max_nchw(x, b)                       # (B,C,Hb,Wb)
    surrogate_only = False
    if cfg.mode == "train" and tnet is not None:
        gap = jnp.mean(x, axis=(2, 3)).astype(jnp.float32)        # (B,C) GAP
        thr = _thresholds_from_net(tnet, gap)                     # (B,C)
        reg = _reg_loss(thr, cfg.t_obj)
        thr_b = thr[:, :, None, None].astype(blockmax.dtype)
    else:
        # infer, or constant-threshold (deployment-matched) training: the
        # deployed T_obj comparator is the gate (Fig. 3); in train mode the
        # reg slot reports the realized zero-block count (Eq. 1 observable)
        thr = jnp.full((C,), cfg.t_obj, jnp.float32)
        reg = None if cfg.mode == "train" else jnp.float32(0.0)
        thr_b = thr[None, :, None, None].astype(blockmax.dtype)
        surrogate_only = cfg.mode == "train"
    keep = (blockmax >= thr_b)
    y = _apply_gate(x, keep, blockmax, thr_b, cfg,
                    lambda m: _expand_mask_nchw(m, b), surrogate_only)
    zero_frac = 1.0 - jnp.mean(keep.astype(jnp.float32))
    n_blocks = C * (H // b) * (W // b)
    if reg is None:
        reg = jax.lax.stop_gradient(zero_frac) * n_blocks
    return y, {"reg": reg, "zero_frac": zero_frac, "n_blocks": n_blocks,
               "thresholds": thr}


def zebra_tokens(x: jax.Array, cfg: ZebraConfig, tnet: dict | None = None) -> tuple[jax.Array, Aux]:
    """Zebra over a (B, S, D) token activation map (TPU tile blocks)."""
    if not cfg.enabled:
        return x, {"reg": jnp.float32(0.0), "zero_frac": jnp.float32(0.0),
                   "n_blocks": 0, "thresholds": None}
    B, S, D = x.shape
    bs, bc = cfg.block_seq, cfg.block_ch
    if S % bs or D % bc:
        raise ValueError(f"(S={S}, D={D}) not divisible by block ({bs},{bc})")
    tnet = effective_tnet(cfg, tnet)
    require_tnet(cfg, tnet)
    blockmax = _block_reduce_max_bsd(x, bs, bc)                   # (B,Sb,Db)
    surrogate_only = False
    if cfg.mode == "train" and tnet is not None:
        gap = jnp.mean(jnp.abs(x), axis=1).astype(jnp.float32)    # (B,D) GAP
        thr_ch = _thresholds_from_net(tnet, gap)                  # (B,Db)
        reg = _reg_loss(thr_ch, cfg.t_obj)
        thr_b = thr_ch[:, None, :].astype(blockmax.dtype)         # (B,1,Db)
    else:
        # infer, or constant-threshold (deployment-matched) training
        reg = None if cfg.mode == "train" else jnp.float32(0.0)
        thr_b = jnp.asarray(cfg.t_obj, blockmax.dtype)
        thr_ch = None
        surrogate_only = cfg.mode == "train"
    keep = (blockmax >= thr_b)
    y = _apply_gate(x, keep, blockmax, thr_b, cfg,
                    lambda m: _expand_mask_bsd(m, bs, bc), surrogate_only)
    zero_frac = 1.0 - jnp.mean(keep.astype(jnp.float32))
    n_blocks = (S // bs) * (D // bc)
    if reg is None:
        reg = jax.lax.stop_gradient(zero_frac) * n_blocks
    return y, {"reg": reg, "zero_frac": zero_frac, "n_blocks": n_blocks,
               "thresholds": thr_ch}


def zebra_infer_bitmap_nchw(x: jax.Array, cfg: ZebraConfig) -> tuple[jax.Array, jax.Array]:
    """Inference helper: (masked x, keep-bitmap) for hardware-style storage.

    Like ``zebra_cnn``, ``cfg.enabled=False`` is a passthrough: x unchanged,
    every block kept (all-ones bitmap).
    """
    b = cfg.block_hw
    B, C, H, W = x.shape
    if not cfg.enabled:
        return x, jnp.ones((B, C, H // b, W // b), bool)
    blockmax = _block_reduce_max_nchw(x, b)
    keep = blockmax >= jnp.asarray(cfg.t_obj, blockmax.dtype)
    y = x * _expand_mask_nchw(keep, b).astype(x.dtype)
    return y, keep


def zebra_infer_bitmap_tokens(x: jax.Array, cfg: ZebraConfig) -> tuple[jax.Array, jax.Array]:
    bs, bc = cfg.block_seq, cfg.block_ch
    B, S, D = x.shape
    if not cfg.enabled:
        return x, jnp.ones((B, S // bs, D // bc), bool)
    blockmax = _block_reduce_max_bsd(x, bs, bc)
    keep = blockmax >= jnp.asarray(cfg.t_obj, blockmax.dtype)
    y = x * _expand_mask_bsd(keep, bs, bc).astype(x.dtype)
    return y, keep


def collect_zebra_loss(auxes: list[Aux]) -> jax.Array:
    """Σ_{l} reg_l — the second term of Eq. 1 across all Zebra sites."""
    regs = [a["reg"] for a in auxes if a.get("reg") is not None]
    return jnp.sum(jnp.stack(regs)) if regs else jnp.float32(0.0)


def mean_zero_frac(auxes: list[Aux]) -> jax.Array:
    """Block-count-weighted mean zero-block fraction across sites."""
    num, den = jnp.float32(0.0), 0.0
    for a in auxes:
        nb = float(a.get("n_blocks", 0) or 0)
        if nb:
            num = num + a["zero_frac"] * nb
            den += nb
    return num / den if den else jnp.float32(0.0)
