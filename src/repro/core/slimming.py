"""Network Slimming (Liu et al., ICCV'17) — the structured-pruning partner
method the paper composes Zebra with (Tables II-IV).

Procedure (faithful):
 1. *Sparsity training*: add L1 penalty ``rho * Σ|γ|`` on every BatchNorm
    scale γ to the loss.
 2. *Slim*: rank all γ globally by magnitude, zero the channels whose γ
    falls in the bottom ``prune_frac`` percentile (per-layer channel masks).
 3. *Retrain* with the masks fixed (here: together with Zebra).

We prune by masking (γ, β and the channel's outgoing activation) rather
than physically re-shaping weights — computationally identical for
accuracy, keeps residual shapes intact, and the bandwidth accounting
counts masked channels as removed maps (their blocks are all-zero).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..utils import PyTree


def gamma_l1(params: PyTree) -> jax.Array:
    """Σ |γ| over every BatchNorm in the tree (keys named 'scale' under 'bn*')."""
    total = jnp.float32(0.0)
    for path, leaf in jax.tree_util.tree_leaves_with_path(params):
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if any(str(n).startswith("bn") for n in names) and str(names[-1]) == "scale":
            total = total + jnp.sum(jnp.abs(leaf.astype(jnp.float32)))
    return total


def collect_gammas(params: PyTree) -> list[tuple[tuple, jax.Array]]:
    out = []
    for path, leaf in jax.tree_util.tree_leaves_with_path(params):
        names = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
        if any(n.startswith("bn") for n in names) and names[-1] == "scale":
            out.append((tuple(names), leaf))
    return out


def global_threshold(params: PyTree, prune_frac: float) -> float:
    """Magnitude cut so that `prune_frac` of all BN channels fall below it."""
    gammas = collect_gammas(params)
    if not gammas:
        return 0.0
    allg = jnp.concatenate([jnp.abs(g.reshape(-1)) for _, g in gammas])
    return float(jnp.quantile(allg.astype(jnp.float32), prune_frac))


def channel_masks(params: PyTree, prune_frac: float) -> dict[tuple, jax.Array]:
    """path-names -> keep mask (1.0 keep / 0.0 prune) per BN scale tensor."""
    thr = global_threshold(params, prune_frac)
    return {names: (jnp.abs(g) > thr).astype(jnp.float32)
            for names, g in collect_gammas(params)}


def apply_masks(params: PyTree, masks: dict[tuple, jax.Array]) -> PyTree:
    """Multiply γ and β of pruned channels by 0 (channel output ≡ BN bias 0)."""
    def fix(path, leaf):
        names = tuple(str(getattr(p, "key", getattr(p, "name", ""))) for p in path)
        if names in masks:
            return leaf * masks[names].astype(leaf.dtype)
        if names[:-1] + ("scale",) in masks and names[-1] == "bias":
            return leaf * masks[names[:-1] + ("scale",)].astype(leaf.dtype)
        return leaf
    return jax.tree_util.tree_map_with_path(fix, params)


def pruned_channel_frac(masks: dict[tuple, jax.Array]) -> float:
    tot = sum(int(m.size) for m in masks.values())
    kept = sum(float(jnp.sum(m)) for m in masks.values())
    return 1.0 - kept / max(tot, 1)
