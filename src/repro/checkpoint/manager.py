"""Fault-tolerant checkpointing (DESIGN.md §5).

Format: one ``.npz`` per host shard holding flattened leaves keyed by
path-string, plus ``manifest.json`` (step, pytree structure, leaf paths,
host count). Writes go to ``<dir>/tmp.<step>`` then ``os.replace`` to
``<dir>/step_<step>`` — atomic on POSIX, so a job killed mid-save never
corrupts the restore point. ``keep_last`` old checkpoints are retained.

Async mode ships the device->host copy synchronously (cheap) and the disk
write on a background thread so the train loop isn't blocked (the thread is
joined before the next save or at close).

Integrity: the manifest carries a CRC32 per leaf, computed from the host
buffers at save time. ``restore`` re-hashes every leaf before handing the
tree back — ``np.savez`` stores leaves *uncompressed*, so a flipped byte
on disk loads "successfully" as silently-wrong weights; only the CRC sees
it. A corrupt/truncated newest checkpoint makes ``restore`` fall back to
the next-older step (the whole point of ``keep_last > 1``), raising
``ft.faults.CorruptStream`` only when the entire chain is bad. Manifests
from before this scheme (no ``checksums`` key) restore as before.
"""
from __future__ import annotations

import json
import logging
import os
import shutil
import threading
import zlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

_log = logging.getLogger("repro.checkpoint")

PyTree = Any
_SEP = "/"


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = _SEP.join(str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p)))) for p in path)
        arr = np.asarray(leaf)
        _NATIVE = (np.float64, np.float32, np.float16, np.int64, np.int32,
                   np.int16, np.int8, np.uint8, np.uint16, np.uint32,
                   np.uint64, np.bool_)
        if arr.dtype not in _NATIVE:              # bf16 etc: not npz-native
            arr = arr.astype(np.float32)          # load casts back via `like`
        flat[key] = arr
    return flat


def _treedef_paths(tree: PyTree) -> list[str]:
    return sorted(_flatten(tree).keys())


def save_pytree(path: str, tree: PyTree, host_id: int = 0) -> None:
    flat = _flatten(tree)
    os.makedirs(path, exist_ok=True)
    np.savez(os.path.join(path, f"shard_{host_id}.npz"), **flat)


def load_pytree(path: str, like: PyTree, host_id: int = 0) -> PyTree:
    data = np.load(os.path.join(path, f"shard_{host_id}.npz"))
    paths_and_leaves = jax.tree_util.tree_leaves_with_path(like)
    treedef = jax.tree_util.tree_structure(like)
    leaves = []
    for p, leaf in paths_and_leaves:
        key = _SEP.join(str(getattr(q, "key", getattr(q, "name", getattr(q, "idx", q)))) for q in p)
        arr = data[key]
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _stream_layout(shape: tuple[int, ...], bs: int, bc: int,
                   block_hw: int) -> tuple[tuple[int, int], int, int] | None:
    """Pick the engine tile-grid view for one map. 4-D NCHW maps use the
    paper's spatial ``b x b`` block layout (core.engine.nchw_stream_dims)
    — the blocks a Zebra CNN site actually zeroed — before anything else;
    other maps use the token layout ``(..., K)`` with (bs, bc) tiles when
    it divides. None = store dense."""
    from ..core.engine import nchw_stream_dims

    nchw = nchw_stream_dims(shape, block_hw)
    if nchw is not None:
        m, k, b = nchw
        if b > 1 or block_hw == 1:
            return (m, k), b, b
    flat_k = shape[-1] if len(shape) >= 2 else 0
    flat_m = int(np.prod(shape[:-1])) if len(shape) >= 2 else 0
    if flat_m and flat_m % bs == 0 and flat_k % bc == 0:
        return (flat_m, flat_k), bs, bc
    return None


def save_compressed_acts(path: str, acts: dict[str, Any], bs: int = 8,
                         bc: int = 128, block_hw: int = 4) -> dict:
    """Persist activation maps as compressed streams in one .npz.

    Per map ``name``: ``<name>/payload`` (live blocks only — the trim is
    what makes the file small), ``<name>/index`` (packed bitmap) and
    ``<name>/meta`` = [*shape, m, k, bs, bc]. Block layout follows the
    site engine: token maps tile ``(..., K)`` with (bs, bc); 4-D NCHW maps
    fall back to the paper's spatial ``block_hw x block_hw`` blocks
    flattened onto the same tile grid (so CNN maps compress too). Maps
    fitting neither are stored dense under ``<name>/dense``.
    Returns per-map {dense_bytes, stored_bytes}."""
    from ..compress.stream import compress

    arrs: dict[str, np.ndarray] = {}
    stats: dict[str, dict] = {}
    for name, x in acts.items():
        xa = np.asarray(x)
        layout = _stream_layout(tuple(xa.shape), bs, bc, block_hw)
        if layout is None or (
                xa.dtype not in (np.float32, np.float16) and
                xa.dtype.name != "bfloat16"):  # f64 would downcast via jnp
            arrs[f"{name}/dense"] = xa
            stats[name] = {"dense_bytes": xa.nbytes, "stored_bytes": xa.nbytes}
            continue
        (m_dim, k_dim), ebs, ebc = layout
        cm = compress(jnp.asarray(xa).reshape(m_dim, k_dim), bs=ebs, bc=ebc,
                      use_kernel=False)
        n_live = int(cm.n_live)
        payload = np.asarray(cm.payload)[:n_live]          # the actual trim
        index = np.asarray(cm.index)
        arrs[f"{name}/dtype"] = np.asarray(payload.dtype.name)
        if payload.dtype.name == "bfloat16":               # not npz-native
            payload = payload.view(np.uint16)
        arrs[f"{name}/payload"] = payload
        arrs[f"{name}/index"] = index
        arrs[f"{name}/meta"] = np.asarray(
            [*xa.shape, cm.m, cm.k, ebs, ebc], np.int64)
        stats[name] = {"dense_bytes": xa.nbytes,
                       "stored_bytes": payload.nbytes + index.nbytes}
    np.savez(path, **arrs)
    return stats


def load_compressed_acts(path: str,
                         validation: str = "off") -> dict[str, np.ndarray]:
    """Inverse of save_compressed_acts: dense maps, bit-exact.

    ``validation`` (``compress.integrity`` level) checks each stream's
    wire contract before expansion — a flipped on-disk index bit would
    otherwise silently relocate every later payload block. Raises
    ``ft.faults.CorruptStream`` naming the map and invariant."""
    from ..compress.integrity import validate_map
    from ..compress.stream import CompressedMap, decompress

    data = np.load(path)
    out: dict[str, np.ndarray] = {}
    for key in data.files:
        if "/" not in key:                 # save_acts(compressed=False) keys
            out[key] = data[key]
            continue
        name, kind = key.rsplit("/", 1)
        if kind == "dense":
            out[name] = data[key]
        elif kind == "payload":
            meta = data[f"{name}/meta"]
            m, k, bs, bc = (int(v) for v in meta[-4:])
            shape = tuple(int(v) for v in meta[:-4])
            payload = data[key]
            if str(data[f"{name}/dtype"]) == "bfloat16":
                payload = payload.view(jnp.bfloat16)
            n_blocks = (m // bs) * (k // bc)
            full = np.zeros((n_blocks, bs, bc), payload.dtype)
            full[: payload.shape[0]] = payload
            cm = CompressedMap(payload=jnp.asarray(full),
                               index=jnp.asarray(data[f"{name}/index"]),
                               n_live=jnp.int32(payload.shape[0]),
                               shape=shape, m=m, k=k, bs=bs, bc=bc)
            if validation != "off":
                validate_map(cm, level=validation, site=f"ckpt-acts:{name}")
            out[name] = np.asarray(decompress(cm, use_kernel=False))
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep_last = keep_last
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def _write(self, tmp: str, final: str, flat: dict[str, np.ndarray],
               manifest: dict) -> None:
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "shard_0.npz"), **flat)
        # leaf CRCs ride the background thread — hashing GBs of weights
        # must not block the train loop any more than the disk write does
        manifest = dict(manifest)
        manifest["checksums"] = {k: _crc(v) for k, v in flat.items()}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)          # atomic publish
        self._gc()

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: max(0, len(steps) - self.keep_last)]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # ------------------------------------------------------------------
    def save(self, step: int, tree: PyTree, extra: dict | None = None) -> None:
        self.wait()
        # device->host copy happens here, synchronously
        flat = _flatten(jax.device_get(tree))
        manifest = {"step": int(step), "paths": sorted(flat.keys()),
                    "extra": extra or {}}
        tmp = os.path.join(self.dir, f"tmp.{step}")
        final = os.path.join(self.dir, f"step_{step}")
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(tmp, final, flat, manifest), daemon=True)
            self._thread.start()
        else:
            self._write(tmp, final, flat, manifest)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                if os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                    out.append(int(name.split("_", 1)[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------------
    # Zebra-masked activation maps, persisted in compressed stream form
    # (README.md §Compressed activation transport): payload trimmed to
    # n_live blocks + packed 1-bit index, so the on-disk size tracks
    # stored_bits(), not the dense map size.
    def save_acts(self, step: int, acts: dict[str, Any],
                  compressed: bool = True, bs: int = 8, bc: int = 128,
                  block_hw: int = 4) -> dict:
        path = os.path.join(self.dir, f"acts_{step}.npz")
        if not compressed:
            arrs = {name: np.asarray(x) for name, x in acts.items()}
            np.savez(path, **arrs)
            return {name: {"dense_bytes": a.nbytes, "stored_bytes": a.nbytes}
                    for name, a in arrs.items()}
        return save_compressed_acts(path, acts, bs=bs, bc=bc, block_hw=block_hw)

    def restore_acts(self, step: int,
                     validation: str = "structural") -> dict[str, np.ndarray]:
        path = os.path.join(self.dir, f"acts_{step}.npz")
        return load_compressed_acts(path, validation=validation)

    # ------------------------------------------------------------------
    def verify(self, step: int) -> dict:
        """Check one checkpoint end-to-end (readable manifest, leaf set
        matches, every leaf CRC matches) and return its manifest. Raises
        ``ft.faults.CorruptStream`` naming what failed. Pre-checksum
        manifests verify structurally only."""
        from ..ft.faults import CorruptStream
        path = os.path.join(self.dir, f"step_{step}")
        try:
            with open(os.path.join(path, "manifest.json")) as f:
                manifest = json.load(f)
            data = np.load(os.path.join(path, "shard_0.npz"))
            keys = set(data.files)
        except CorruptStream:
            raise
        except Exception as e:  # truncated zip/json, missing files, ...
            raise CorruptStream(
                f"ckpt step_{step}: unreadable ({type(e).__name__}: {e})"
            ) from e
        paths = manifest.get("paths")
        if paths is not None and set(paths) != keys:
            raise CorruptStream(
                f"ckpt step_{step}: leaf set mismatch — manifest lists "
                f"{len(paths)} leaves, shard holds {len(keys)}")
        sums = manifest.get("checksums")
        if sums:
            for k in sorted(keys):
                try:
                    got = _crc(data[k])
                except Exception as e:  # zip-member CRC/truncation on read
                    raise CorruptStream(
                        f"ckpt step_{step}: leaf {k!r} unreadable "
                        f"({type(e).__name__}: {e})") from e
                want = int(sums.get(k, got))
                if got != want:
                    raise CorruptStream(
                        f"ckpt step_{step}: leaf {k!r} CRC mismatch "
                        f"(manifest {want:#010x}, on-disk {got:#010x})")
        return manifest

    def restore(self, like: PyTree, step: int | None = None,
                verify: bool = True) -> tuple[int, PyTree, dict]:
        """Restore the newest VERIFIED checkpoint (or the explicit
        ``step``). A corrupt candidate falls back to the next-older step
        with a warning; an explicitly requested step never falls back."""
        from ..ft.faults import CorruptStream
        self.wait()
        candidates = [step] if step is not None else \
            list(reversed(self.all_steps()))
        if not candidates:
            raise FileNotFoundError(f"no checkpoint under {self.dir}")
        last: Exception | None = None
        for s in candidates:
            path = os.path.join(self.dir, f"step_{s}")
            try:
                if verify:
                    manifest = self.verify(s)
                else:
                    with open(os.path.join(path, "manifest.json")) as f:
                        manifest = json.load(f)
                tree = load_pytree(path, like)
                return s, tree, manifest.get("extra", {})
            except Exception as e:  # noqa: BLE001 — chain fallback below
                if step is not None or isinstance(e, KeyboardInterrupt):
                    raise
                _log.warning("ckpt step_%s failed to restore (%s); falling "
                             "back to older step", s, e)
                last = e
        raise CorruptStream(
            f"no restorable checkpoint under {self.dir}: all of "
            f"{candidates} failed verification") from last
