from .manager import (CheckpointManager, save_pytree, load_pytree,  # noqa: F401
                      save_compressed_acts, load_compressed_acts)
