"""Quickstart: train ResNet-18 with Zebra on procedural CIFAR-10, watch the
thresholds converge to T_obj and the activation-bandwidth saving appear.

    PYTHONPATH=src python examples/quickstart.py [--steps 200] [--t-obj 0.2]
"""
import argparse

from repro.core import ZebraConfig
from repro.data import ImageDatasetConfig
from repro.optim import sgd, step_decay
from repro.train import CNNTrainer, CNNTrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--t-obj", type=float, default=0.2)
    ap.add_argument("--model", default="resnet18")
    ap.add_argument("--width", type=float, default=0.25)
    args = ap.parse_args()

    cfg = CNNTrainConfig(
        model=args.model, width_mult=args.width,
        dataset=ImageDatasetConfig("syn-cifar10", 10, 32),
        batch=48, steps=args.steps,
        zebra=ZebraConfig(t_obj=args.t_obj, block_hw=4))
    tr = CNNTrainer(cfg, sgd(step_decay(0.05, total_steps=args.steps)))

    print(f"training {args.model} w/ Zebra(T_obj={args.t_obj}) "
          f"for {args.steps} steps...")
    state, hist = tr.train(log_every=25, callback=lambda m: print(
        f"  step {m['step']:4d} loss={m['loss']:.3f} ce={m['ce']:.3f} "
        f"zebra_reg={m['zebra_reg']:.4f} zero_blocks={m['zero_frac']*100:.1f}%"))

    ev = tr.evaluate(state["variables"], batches=4)
    print("\n== inference with threshold net removed (T = T_obj, paper Fig.3) ==")
    print(f"accuracy           : {ev['acc']*100:.2f}% (top5 {ev['top5']*100:.2f}%)")
    print(f"zero-block fraction: {ev['zero_frac']*100:.1f}%")
    print(f"reduced bandwidth  : {ev['reduced_bandwidth_pct']:.1f}% "
          f"(paper Table II: 33.5% @ T_obj=0.1 for ResNet-18)")


if __name__ == "__main__":
    main()
