"""Zebra + Network Slimming / Weight Pruning combination (paper §III.A,
Tables II & IV): sparsity-train BN gammas, slim 20% of channels, retrain
with Zebra; compare against Zebra alone and WP+Zebra.

    PYTHONPATH=src python examples/pruning_combo.py
"""
from repro.core import ZebraConfig
from repro.data import ImageDatasetConfig
from repro.optim import sgd, step_decay
from repro.train import CNNTrainer, CNNTrainConfig

STEPS = 150


def run(tag, ns_rho=0.0, prune=None, frac=0.2):
    cfg = CNNTrainConfig(model="resnet18", width_mult=0.25,
                         dataset=ImageDatasetConfig("syn-cifar10", 10, 32),
                         batch=48, steps=STEPS, ns_rho=ns_rho,
                         zebra=ZebraConfig(t_obj=0.2, block_hw=4))
    tr = CNNTrainer(cfg, sgd(step_decay(0.05, total_steps=STEPS)))
    state, _ = tr.train(log_every=STEPS)
    if prune == "ns":
        pf = tr.apply_network_slimming(state["variables"], frac)
        state, _ = tr.train(steps=STEPS // 2, state=state, log_every=STEPS)
        print(f"  [{tag}] slimmed {pf*100:.1f}% of channels, retrained")
    elif prune == "wp":
        pf = tr.apply_weight_pruning(state["variables"], frac)
        state, _ = tr.train(steps=STEPS // 2, state=state, log_every=STEPS)
        print(f"  [{tag}] pruned {pf*100:.1f}% of weights, retrained")
    ev = tr.evaluate(state["variables"], batches=3)
    print(f"  [{tag}] acc={ev['acc']*100:.2f}% "
          f"reduced_bw={ev['reduced_bandwidth_pct']:.1f}%")
    return ev


def main():
    print("== Zebra alone ==")
    run("zebra")
    print("== Zebra + Network Slimming (20%) ==")
    run("zebra+ns", ns_rho=1e-4, prune="ns")
    print("== Zebra + Weight Pruning (20%) ==")
    run("zebra+wp", prune="wp")


if __name__ == "__main__":
    main()
