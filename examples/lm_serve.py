"""End-to-end serving driver: build a ~100M-param gemma3-family model,
prefill a batch of prompts and decode with the sharded KV cache + Zebra
KV-cache block compression (the decode-bandwidth analogue of the paper).

    PYTHONPATH=src python examples/lm_serve.py [--batch 4] [--gen 24]

This drives exactly the production `repro.launch.serve` path.
"""
import argparse
import sys

from repro.launch import serve as serve_mod
import repro.configs as configs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--use-kernel", action="store_true",
                    help="compressed activation transport (Pallas pack/unpack"
                         " + measured-bytes accounting)")
    args = ap.parse_args()

    # ~100M-class member of the gemma3 family (6 layers of the 5:1 pattern)
    base = configs.get("gemma3-4b")
    cfg = base.replace(n_layers=6, d_model=512, n_heads=8, n_kv_heads=4,
                       d_ff=1536, vocab=32768, window=64, attn_chunk=64)
    n = cfg.param_counts()["total"]
    print(f"serving {cfg.name}-mini: {n/1e6:.1f}M params "
          f"(pattern {cfg.layer_pattern})")

    import types
    mod = types.SimpleNamespace(CONFIG=cfg, reduced=lambda: cfg)
    configs._ARCH_MODULES["gemma3-mini"] = "gemma3_4b"
    orig = configs._mod
    configs._mod = lambda a: mod if a == "gemma3-mini" else orig(a)

    sys.argv = ["serve", "--arch", "gemma3-mini", "--reduced",
                "--batch", str(args.batch), "--prompt-len", str(args.prompt_len),
                "--gen", str(args.gen), "--t-obj", "0.05"]
    if args.use_kernel:
        sys.argv.append("--use-kernel")
    serve_mod.main()


if __name__ == "__main__":
    main()
