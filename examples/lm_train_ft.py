"""Fault-tolerant LM training demo: trains a reduced MoE arch with the
production launcher (sharded jit + Zebra FFN sites + async checkpoints),
kills itself at step 15, then resumes from the checkpoint — no sample is
replayed thanks to the counter-indexed data stream.

    PYTHONPATH=src python examples/lm_train_ft.py
"""
import shutil
import subprocess
import sys

CKPT = "/tmp/repro_ft_demo"


def launch(steps):
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch",
         "granite-moe-1b-a400m", "--reduced", "--steps", str(steps),
         "--batch", "8", "--seq", "64", "--ckpt", CKPT,
         "--ckpt-every", "10"],
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        capture_output=True, text=True)


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    print("== phase 1: train 15 steps (checkpoint every 10), then 'crash' ==")
    r = launch(15)
    print(r.stdout[-800:])
    print("== phase 2: relaunch — auto-resumes from step >= 10 ==")
    r = launch(30)
    assert "start_step=1" in r.stdout or "start_step=" in r.stdout
    print(r.stdout[-800:])
    start = [l for l in r.stdout.splitlines() if "start_step" in l]
    print("resume line:", start[0] if start else "?")


if __name__ == "__main__":
    main()
