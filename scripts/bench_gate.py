#!/usr/bin/env python
"""Perf-trajectory gate: fail CI when a fresh benchmark run drifts from
the committed BENCH_*.json baselines.

Two classes of check, per row matched by ``name`` across baseline and
fresh (the intersection must be non-empty per file):

* **exact**: byte-accounting columns (``stream_bytes``,
  ``measured_bytes``, ``dense_bytes``, ``index_bytes``) must match the
  baseline bit for bit — the compressed stream length is a correctness
  observable (paper Eq. 2/3), not a performance number, so ANY drift is
  a bug, not noise.
* **bounded**: ``us_per_call`` may regress to at most
  ``tol * baseline + slack`` (defaults 3.0x + 5000 us — generous,
  because CI containers share cores and sub-millisecond interpret-mode
  rows swing 2-3x run to run on a loaded machine; the absolute slack
  keeps micro-rows from flapping while still catching the
  order-of-magnitude regressions this gate exists for). Rows faster
  than 50 us are exempt entirely (pure-overhead rows where scheduler
  jitter exceeds the signal).

Baseline-schema tolerance: the committed baseline may predate rows or
columns a new bench version added. Fresh-only rows are reported as
"seeding" (they enter the baseline when the fresh artifacts are
committed), baseline-only rows as a warning (a rename or a removed
bench — deliberate removals just need the baseline regenerated), and
exact-key comparison only applies to keys present on BOTH sides. None
of these fail the gate; byte drift and latency regression on rows
present in both always do.

Two absolute checks ride on the fresh artifacts independent of any
baseline: the ``kernel/zebra_spmm`` and ``kernel/spmm_cs.fused`` rows
of ``BENCH_kernels.json`` must report ``speedup_vs_dense > 1`` — the
compressed consumer beating the dense matmul at the ~64%-zeros
operating point is the acceptance bar of the consumer rearchitecture,
and a missing row/column is itself a failure — and every
``*.compressed`` row of ``BENCH_collectives.json`` must report
``ici_bytes == ici_predicted_bytes`` exactly (Eq. 2/3 carried onto the
interconnect) with ``ici_bytes < ici_dense_bytes``.

Usage:
    python scripts/bench_gate.py --baseline DIR --fresh DIR \
        [--tol 3.0] [--slack-us 5000]

Exit 0 = gate green; exit 1 = drift/regression with a per-row report.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

FILES = ("BENCH_kernels.json", "BENCH_bandwidth.json", "BENCH_train.json",
         "BENCH_collectives.json", "BENCH_faults.json", "BENCH_serve.json",
         "BENCH_serve_chaos.json")
EXACT_KEYS = ("stream_bytes", "measured_bytes", "dense_bytes", "index_bytes",
              "ici_bytes", "ici_dense_bytes", "ici_predicted_bytes",
              "kv_bytes_measured", "kv_bytes_dense", "kv_pages")
US_EXEMPT_BELOW = 50.0

# rows of the fresh BENCH_kernels.json that must beat dense (the
# consumer-rearchitecture acceptance bar; checked baseline or not)
SPEEDUP_ROWS = ("kernel/zebra_spmm", "kernel/spmm_cs.fused")

# NOTE on removed columns: the deprecated `speedup_vs_ref` alias on
# kernel/zebra_spmm is gone from fresh runs. Old baselines still carrying
# it are tolerated automatically — it was never an EXACT_KEY, and exact
# comparison only applies to keys present on BOTH sides.


def _rows(path: str) -> dict[str, dict]:
    with open(path) as f:
        doc = json.load(f)
    return {r["name"]: r for r in doc["rows"]}


def gate_file(base_path: str, fresh_path: str, tol: float,
              slack_us: float) -> list[str]:
    errors = []
    base = _rows(base_path)
    fresh = _rows(fresh_path)
    shared = sorted(set(base) & set(fresh))
    fname = os.path.basename(fresh_path)
    if not shared:
        return [f"{fname}: no row names shared with "
                f"the baseline — the bench was renamed without regenerating "
                f"the committed baseline"]
    # schema tolerance: new rows seed the trajectory, vanished rows warn
    for name in sorted(set(fresh) - set(base)):
        print(f"bench_gate: {fname}: {name}: new row (not in baseline) — "
              f"seeding, will be gated once committed")
    for name in sorted(set(base) - set(fresh)):
        print(f"bench_gate: {fname}: WARNING: baseline row {name} missing "
              f"from the fresh run (renamed or removed bench? regenerate "
              f"the baseline if deliberate)")
    for name in shared:
        b, f = base[name], fresh[name]
        # exact keys compare only where BOTH sides have them: a baseline
        # predating a newly added column must not fail the gate
        for key in EXACT_KEYS:
            if key in b and key in f and b[key] != f[key]:
                errors.append(
                    f"{name}: {key} drifted {b[key]} -> {f[key]} (byte "
                    f"accounting is exact — this is a stream-format bug, "
                    f"not noise)")
        bus, fus = b.get("us_per_call", 0.0), f.get("us_per_call", 0.0)
        if bus >= US_EXEMPT_BELOW and fus > tol * bus + slack_us:
            errors.append(
                f"{name}: us_per_call regressed {bus:.1f} -> {fus:.1f} "
                f"(> {tol:g}x + {slack_us:g} us tolerance)")
    return errors


def gate_speedup(fresh_path: str) -> list[str]:
    """Absolute acceptance check on the fresh kernels artifact: the
    compressed consumers must beat their dense baselines (the reason the
    consumer-order payload + static prefetch schedule exist). No
    baseline involvement — a fresh run that loses to dense is a
    regression even on a machine with no committed trajectory."""
    try:
        fresh = _rows(fresh_path)
    except (FileNotFoundError, json.JSONDecodeError, KeyError):
        return [f"{os.path.basename(fresh_path)}: unreadable — cannot check "
                f"the speedup_vs_dense acceptance rows"]
    errors = []
    for name in SPEEDUP_ROWS:
        r = fresh.get(name)
        if r is None:
            errors.append(f"{name}: row missing from the fresh "
                          f"BENCH_kernels.json (bench renamed?)")
            continue
        if "speedup_vs_dense" not in r:
            errors.append(f"{name}: speedup_vs_dense column missing (the "
                          f"bench must emit the dense-baseline ratio)")
            continue
        s = float(r["speedup_vs_dense"])
        if not s > 1.0:
            errors.append(
                f"{name}: speedup_vs_dense = {s:g} <= 1 — the compressed "
                f"consumer lost to the dense matmul at zero_frac "
                f"{r.get('zero_frac', '?')}")
    return errors


def gate_collectives(fresh_path: str) -> list[str]:
    """Absolute acceptance check on the fresh collectives artifact (no
    baseline involvement): every compressed row's measured interconnect
    bytes must equal the Eq. 2/3 analytic prediction EXACTLY (byte
    accounting is a correctness observable), and must be strictly below
    the dense-equivalent bytes — the paper's claim carried onto the wire
    at the ~64%-zeros operating point. A missing artifact is fine (the
    bench needs a forced 8-device mesh and may not have run); a present
    artifact with no compressed rows is a failure."""
    if not os.path.exists(fresh_path):
        print("bench_gate: no fresh BENCH_collectives.json — skipping the "
              "interconnect-byte acceptance check (multi-device shard "
              "not run)")
        return []
    try:
        fresh = _rows(fresh_path)
    except (json.JSONDecodeError, KeyError):
        return [f"{os.path.basename(fresh_path)}: unreadable — cannot check "
                f"the interconnect-byte acceptance rows"]
    errors = []
    comp = {n: r for n, r in fresh.items() if n.endswith(".compressed")}
    if not comp:
        return [f"{os.path.basename(fresh_path)}: no *.compressed rows — "
                f"the bench emitted nothing to accept"]
    for name, r in sorted(comp.items()):
        missing = [k for k in ("ici_bytes", "ici_dense_bytes",
                               "ici_predicted_bytes") if k not in r]
        if missing:
            errors.append(f"{name}: byte columns missing: {missing}")
            continue
        moved, dense, pred = (int(r["ici_bytes"]), int(r["ici_dense_bytes"]),
                              int(r["ici_predicted_bytes"]))
        if moved != pred:
            errors.append(
                f"{name}: ici_bytes {moved} != predicted {pred} (Eq. 2/3 "
                f"accounting is exact — stream-format bug, not noise)")
        if not moved < dense:
            errors.append(
                f"{name}: ici_bytes {moved} >= dense {dense} — the "
                f"compressed exchange moved no fewer bytes than dense at "
                f"zero_frac {r.get('zero_frac', '?')}")
    return errors


def gate_faults(fresh_path: str) -> list[str]:
    """Absolute acceptance check on the fresh faults artifact (no
    baseline involvement): every ``faults/detect.*`` row of the chaos
    matrix must report ``detected == injected`` (100% detection across
    the boundary x fault-class pairs) and ``recovered == 1`` (the
    per-class policy restored a correct output), and the
    ``faults/validate.*`` overhead rows must carry one identical
    ``stream_bytes`` across all three levels — validation must never
    change what the wire carries. A missing artifact is fine (the bench
    needs a forced 8-device mesh for its ring boundary and may not have
    run); a present artifact with no detect rows is a failure."""
    if not os.path.exists(fresh_path):
        print("bench_gate: no fresh BENCH_faults.json — skipping the "
              "chaos-matrix acceptance check (chaos shard not run)")
        return []
    try:
        fresh = _rows(fresh_path)
    except (json.JSONDecodeError, KeyError):
        return [f"{os.path.basename(fresh_path)}: unreadable — cannot check "
                f"the chaos-matrix acceptance rows"]
    errors = []
    detect = {n: r for n, r in fresh.items()
              if n.startswith("faults/detect.")}
    if not detect:
        return [f"{os.path.basename(fresh_path)}: no faults/detect.* rows — "
                f"the chaos matrix emitted nothing to accept"]
    for name, r in sorted(detect.items()):
        missing = [k for k in ("injected", "detected", "recovered")
                   if k not in r]
        if missing:
            errors.append(f"{name}: chaos columns missing: {missing}")
            continue
        if int(r["detected"]) != int(r["injected"]):
            errors.append(
                f"{name}: detected {r['detected']} != injected "
                f"{r['injected']} — a fault class slipped past its "
                f"boundary's validation level")
        if int(r["recovered"]) != 1:
            errors.append(
                f"{name}: recovered = {r['recovered']} — the "
                f"{r.get('policy', '?')} policy did not restore a correct "
                f"output")
    sb = {int(r["stream_bytes"]) for n, r in fresh.items()
          if n.startswith("faults/validate.") and "stream_bytes" in r}
    if len(sb) > 1:
        errors.append(
            f"faults/validate.*: stream_bytes differ across validation "
            f"levels {sorted(sb)} — turning validation on changed the wire")
    # structural validation must stay a bounded fraction of the pipeline
    # (measured ~1.3x; the 3x bound is generous because both rows run in
    # the same process, so the RATIO is far more stable than either
    # absolute latency on a shared CI core)
    st = fresh.get("faults/validate.structural")
    if st is not None and float(st.get("overhead_vs_off", 0.0)) > 3.0:
        errors.append(
            f"faults/validate.structural: overhead_vs_off = "
            f"{st['overhead_vs_off']} > 3.0 — structural validation is no "
            f"longer cheap relative to the unvalidated pipeline")
    return errors


def gate_serve(fresh_path: str) -> list[str]:
    """Absolute acceptance check on the fresh serving artifact (no
    baseline involvement): the continuous-batching row must beat the
    sequential baseline by >= 2x requests/s, its measured KV stream
    bytes must reconcile with the Eq. 2/3 prediction within the per-page
    index-padding bound (kv_pages * 2 B: 1 B padding + 1 B float
    roundoff per page) while staying strictly below dense, the pool's
    zero-block fraction must sit in a wide band around the paper's 0.64
    operating point, and the decode dispatch-shape count must respect
    the engine's declared ladder bound. A missing artifact is fine (the
    serve shard may not have run); a present artifact without the
    continuous row is a failure."""
    if not os.path.exists(fresh_path):
        print("bench_gate: no fresh BENCH_serve.json — skipping the "
              "continuous-batching acceptance check (serve shard not run)")
        return []
    try:
        fresh = _rows(fresh_path)
    except (json.JSONDecodeError, KeyError):
        return [f"{os.path.basename(fresh_path)}: unreadable — cannot check "
                f"the serving acceptance rows"]
    errors = []
    r = fresh.get("serve/continuous")
    if r is None:
        return [f"{os.path.basename(fresh_path)}: serve/continuous row "
                f"missing — the bench emitted nothing to accept"]
    need = ("speedup_vs_sequential", "kv_bytes_measured",
            "kv_bytes_predicted", "kv_bytes_dense", "kv_pages",
            "zero_frac", "decode_shapes", "decode_shape_bound")
    missing = [k for k in need if k not in r]
    if missing:
        return [f"serve/continuous: columns missing: {missing}"]
    if "serve/sequential" not in fresh:
        errors.append("serve/sequential baseline row missing — the speedup "
                      "has nothing it was measured against")
    s = float(r["speedup_vs_sequential"])
    if not s >= 2.0:
        errors.append(
            f"serve/continuous: speedup_vs_sequential = {s:g} < 2.0 — "
            f"continuous batching is not paying for itself over "
            f"one-request-at-a-time serving")
    meas, pred = int(r["kv_bytes_measured"]), float(r["kv_bytes_predicted"])
    dense, pages = int(r["kv_bytes_dense"]), int(r["kv_pages"])
    if pages < 1:
        errors.append("serve/continuous: kv_pages = 0 — no KV traffic rode "
                      "the compressed pool")
    if abs(meas - pred) > pages * 2.0:
        errors.append(
            f"serve/continuous: |kv_bytes_measured {meas} - predicted "
            f"{pred:g}| > {pages} pages x 2 B — the per-request stream "
            f"bytes left the Eq. 2/3 index-padding bound")
    if not meas < dense:
        errors.append(
            f"serve/continuous: kv_bytes_measured {meas} >= dense {dense} — "
            f"paging through the pool moved no fewer bytes than dense at "
            f"zero_frac {r.get('zero_frac', '?')}")
    zf = float(r["zero_frac"])
    if not 0.40 <= zf <= 0.90:
        errors.append(
            f"serve/continuous: zero_frac = {zf:g} outside [0.40, 0.90] — "
            f"the trace is not at the paper's ~64%-zeros operating point "
            f"(recalibrate T_OBJ in benchmarks/serve_bench.py)")
    if int(r["decode_shapes"]) > int(r["decode_shape_bound"]):
        errors.append(
            f"serve/continuous: decode_shapes {r['decode_shapes']} > bound "
            f"{r['decode_shape_bound']} — the hot path compiled shapes "
            f"outside the declared ladder")
    return errors


def gate_serve_chaos(fresh_path: str) -> list[str]:
    """Absolute acceptance check on the fresh serving-resilience
    artifact (no baseline involvement): under the deterministic fault
    storm the engine must keep >= 70% of the clean run's goodput, every
    request it completes must be token-bitwise-equal to the clean run
    (``token_parity == 1`` — crash recovery resumes from paged
    compressed KV without replaying or altering generated tokens), at
    least one crash must actually have been recovered, the page
    breaker's trip count must match the count the armed plan implies
    (and be nonzero — the storm is sized to trip it), the breaker must
    have closed again before the run ended, and the SLO fractions must
    be sane. A missing artifact is fine (the chaos-serve shard may not
    have run); a present artifact without the storm row is a failure."""
    if not os.path.exists(fresh_path):
        print("bench_gate: no fresh BENCH_serve_chaos.json — skipping the "
              "serving-resilience acceptance check (chaos-serve shard "
              "not run)")
        return []
    try:
        fresh = _rows(fresh_path)
    except (json.JSONDecodeError, KeyError):
        return [f"{os.path.basename(fresh_path)}: unreadable — cannot check "
                f"the serving-resilience acceptance rows"]
    errors = []
    storm = fresh.get("serve_chaos/storm")
    if storm is None:
        return [f"{os.path.basename(fresh_path)}: serve_chaos/storm row "
                f"missing — the bench emitted nothing to accept"]
    if "serve_chaos/clean" not in fresh:
        errors.append("serve_chaos/clean baseline row missing — goodput has "
                      "nothing it was measured against")
    need = ("goodput_frac", "token_parity", "crash_recoveries",
            "breaker_trips", "breaker_trips_expected", "breaker_recovered",
            "shed_frac", "deadline_miss_frac", "faults_injected")
    missing = [k for k in need if k not in storm]
    if missing:
        return errors + [f"serve_chaos/storm: columns missing: {missing}"]
    g = float(storm["goodput_frac"])
    if not g >= 0.70:
        errors.append(
            f"serve_chaos/storm: goodput_frac = {g:g} < 0.70 — the fault "
            f"storm collapsed throughput instead of degrading it")
    if float(storm["token_parity"]) != 1.0:
        errors.append(
            "serve_chaos/storm: token_parity != 1 — a request completed "
            "under the storm with different tokens than the clean run "
            "(crash recovery replayed or corrupted generation)")
    if int(storm["crash_recoveries"]) < 1:
        errors.append(
            "serve_chaos/storm: crash_recoveries = 0 — the armed engine "
            "crash never exercised the snapshot/restore path")
    trips, expected = (int(storm["breaker_trips"]),
                       int(storm["breaker_trips_expected"]))
    if trips != expected or expected < 1:
        errors.append(
            f"serve_chaos/storm: breaker_trips {trips} != expected "
            f"{expected} (or storm not sized to trip) — detection is no "
            f"longer 1:1 with the armed plan")
    if float(storm["breaker_recovered"]) != 1.0:
        errors.append(
            "serve_chaos/storm: breaker_recovered != 1 — the page breaker "
            "never closed again (half-open probes not reaching the "
            "compressed path?)")
    if int(storm["faults_injected"]) < 1:
        errors.append("serve_chaos/storm: faults_injected = 0 — the plan "
                      "armed nothing")
    for key in ("shed_frac", "deadline_miss_frac"):
        v = float(storm[key])
        if not 0.0 <= v <= 1.0:
            errors.append(f"serve_chaos/storm: {key} = {v:g} outside [0, 1]")
    return errors


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True,
                    help="directory holding the committed BENCH_*.json")
    ap.add_argument("--fresh", required=True,
                    help="directory holding the freshly emitted BENCH_*.json")
    ap.add_argument("--tol", type=float,
                    default=float(os.environ.get("BENCH_GATE_TOL", 3.0)),
                    help="us_per_call regression tolerance factor")
    ap.add_argument("--slack-us", type=float,
                    default=float(os.environ.get("BENCH_GATE_SLACK_US", 5000)),
                    help="absolute us_per_call slack on top of --tol")
    args = ap.parse_args()

    all_errors = []
    checked = 0
    for fname in FILES:
        base_path = os.path.join(args.baseline, fname)
        fresh_path = os.path.join(args.fresh, fname)
        try:
            _rows(base_path)
        except (FileNotFoundError, json.JSONDecodeError, KeyError):
            # missing, empty (e.g. a failed `git show` left a truncated
            # file) or schema-less baseline: nothing to gate against yet
            print(f"bench_gate: no usable baseline {base_path} — skipping "
                  f"(first run seeds it)")
            continue
        if not os.path.exists(fresh_path):
            all_errors.append(f"{fname}: fresh artifact missing at "
                              f"{fresh_path} (bench did not run?)")
            continue
        errs = gate_file(base_path, fresh_path, args.tol, args.slack_us)
        n = len(_rows(fresh_path))
        checked += 1
        status = "FAIL" if errs else "ok"
        print(f"bench_gate: {fname}: {n} fresh rows vs baseline -> {status}")
        all_errors.extend(errs)

    # absolute consumer-beats-dense acceptance rows (baseline-independent)
    sp_errs = gate_speedup(os.path.join(args.fresh, "BENCH_kernels.json"))
    print(f"bench_gate: speedup_vs_dense > 1 on {list(SPEEDUP_ROWS)} -> "
          f"{'FAIL' if sp_errs else 'ok'}")
    all_errors.extend(sp_errs)

    # absolute interconnect-byte acceptance (baseline-independent): the
    # compressed collectives must match Eq. 2/3 exactly and beat dense
    coll_path = os.path.join(args.fresh, "BENCH_collectives.json")
    coll_errs = gate_collectives(coll_path)
    if os.path.exists(coll_path):
        print(f"bench_gate: BENCH_collectives.json ici_bytes == predicted "
              f"and < dense -> {'FAIL' if coll_errs else 'ok'}")
    all_errors.extend(coll_errs)

    # absolute chaos-matrix acceptance (baseline-independent): 100%
    # detection across the (boundary x fault class) pairs, recovery to a
    # correct output, and a level-independent wire
    faults_path = os.path.join(args.fresh, "BENCH_faults.json")
    faults_errs = gate_faults(faults_path)
    if os.path.exists(faults_path):
        print(f"bench_gate: BENCH_faults.json detected == injected and "
              f"recovered on every detect row -> "
              f"{'FAIL' if faults_errs else 'ok'}")
    all_errors.extend(faults_errs)

    # absolute serving acceptance (baseline-independent): continuous
    # batching >= 2x sequential, per-request KV bytes inside the Eq. 2/3
    # index-padding bound, bounded decode dispatch shapes
    serve_path = os.path.join(args.fresh, "BENCH_serve.json")
    serve_errs = gate_serve(serve_path)
    if os.path.exists(serve_path):
        print(f"bench_gate: BENCH_serve.json speedup >= 2x and KV bytes "
              f"within the index-padding bound -> "
              f"{'FAIL' if serve_errs else 'ok'}")
    all_errors.extend(serve_errs)

    # absolute serving-resilience acceptance (baseline-independent):
    # goodput holds under the storm, crash recovery is token-exact, and
    # the breaker trips and recovers 1:1 with the armed plan
    chaos_path = os.path.join(args.fresh, "BENCH_serve_chaos.json")
    chaos_errs = gate_serve_chaos(chaos_path)
    if os.path.exists(chaos_path):
        print(f"bench_gate: BENCH_serve_chaos.json goodput >= 0.70, token "
              f"parity, breaker trip/recover 1:1 -> "
              f"{'FAIL' if chaos_errs else 'ok'}")
    all_errors.extend(chaos_errs)

    if all_errors:
        print("\nbench_gate FAILED:", file=sys.stderr)
        for e in all_errors:
            print(f"  - {e}", file=sys.stderr)
        sys.exit(1)
    if not checked:
        print("bench_gate: nothing to check (no baselines found)")
    else:
        print("bench_gate OK: byte accounting exact, us_per_call within "
              f"{args.tol:g}x + {args.slack_us:g} us")


if __name__ == "__main__":
    main()
