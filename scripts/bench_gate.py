#!/usr/bin/env python
"""Perf-trajectory gate: fail CI when a fresh benchmark run drifts from
the committed BENCH_*.json baselines.

Two classes of check, per row matched by ``name`` across baseline and
fresh (the intersection must be non-empty per file):

* **exact**: byte-accounting columns (``stream_bytes``,
  ``measured_bytes``, ``dense_bytes``, ``index_bytes``) must match the
  baseline bit for bit — the compressed stream length is a correctness
  observable (paper Eq. 2/3), not a performance number, so ANY drift is
  a bug, not noise.
* **bounded**: ``us_per_call`` may regress to at most
  ``tol * baseline + slack`` (defaults 3.0x + 5000 us — generous,
  because CI containers share cores and sub-millisecond interpret-mode
  rows swing 2-3x run to run on a loaded machine; the absolute slack
  keeps micro-rows from flapping while still catching the
  order-of-magnitude regressions this gate exists for). Rows faster
  than 50 us are exempt entirely (pure-overhead rows where scheduler
  jitter exceeds the signal).

Usage:
    python scripts/bench_gate.py --baseline DIR --fresh DIR \
        [--tol 3.0] [--slack-us 5000]

Exit 0 = gate green; exit 1 = drift/regression with a per-row report.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

FILES = ("BENCH_kernels.json", "BENCH_bandwidth.json", "BENCH_train.json")
EXACT_KEYS = ("stream_bytes", "measured_bytes", "dense_bytes", "index_bytes")
US_EXEMPT_BELOW = 50.0


def _rows(path: str) -> dict[str, dict]:
    with open(path) as f:
        doc = json.load(f)
    return {r["name"]: r for r in doc["rows"]}


def gate_file(base_path: str, fresh_path: str, tol: float,
              slack_us: float) -> list[str]:
    errors = []
    base = _rows(base_path)
    fresh = _rows(fresh_path)
    shared = sorted(set(base) & set(fresh))
    if not shared:
        return [f"{os.path.basename(fresh_path)}: no row names shared with "
                f"the baseline — the bench was renamed without regenerating "
                f"the committed baseline"]
    for name in shared:
        b, f = base[name], fresh[name]
        for key in EXACT_KEYS:
            if key in b and key in f and b[key] != f[key]:
                errors.append(
                    f"{name}: {key} drifted {b[key]} -> {f[key]} (byte "
                    f"accounting is exact — this is a stream-format bug, "
                    f"not noise)")
        bus, fus = b.get("us_per_call", 0.0), f.get("us_per_call", 0.0)
        if bus >= US_EXEMPT_BELOW and fus > tol * bus + slack_us:
            errors.append(
                f"{name}: us_per_call regressed {bus:.1f} -> {fus:.1f} "
                f"(> {tol:g}x + {slack_us:g} us tolerance)")
    return errors


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True,
                    help="directory holding the committed BENCH_*.json")
    ap.add_argument("--fresh", required=True,
                    help="directory holding the freshly emitted BENCH_*.json")
    ap.add_argument("--tol", type=float,
                    default=float(os.environ.get("BENCH_GATE_TOL", 3.0)),
                    help="us_per_call regression tolerance factor")
    ap.add_argument("--slack-us", type=float,
                    default=float(os.environ.get("BENCH_GATE_SLACK_US", 5000)),
                    help="absolute us_per_call slack on top of --tol")
    args = ap.parse_args()

    all_errors = []
    checked = 0
    for fname in FILES:
        base_path = os.path.join(args.baseline, fname)
        fresh_path = os.path.join(args.fresh, fname)
        try:
            _rows(base_path)
        except (FileNotFoundError, json.JSONDecodeError, KeyError):
            # missing, empty (e.g. a failed `git show` left a truncated
            # file) or schema-less baseline: nothing to gate against yet
            print(f"bench_gate: no usable baseline {base_path} — skipping "
                  f"(first run seeds it)")
            continue
        if not os.path.exists(fresh_path):
            all_errors.append(f"{fname}: fresh artifact missing at "
                              f"{fresh_path} (bench did not run?)")
            continue
        errs = gate_file(base_path, fresh_path, args.tol, args.slack_us)
        n = len(_rows(fresh_path))
        checked += 1
        status = "FAIL" if errs else "ok"
        print(f"bench_gate: {fname}: {n} fresh rows vs baseline -> {status}")
        all_errors.extend(errs)

    if all_errors:
        print("\nbench_gate FAILED:", file=sys.stderr)
        for e in all_errors:
            print(f"  - {e}", file=sys.stderr)
        sys.exit(1)
    if not checked:
        print("bench_gate: nothing to check (no baselines found)")
    else:
        print("bench_gate OK: byte accounting exact, us_per_call within "
              f"{args.tol:g}x + {args.slack_us:g} us")


if __name__ == "__main__":
    main()
