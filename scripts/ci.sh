#!/usr/bin/env bash
# Tier-1 CI: run the suite without hypothesis (shim fallback), then with
# hypothesis if it can be installed, then the bandwidth benchmark smoke.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 (hypothesis-optional shim path) =="
python -m pytest -x -q

if python -c "import hypothesis" 2>/dev/null; then
    echo "== hypothesis already present =="
elif pip install --quiet hypothesis 2>/dev/null; then
    echo "== tier-1 (with hypothesis) =="
    python -m pytest -x -q
else
    echo "== pip install hypothesis unavailable (offline) — shim run only =="
fi

echo "== benchmarks (smoke: import-check all, run kernels/bandwidth/roofline/table5 at toy sizes) =="
python -m benchmarks.run --smoke
echo "CI OK"
