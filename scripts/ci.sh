#!/usr/bin/env bash
# Tier-1 CI: run the suite without hypothesis (shim fallback), then with
# hypothesis if it can be installed, then the bandwidth benchmark smoke.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Stash the COMMITTED BENCH_*.json as the perf-gate baseline (scripts/
# bench_gate.py compares at the end). Taken from git HEAD, not the working
# tree: repeated local runs must keep comparing against the committed
# trajectory, not ratchet against their own previous output. Falls back to
# the working-tree copy outside a git checkout.
mkdir -p .bench-baseline
for f in BENCH_kernels.json BENCH_bandwidth.json BENCH_train.json BENCH_collectives.json BENCH_faults.json BENCH_serve.json BENCH_serve_chaos.json; do
    if ! git show "HEAD:$f" > ".bench-baseline/$f" 2>/dev/null; then
        # a failed `git show` leaves a truncated file — replace it with
        # the working-tree copy, or remove it so the gate's first-run
        # skip path engages instead of choking on empty JSON
        cp "$f" ".bench-baseline/$f" 2>/dev/null \
            || rm -f ".bench-baseline/$f"
    fi
done

echo "== tier-1 (hypothesis-optional shim path) =="
python -m pytest -x -q

if python -c "import hypothesis" 2>/dev/null; then
    echo "== hypothesis already present =="
elif pip install --quiet hypothesis 2>/dev/null; then
    echo "== tier-1 (with hypothesis) =="
    python -m pytest -x -q
else
    echo "== pip install hypothesis unavailable (offline) — shim run only =="
fi

echo "== benchmarks (smoke: import-check all, run kernels/bandwidth/roofline/table5 at toy sizes + the 2-step train smoke on the pallas backend; emit BENCH_*.json) =="
python -m benchmarks.run --smoke --json

# -- multi-device shard: its own processes because the 8-device host
# platform must be forced via XLA_FLAGS before jax imports (which the
# shared bench runner and the tier-1 pytest process cannot guarantee)
echo "== multi-device shard (8 forced host devices): collectives tests + bench =="
python -m pytest -x -q tests/test_collectives.py tests/test_sharding_spec.py
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python -m benchmarks.collectives_bench --smoke --json

# -- chaos shard: the (boundary x fault class) matrix + validation
# overhead. Its own process for the same XLA_FLAGS reason (the ring
# boundary needs the forced 8-device mesh). The tier-1 run above already
# executed tests/test_faults.py; this shard produces the gated
# BENCH_faults.json artifact.
echo "== chaos shard (fault injection): faults bench =="
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python -m benchmarks.faults_bench --smoke --json

echo "== BENCH_faults.json schema + chaos-matrix columns =="
python - <<'EOF'
import json, sys
try:
    with open("BENCH_faults.json") as f:
        doc = json.load(f)
except FileNotFoundError:
    sys.exit("FAIL: BENCH_faults.json missing (faults_bench --json did "
             "not write it)")
except json.JSONDecodeError as e:
    sys.exit(f"FAIL: BENCH_faults.json is not valid JSON: {e}")
for key in ("bench", "schema_version", "generated_unix", "rows"):
    if key not in doc:
        sys.exit(f"FAIL: BENCH_faults.json missing key {key!r}")
rows = doc["rows"]
detect = [r for r in rows if r["name"].startswith("faults/detect.")]
levels = {r["level"] for r in rows if r["name"].startswith("faults/validate.")}
if levels != {"off", "structural", "checksum"}:
    sys.exit(f"FAIL: expected overhead rows at all three validation "
             f"levels, got {levels}")
if not detect:
    sys.exit("FAIL: BENCH_faults.json has no faults/detect.* rows")
for r in detect:
    for k in ("injected", "detected", "recovered", "policy"):
        if k not in r:
            sys.exit(f"FAIL: {r['name']} missing column {k!r}")
bounds = {r["name"].split(".")[1] for r in detect}
need = {"stream", "fused", "serve", "ckpt", "ring"}
if not need <= bounds:
    sys.exit(f"FAIL: chaos matrix boundaries {sorted(bounds)} missing "
             f"{sorted(need - bounds)}")
print(f"  BENCH_faults.json: {len(detect)} detect rows across boundaries "
      f"{sorted(bounds)}, overhead at levels {sorted(levels)} OK")
EOF

# -- serving shard: continuous batching vs the sequential baseline over
# the paged compressed-KV pool. Multi-second end-to-end loop, so it runs
# standalone like the collectives/faults shards rather than inside the
# shared smoke runner.
echo "== serving shard (continuous batching): serve bench =="
python -m benchmarks.serve_bench --smoke --json

echo "== BENCH_serve.json schema + serving-contract columns =="
python - <<'EOF'
import json, sys
try:
    with open("BENCH_serve.json") as f:
        doc = json.load(f)
except FileNotFoundError:
    sys.exit("FAIL: BENCH_serve.json missing (serve_bench --json did "
             "not write it)")
except json.JSONDecodeError as e:
    sys.exit(f"FAIL: BENCH_serve.json is not valid JSON: {e}")
for key in ("bench", "schema_version", "generated_unix", "rows"):
    if key not in doc:
        sys.exit(f"FAIL: BENCH_serve.json missing key {key!r}")
rows = {r["name"]: r for r in doc["rows"]}
for name in ("serve/continuous", "serve/sequential"):
    if name not in rows:
        sys.exit(f"FAIL: BENCH_serve.json missing row {name}")
cont = rows["serve/continuous"]
for k in ("us_per_call", "requests_per_s", "tokens_per_s",
          "speedup_vs_sequential", "p50_token_ms", "p95_token_ms",
          "kv_bytes_measured", "kv_bytes_predicted", "kv_bytes_dense",
          "kv_pages", "zero_frac", "decode_shapes", "decode_shape_bound"):
    if not isinstance(cont.get(k), (int, float)):
        sys.exit(f"FAIL: serve/continuous missing numeric column {k!r}: "
                 f"{cont.get(k)!r}")
if "speedup_vs_sequential" in rows["serve/sequential"]:
    sys.exit("FAIL: the sequential baseline row must not carry a "
             "speedup_vs_sequential column (it IS the denominator)")
print(f"  BENCH_serve.json: {len(rows)} rows, continuous at "
      f"{cont['requests_per_s']} req/s "
      f"({cont['speedup_vs_sequential']}x sequential), zero_frac "
      f"{cont['zero_frac']} OK")
EOF

# -- chaos-serve shard: the same engine under a deterministic fault
# storm (engine crash + page-ingest corruption burst) with deadlines, a
# bounded queue and the page-boundary circuit breaker armed. Produces
# the gated BENCH_serve_chaos.json resilience artifact.
echo "== chaos-serve shard (resilient serving): serve chaos bench =="
python -m benchmarks.serve_chaos_bench --smoke --json

echo "== BENCH_serve_chaos.json schema + resilience-contract columns =="
python - <<'EOF'
import json, sys
try:
    with open("BENCH_serve_chaos.json") as f:
        doc = json.load(f)
except FileNotFoundError:
    sys.exit("FAIL: BENCH_serve_chaos.json missing (serve_chaos_bench "
             "--json did not write it)")
except json.JSONDecodeError as e:
    sys.exit(f"FAIL: BENCH_serve_chaos.json is not valid JSON: {e}")
for key in ("bench", "schema_version", "generated_unix", "rows"):
    if key not in doc:
        sys.exit(f"FAIL: BENCH_serve_chaos.json missing key {key!r}")
rows = {r["name"]: r for r in doc["rows"]}
for name in ("serve_chaos/clean", "serve_chaos/storm"):
    if name not in rows:
        sys.exit(f"FAIL: BENCH_serve_chaos.json missing row {name}")
storm = rows["serve_chaos/storm"]
for k in ("us_per_call", "goodput_frac", "token_parity", "n_shed",
          "shed_frac", "deadline_misses", "deadline_miss_frac",
          "crash_recoveries", "recovered_requests", "breaker_trips",
          "breaker_trips_expected", "breaker_probes", "breaker_recovered",
          "pages_breaker_dense", "faults_injected"):
    if not isinstance(storm.get(k), (int, float)):
        sys.exit(f"FAIL: serve_chaos/storm missing numeric column {k!r}: "
                 f"{storm.get(k)!r}")
if rows["serve_chaos/clean"]["faults_injected"] != 0:
    sys.exit("FAIL: the clean row recorded injected faults — the baseline "
             "run is not fault-free")
print(f"  BENCH_serve_chaos.json: {len(rows)} rows, storm goodput "
      f"{storm['goodput_frac']} of clean, {storm['crash_recoveries']} crash "
      f"recoveries, breaker trips {storm['breaker_trips']}"
      f"/{storm['breaker_trips_expected']} expected OK")
EOF

echo "== BENCH_collectives.json schema + byte-contract columns =="
python - <<'EOF'
import json, sys
try:
    with open("BENCH_collectives.json") as f:
        doc = json.load(f)
except FileNotFoundError:
    sys.exit("FAIL: BENCH_collectives.json missing (collectives_bench "
             "--json did not write it)")
except json.JSONDecodeError as e:
    sys.exit(f"FAIL: BENCH_collectives.json is not valid JSON: {e}")
for key in ("bench", "schema_version", "generated_unix", "rows"):
    if key not in doc:
        sys.exit(f"FAIL: BENCH_collectives.json missing key {key!r}")
rows = doc["rows"]
comp = [r for r in rows if r["name"].endswith(".compressed")]
if not comp:
    sys.exit("FAIL: BENCH_collectives.json has no *.compressed rows")
for r in rows:
    for k in ("name", "us_per_call", "axis", "ici_bytes", "ici_dense_bytes",
              "ici_predicted_bytes"):
        if k not in r:
            sys.exit(f"FAIL: {r.get('name', '?')} missing column {k!r}")
axes = {r["axis"] for r in rows}
if axes != {"model", "data"}:
    sys.exit(f"FAIL: expected per-axis rows for model AND data, got {axes}")
print(f"  BENCH_collectives.json: {len(rows)} rows "
      f"({len(comp)} compressed) across axes {sorted(axes)} OK")
EOF

echo "== BENCH_*.json perf-trajectory artifacts =="
python - <<'EOF'
import json, sys

docs = {}
for name in ("BENCH_kernels.json", "BENCH_bandwidth.json", "BENCH_train.json"):
    try:
        with open(name) as f:
            docs[name] = doc = json.load(f)
    except FileNotFoundError:
        sys.exit(f"FAIL: {name} missing (benchmarks/run.py --json did not write it)")
    except json.JSONDecodeError as e:
        sys.exit(f"FAIL: {name} is not valid JSON: {e}")
    for key in ("bench", "schema_version", "generated_unix", "rows"):
        if key not in doc:
            sys.exit(f"FAIL: {name} missing key {key!r}")
    if not doc["rows"] or not all("name" in r and "us_per_call" in r
                                  for r in doc["rows"]):
        sys.exit(f"FAIL: {name} rows empty or missing name/us_per_call")
    print(f"  {name}: {len(doc['rows'])} rows OK")
fused = [r for r in docs["BENCH_kernels.json"]["rows"]
         if r.get("variant") == "fused"]
if not fused:
    sys.exit("FAIL: BENCH_kernels.json has no fused-vs-composed rows")
print(f"  BENCH_kernels.json: {len(fused)} fused-variant rows OK")

# the consumer bench must run at the paper's ~64%-zeros operating point
# and emit the correctly-named dense-baseline ratio (the gate row that
# scripts/bench_gate.py enforces > 1)
krows = {r["name"]: r for r in docs["BENCH_kernels.json"]["rows"]}
spmm = krows.get("kernel/zebra_spmm")
if spmm is None:
    sys.exit("FAIL: BENCH_kernels.json missing kernel/zebra_spmm")
zf = spmm.get("zero_frac")
if not isinstance(zf, (int, float)) or abs(zf - 0.64) > 0.05:
    sys.exit(f"FAIL: kernel/zebra_spmm zero_frac {zf!r} is not ~0.64 — the "
             f"bench drifted off the paper's operating point")
for name in ("kernel/zebra_spmm", "kernel/spmm_cs.fused"):
    r = krows.get(name)
    if r is None or not isinstance(r.get("speedup_vs_dense"), (int, float)):
        sys.exit(f"FAIL: {name} missing a numeric speedup_vs_dense")
print(f"  BENCH_kernels.json: zero_frac {zf} at the operating point, "
      f"speedup_vs_dense columns present")

# table5: overhead_ratio must be a NUMBER (it once emitted "4.07e-04"
# as a string, which no trajectory tooling could compare)
try:
    with open("BENCH_table5.json") as f:
        t5 = json.load(f)
except FileNotFoundError:
    sys.exit("FAIL: BENCH_table5.json missing")
except json.JSONDecodeError as e:
    sys.exit(f"FAIL: BENCH_table5.json is not valid JSON: {e}")
ovh = [r for r in t5["rows"] if r["name"] == "table5/zebra_flop_overhead"]
if not ovh:
    sys.exit("FAIL: BENCH_table5.json missing table5/zebra_flop_overhead")
r = ovh[0].get("overhead_ratio")
if not isinstance(r, float):
    sys.exit(f"FAIL: table5 overhead_ratio must be a float, got "
             f"{type(r).__name__}: {r!r}")
if not (0.0 < r < 1.0):
    sys.exit(f"FAIL: table5 overhead_ratio {r} outside (0, 1)")
print(f"  BENCH_table5.json: overhead_ratio {r:.3e} is numeric OK")

# train-step smoke rows: reference AND pallas backends, CNN and LM, loss
# finite + grads nonzero, and the pallas rows really resolved to the
# kernel backend (no silent degrade to reference)
trows = docs["BENCH_train.json"]["rows"]
for model in ("cnn", "lm"):
    for backend in ("reference", "pallas"):
        match = [r for r in trows if r["name"] == f"train/{model}.{backend}"]
        if not match:
            sys.exit(f"FAIL: BENCH_train.json missing train/{model}.{backend}")
        r = match[0]
        if not (r.get("loss_finite") and r.get("grads_nonzero")):
            sys.exit(f"FAIL: {r['name']} train smoke flags not set: {r}")
        if r.get("resolved_backend") != backend:
            sys.exit(f"FAIL: {r['name']} resolved to "
                     f"{r.get('resolved_backend')!r}, expected {backend!r}")
print(f"  BENCH_train.json: {len(trows)} train-smoke rows OK "
      f"(reference+pallas, CNN+LM)")
EOF

echo "== perf-trajectory gate (stream_bytes exact, us_per_call bounded) =="
python scripts/bench_gate.py --baseline .bench-baseline --fresh .

echo "CI OK"
