"""Paper Table IV / Fig. 5: ablation — NS alone vs Zebra alone vs Zebra+NS
(the paper's claim: NS composes synergistically with Zebra)."""
from __future__ import annotations

from repro.data import SYN_CIFAR10
from .common import emit, eval_row, train_cnn


def run(budget, quick=True) -> list[dict]:
    rows = []
    model, t_obj, ns_frac = "resnet18", 0.2, 0.2

    # NS only (sparsity-train, slim, retrain; Zebra off)
    tr, state, _ = train_cnn(model, SYN_CIFAR10, 0.0, budget,
                             zebra_on=False, ns_rho=1e-4)
    tr.apply_network_slimming(state["variables"], ns_frac)
    state, _ = tr.train(steps=budget["steps"] // 2, state=state,
                        log_every=budget["steps"])
    r = {"name": "table4/ns_only"}
    r.update(eval_row(tr, state, budget))
    # NS-only bandwidth saving: pruned channels' maps are never written
    rows.append(r)

    # Zebra only
    tr, state, _ = train_cnn(model, SYN_CIFAR10, t_obj, budget)
    r = {"name": "table4/zebra_only", "t_obj": t_obj}
    r.update(eval_row(tr, state, budget))
    rows.append(r)

    # Zebra + NS
    tr, state, _ = train_cnn(model, SYN_CIFAR10, t_obj, budget, ns_rho=1e-4)
    tr.apply_network_slimming(state["variables"], ns_frac)
    state, _ = tr.train(steps=budget["steps"] // 2, state=state,
                        log_every=budget["steps"])
    r = {"name": "table4/zebra_plus_ns", "t_obj": t_obj}
    r.update(eval_row(tr, state, budget))
    rows.append(r)

    emit(rows, "table4")
    return rows
