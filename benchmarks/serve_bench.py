"""Continuous-batching serving benchmark — BENCH_serve.json.

Serves the same synthetic heavy-traffic trace twice through
``repro.serve.ServeEngine`` at the paper's ~64%-zero-blocks KV operating
point (``zebra_t_obj`` calibrated for the reduced gemma3 stack):

  serve/continuous   n_slots lanes, mixed prefill/decode batching,
                     preemption-capable paged compressed-KV pool
  serve/sequential   the SAME engine machinery at n_slots=1 — one
                     request at a time, the throughput baseline the
                     gate's ``speedup_vs_sequential`` is measured against

Columns (the CI gate's exact contract, ``scripts/bench_gate.py``):

  requests_per_s, tokens_per_s   end-to-end trace throughput
  speedup_vs_sequential          continuous req/s over sequential req/s
                                 (gate: >= 2.0 on the continuous row)
  p50_token_ms, p95_token_ms     inter-token latency percentiles
  kv_bytes_measured              stream bytes actually moved through the
                                 paged pool for the trace's requests
  kv_bytes_predicted             the Eq. 2/3 analytic prediction summed
                                 over the same pages (gate: measured
                                 within kv_pages * 2 B — per-page index
                                 padding + float roundoff)
  kv_bytes_dense                 dense-equivalent bytes (gate: measured
                                 < dense)
  kv_pages, zero_frac            compressed page count; block-weighted
                                 zero fraction over every page (gate:
                                 the ~64% operating point, wide band)
  decode_shapes/_bound           distinct decode dispatch shapes vs the
                                 declared ladder bound (gate: <=)

Both engines serve a rid-offset warmup trace first (identical shape
ladder coverage), so the timed run measures steady-state dispatches,
not compiles. Output parity between the two rows is asserted in-line:
continuous batching must not change a single token.

Standalone like the collectives/faults benches (NOT in
``benchmarks/run.py``'s smoke list — it is a multi-second end-to-end
loop, its own CI shard in ``scripts/ci.sh``), but registered in the
harness's bench table for ``--only serve``.
"""
from __future__ import annotations

import argparse
import os

import jax

from benchmarks.common import emit, set_json_dir
import repro.configs as configs
from repro.launch.mesh import make_host_mesh
from repro.models.lm import LM
from repro.serve import ServeEngine, synthetic_trace

# calibrated on the reduced gemma3 stack: prefill KV masking at this
# threshold plus the (all-dead) pad tails lands the pool's block-zero
# fraction near the paper's 0.64 operating point; decode-written KV is
# unmasked and dilutes it, hence the wide gate band
T_OBJ = 3.45
TRACE = dict(vocab=512, seed=0, prompt_lo=8, prompt_hi=48,
             gen_lo=8, gen_hi=16)
MAX_CACHE = 128
SLOTS = 4


def _build():
    cfg = configs.reduced("gemma3-4b").replace(
        param_dtype="bfloat16", zebra_sites=("ffn_hidden", "kv_cache"),
        zebra_t_obj=T_OBJ)
    mesh = make_host_mesh(model=1)
    model = LM(cfg)
    params = jax.jit(model.init)(jax.random.PRNGKey(0))
    return cfg, mesh, model, params


def _serve(model, params, mesh, n_requests: int, slots: int):
    eng = ServeEngine(model, params, mesh, n_slots=slots,
                      max_cache_len=MAX_CACHE, page_tokens=16,
                      validation="structural")
    warm = synthetic_trace(n_requests, **TRACE)
    for r in warm:                  # offset rids: no pool-meter site overlap
        r.rid += 1000
    eng.run(warm)                   # compiles every ladder shape untimed
    rep = eng.run(synthetic_trace(n_requests, **TRACE))
    outs = {r.rid: list(r.out) for r in eng.scheduler.completed
            if r.status == "done"}
    return rep, outs


def _row(name: str, rep: dict, speedup: float | None) -> dict:
    row = {
        "name": name,
        "us_per_call": rep["wall_s"] / max(rep["steps"], 1) * 1e6,
        "n_requests": rep["n_requests"],
        "requests_per_s": round(rep["requests_per_s"], 3),
        "tokens_per_s": round(rep["tokens_per_s"], 2),
        "p50_token_ms": round(rep["p50_token_ms"], 2),
        "p95_token_ms": round(rep["p95_token_ms"], 2),
        "evictions": rep["evictions"],
        "kv_bytes_measured": rep["kv_bytes_measured"],
        "kv_bytes_predicted": round(rep["kv_bytes_predicted"], 2),
        "kv_bytes_dense": rep["kv_bytes_dense"],
        "kv_pages": rep["kv_pages"],
        "zero_frac": round(rep["zero_frac"], 4),
        "decode_shapes": rep["decode_shapes"],
        "decode_shape_bound": rep["decode_shape_bound"],
        "prefill_shapes": rep["prefill_shapes"],
        "pages_recovered": rep["pages_recovered"],
    }
    if speedup is not None:
        row["speedup_vs_sequential"] = round(speedup, 3)
    return row


def run(n_requests: int = 12) -> list[dict]:
    cfg, mesh, model, params = _build()
    seq_rep, seq_outs = _serve(model, params, mesh, n_requests, slots=1)
    cont_rep, cont_outs = _serve(model, params, mesh, n_requests,
                                 slots=SLOTS)
    # continuous batching must be invisible in the tokens: every request
    # matches its sequential-serving output exactly
    assert set(cont_outs) == set(seq_outs)
    for rid, out in seq_outs.items():
        assert cont_outs[rid] == out, f"rid {rid} diverged under batching"
    speedup = (cont_rep["requests_per_s"] / seq_rep["requests_per_s"]
               if seq_rep["requests_per_s"] else 0.0)
    rows = [_row("serve/continuous", cont_rep, speedup),
            _row("serve/sequential", seq_rep, None)]
    emit(rows, "serve")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="shorter trace (CI shard)")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_serve.json to the CWD")
    ap.add_argument("--requests", type=int, default=0,
                    help="override trace length")
    args = ap.parse_args()
    if args.json:
        set_json_dir(os.getcwd())
    n = args.requests or (8 if args.smoke else 24)
    run(n)


if __name__ == "__main__":
    main()
