"""Roofline table from dry-run artifacts: reads benchmarks/artifacts/*.json
(written by repro.launch.dryrun) and renders the §Roofline table rows +
markdown for EXPERIMENTS.md."""
from __future__ import annotations

import glob
import json
import os

from .common import emit

ART_DIR = os.path.join(os.path.dirname(__file__), "artifacts")


def load(tag_filter: str | None = None, mesh: str | None = None) -> list[dict]:
    arts = []
    for fn in sorted(glob.glob(os.path.join(ART_DIR, "*.json"))):
        a = json.load(open(fn))
        if tag_filter and a.get("tag") != tag_filter:
            continue
        if mesh and a.get("mesh") != mesh:
            continue
        arts.append(a)
    return arts


def best_per_cell(arts: list[dict]) -> dict[tuple, dict]:
    """Best artifact per (arch, shape, mesh): perf-* winners (§Perf) beat
    the v1 baseline table; ad-hoc tags rank below both."""
    def rank(tag):
        if "accum" in tag:
            return -1   # grad-accum artifacts are fits-axis only: their
                        # cost terms hide per-microbatch work inside the
                        # accumulation scan (see EXPERIMENTS §Perf M4/C4)
        if tag.startswith("perf"):
            return 2
        return {"v1": 1, "v2": 1}.get(tag, 0)
    out: dict[tuple, dict] = {}
    for a in arts:
        k = (a["arch"], a["shape"], a["mesh"])
        if k not in out or rank(a["tag"]) >= rank(out[k]["tag"]):
            out[k] = a
    return out


def markdown_table(arts: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute (ms) | memory (ms) | coll (ms) | "
           "bottleneck | MODEL_FLOPs/HLO | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for a in arts:
        r = a["roofline"]
        lines.append(
            f"| {a['arch']} | {a['shape']} | {a['mesh']} "
            f"| {r['compute_s']*1e3:.1f} | {r['memory_s']*1e3:.1f} "
            f"| {r['collective_s']*1e3:.1f} | {r['bottleneck']} "
            f"| {r['useful_ratio']:.3f} | {r['fraction']:.3f} |")
    return hdr + "\n".join(lines) + "\n"


def run(budget=None, quick=True) -> list[dict]:
    arts = list(best_per_cell(load(mesh="16x16")).values())
    rows = []
    for a in arts:
        r = a["roofline"]
        rows.append({"name": f"roofline/{a['arch']}/{a['shape']}",
                     "tag": a["tag"],
                     "compute_ms": round(r["compute_s"] * 1e3, 2),
                     "memory_ms": round(r["memory_s"] * 1e3, 2),
                     "collective_ms": round(r["collective_s"] * 1e3, 2),
                     "bottleneck": r["bottleneck"],
                     "useful": round(r["useful_ratio"], 3),
                     "fraction": round(r["fraction"], 4)})
    if rows:
        emit(rows, "roofline")
    else:
        print("roofline/none,0,run `python -m repro.launch.dryrun --all` first")
    return rows
