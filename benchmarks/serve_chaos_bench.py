"""Serving resilience under a deterministic fault storm — BENCH_serve_chaos.json.

Serves the SAME deadline-carrying trace twice through a supervised
``repro.serve.ServeEngine`` (crash-recoverable tick loop, bounded
pending queue, per-boundary circuit breaker at page ingest):

  serve_chaos/clean   supervised but fault-free — the goodput baseline
  serve_chaos/storm   the deterministic storm armed via ``ft.inject``:
                      one engine crash (``crash`` at site
                      ``"engine_tick"``, a named tick) plus a burst of
                      page-ingest stream corruptions (``truncate`` at
                      site ``"page"``) sized to trip the page breaker
                      and then fail its first half-open probes — the
                      full trip -> probe -> decayed reopen -> recover
                      lifecycle in one run

Columns (the CI gate's exact contract, ``scripts/bench_gate.py``):

  goodput_frac            storm completed-requests over clean (gate:
                          >= 0.70 — the storm may shed, not collapse)
  token_parity            1.0 iff every request completed under the
                          storm is token-bitwise-equal to its clean run
                          (gate: == 1.0 — crash recovery resumes from
                          paged compressed KV without replaying or
                          altering a single generated token)
  crash_recoveries        snapshot restores taken (gate: >= 1)
  breaker_trips/_expected measured closed->open transitions vs the
                          count implied by the armed plan (gate: equal,
                          and > 0 on the storm row)
  breaker_recovered       1.0 iff the page breaker closed again before
                          the run ended (gate: == 1.0)
  shed_frac,
  deadline_miss_frac      SLO accounting over the whole trace (gate:
                          both in [0, 1])
  faults_injected         ground-truth fired-fault count from the plan

Both rows come from identically-configured engines (deadlines, queue
bound, breaker, supervision) so the delta is the storm and nothing
else. Standalone like serve_bench (its own CI shard in
``scripts/ci.sh``, not in ``benchmarks/run.py``'s smoke list).
"""
from __future__ import annotations

import argparse
import os

import jax

from benchmarks.common import emit, set_json_dir
import repro.configs as configs
from repro.ft import BreakerConfig, Fault, FTConfig, inject
from repro.launch.mesh import make_host_mesh
from repro.models.lm import LM
from repro.serve import ServeEngine, synthetic_trace

T_OBJ = 3.45                       # serve_bench's ~64%-zeros KV operating point
TRACE = dict(vocab=512, seed=0, prompt_lo=8, prompt_hi=48,
             gen_lo=8, gen_hi=16, arrival_every=1)
MAX_CACHE = 128
SLOTS = 4
DEADLINE_TICKS = 96                # generous TTL: misses are possible, not built in
QUEUE_BOUND = 4                    # pending-queue bound (overflow -> shed)
CRASH_TICK = 12                    # mid-run, lanes guaranteed in flight
PAGE_FAULTS = 6                    # 3 trip the breaker, 3 fail half-open probes
# probe quickly so the full trip -> reopen -> recover lifecycle fits a
# smoke-length run: probes at ticks +1, +3, +7, +15 after the trip
BREAKER = BreakerConfig(trip_after=3, window=64, probe_after=1,
                        probe_backoff=2.0, probe_cap=8, close_after=2)


def _build():
    cfg = configs.reduced("gemma3-4b").replace(
        param_dtype="bfloat16", zebra_sites=("ffn_hidden", "kv_cache"),
        zebra_t_obj=T_OBJ)
    mesh = make_host_mesh(model=1)
    model = LM(cfg)
    params = jax.jit(model.init)(jax.random.PRNGKey(0))
    return cfg, mesh, model, params


def _serve(model, params, mesh, n_requests: int, *, storm: bool):
    eng = ServeEngine(model, params, mesh, n_slots=SLOTS,
                      max_cache_len=MAX_CACHE, page_tokens=16,
                      validation="structural", queue_bound=QUEUE_BOUND,
                      breaker=BREAKER)
    warm = synthetic_trace(min(n_requests, 4), **TRACE)
    for r in warm:                  # offset rids: no pool-meter site overlap
        r.rid += 1000
    eng.run(warm)                   # compiles the ladder shapes untimed
    trace = synthetic_trace(n_requests, **TRACE,
                            deadline_ticks=DEADLINE_TICKS)
    ft_cfg = FTConfig(max_failures=4, backoff_base_s=0.0, jitter_seed=0)
    if not storm:
        rep = eng.run(trace, ft_cfg=ft_cfg)
        injected = []
    else:
        with inject(Fault("crash", site="engine_tick", arg=CRASH_TICK),
                    Fault("truncate", site="page", times=PAGE_FAULTS)) as plan:
            rep = eng.run(trace, ft_cfg=ft_cfg)
        injected = list(plan.injected)
    outs = {r.rid: list(r.out) for r in eng.scheduler.completed
            if r.status == "done"}
    return rep, outs, injected


def _row(name: str, rep: dict, outs: dict, injected: list, *,
         goodput_frac: float, token_parity: float) -> dict:
    page = rep["breakers"].get("page", {})
    n_page_faults = sum(1 for k, s in injected if s == "page")
    return {
        "name": name,
        "us_per_call": rep["wall_s"] / max(rep["steps"], 1) * 1e6,
        "n_requests": rep["n_requests"],
        "goodput_frac": round(goodput_frac, 4),
        "token_parity": token_parity,
        "n_shed": rep["n_shed"],
        "shed_frac": round(rep["shed_frac"], 4),
        "deadline_misses": rep["deadline_misses"],
        "deadline_miss_frac": round(rep["deadline_miss_frac"], 4),
        "deferrals": rep["deferrals"],
        "retries": rep["retries"],
        "crash_recoveries": rep["crash_recoveries"],
        "recovered_requests": rep["recovered_requests"],
        "breaker_trips": rep["breaker_trips"],
        # the armed plan implies the trip count: the first `trip_after`
        # detections trip once; later faults land on half-open probes
        # (reopens, not closed->open trips)
        "breaker_trips_expected":
            1 if n_page_faults >= BREAKER.trip_after else 0,
        "breaker_probes": rep["breaker_probes"],
        "breaker_recovered": 1.0 if page.get("state", "closed") == "closed"
        else 0.0,
        "pages_breaker_dense": rep["pages_breaker_dense"],
        "pages_recovered": rep["pages_recovered"],
        "faults_injected": len(injected),
        "evictions": rep["evictions"],
        "kv_pages": rep["kv_pages"],
        "zero_frac": round(rep["zero_frac"], 4),
    }


def run(n_requests: int = 10) -> list[dict]:
    cfg, mesh, model, params = _build()
    clean_rep, clean_outs, _ = _serve(model, params, mesh, n_requests,
                                      storm=False)
    storm_rep, storm_outs, injected = _serve(model, params, mesh, n_requests,
                                             storm=True)
    assert storm_rep["crash_recoveries"] >= 1, \
        "the armed crash never fired — CRASH_TICK outside the run?"
    assert ("crash", "engine_tick") in injected
    # token parity: every request the storm completed must match its
    # clean-run output bitwise — crash recovery and breaker degradation
    # may shed work, never corrupt it
    parity = 1.0
    for rid, out in storm_outs.items():
        if clean_outs.get(rid, out) != out:
            parity = 0.0
    goodput = (len(storm_outs) / len(clean_outs)) if clean_outs else 0.0
    rows = [
        _row("serve_chaos/clean", clean_rep, clean_outs, [],
             goodput_frac=1.0, token_parity=1.0),
        _row("serve_chaos/storm", storm_rep, storm_outs, injected,
             goodput_frac=goodput, token_parity=parity),
    ]
    emit(rows, "serve_chaos")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="shorter trace (CI shard)")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_serve_chaos.json to the CWD")
    ap.add_argument("--requests", type=int, default=0,
                    help="override trace length")
    args = ap.parse_args()
    if args.json:
        set_json_dir(os.getcwd())
    n = args.requests or (6 if args.smoke else 10)
    run(n)


if __name__ == "__main__":
    main()
