"""Train-step latency + smoke: reference vs pallas kernel backends.

This is the CI witness that training *through the kernels* works: for
each backend it runs a real 2-step train loop (CNN via ``CNNTrainer``,
LM via ``launch.steps.make_train_step``) in constant-threshold
(deployment-matched) mode — ``use_tnet=False`` so the sites resolve to
the requested kernel backend instead of degrading to reference — and
asserts the loss is finite, the gradients are nonzero, and the
reference/pallas losses agree (the custom_vjp forward is the bitwise
comparator, and its backward is numerically equal to reference).

Rows ride the ``BENCH_train.json`` perf-trajectory artifact
(``benchmarks/common.emit`` schema v1); ``scripts/ci.sh`` validates that
both backends are present with the smoke flags set.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import ZebraConfig
from repro.data import ImageDatasetConfig, LMDatasetConfig, image_batch, lm_batch
from repro.optim import sgd, step_decay
from repro.train import CNNTrainer, CNNTrainConfig

from .common import emit, timeit

BACKENDS = ("reference", "pallas")


def _row(name, us, backend, resolved, loss, grad_norm, extra=None):
    """``resolved`` must come from the REAL run's SiteAux.backend values —
    a synthetic probe could stay on the kernel while the model's own
    sites silently degraded (degrade is numerically invisible by
    design, so only the real sites prove the kernels trained)."""
    loss, grad_norm = float(loss), float(grad_norm)
    assert math.isfinite(loss), f"{name}: non-finite loss {loss}"
    assert grad_norm > 0.0, f"{name}: zero gradients"
    r = {"name": name, "us_per_call": us, "backend": backend,
         "resolved_backend": resolved,
         "loss": round(loss, 6), "grad_norm": round(grad_norm, 6),
         "loss_finite": True, "grads_nonzero": True}
    r.update(extra or {})
    return r


# ---------------------------------------------------------------------------
# CNN train step (paper pipeline, constant-threshold mode)
# ---------------------------------------------------------------------------

def _cnn_rows(steps: int = 2) -> list[dict]:
    ds = ImageDatasetConfig("syn-cifar10", 10, 8, seed=3)
    rows, losses = [], {}
    for backend in BACKENDS:
        zcfg = ZebraConfig(t_obj=0.25, block_hw=4, backend=backend,
                           use_tnet=False)
        cfg = CNNTrainConfig(model="resnet18", width_mult=0.125, dataset=ds,
                             batch=8, steps=steps, zebra=zcfg, seed=0)
        tr = CNNTrainer(cfg, sgd(step_decay(0.05, total_steps=steps)))
        state = tr.init_state()
        images, labels = image_batch(ds, cfg.batch, 0)
        metrics = None
        for _ in range(steps):
            state, metrics = tr._train_step(state, images, labels)
        jax.block_until_ready(metrics["loss"])
        us = timeit(lambda: tr._train_step(state, images, labels)[1]["loss"],
                    iters=2, warmup=0)
        losses[backend] = float(metrics["loss"])
        # what the trained model's OWN sites resolved to, from a real
        # train-mode forward (every resnet18 site must agree)
        zc = zcfg.replace(mode="train")
        _, _, auxes = tr.model.apply(state["variables"], images, True, zc)
        resolved = sorted({a.backend for a in auxes})
        assert resolved == [backend], resolved
        rows.append(_row(f"train/cnn.{backend}", us, backend, resolved[0],
                         metrics["loss"], metrics["grad_norm"],
                         {"model": "resnet18", "steps": steps,
                          "zero_frac": round(float(metrics["zero_frac"]), 4)}))
    # the kernel path must train the SAME function as reference
    assert abs(losses["reference"] - losses["pallas"]) < 1e-4, losses
    return rows


# ---------------------------------------------------------------------------
# LM train step (launch.steps cell, constant-threshold mode)
# ---------------------------------------------------------------------------

def _lm_rows(steps: int = 2) -> list[dict]:
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import make_train_state_shape, make_train_step
    from repro.models.lm import LM, LMConfig
    from repro.optim import adamw, warmup_cosine

    mesh = make_host_mesh(model=1)
    rows, losses = [], {}
    for backend in BACKENDS:
        cfg = LMConfig(name="bench", n_layers=2, d_model=64, n_heads=4,
                       n_kv_heads=4, d_ff=256, vocab=256, zebra_t_obj=0.5,
                       zebra_backend=backend, zebra_tnet=False)
        model = LM(cfg)
        opt = adamw(warmup_cosine(1e-3, 2, 20))
        _, init_fn = make_train_state_shape(model, opt)
        state = jax.jit(init_fn)(jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(model, opt, mesh))
        batch = {"tokens": jnp.asarray(
            lm_batch(LMDatasetConfig(vocab=cfg.vocab), 2, 32, 0))}
        metrics = None
        for _ in range(steps):
            state, metrics = step(state, batch)
        jax.block_until_ready(metrics["loss"])
        us = timeit(lambda: step(state, batch)[1]["loss"], iters=2, warmup=0)
        losses[backend] = float(metrics["loss"])
        # what the trained model's OWN ffn site resolves to: run the real
        # layer-0 params (any zebra_tnet leaf would surface as a degrade)
        from repro.models.lm.ffn import ffn_apply
        lp = jax.tree_util.tree_map(lambda a: a[0], state["params"]["run0"])
        _, zaux = ffn_apply(lp["sub0"]["ffn"],
                            jnp.ones((2, 32, cfg.d_model), jnp.bfloat16) / 7,
                            cfg, "train")
        assert zaux.backend == backend, zaux.backend
        rows.append(_row(f"train/lm.{backend}", us, backend, zaux.backend,
                         metrics["loss"], metrics["grad_norm"],
                         {"model": "lm-2l-64d", "steps": steps,
                          "zero_frac": round(float(metrics["zero_frac"]), 4)}))
    assert abs(losses["reference"] - losses["pallas"]) < 1e-4, losses
    return rows


def run(budget=None, quick: bool = True) -> list[dict]:
    rows = _cnn_rows() + _lm_rows()
    emit(rows, "train")
    return rows


if __name__ == "__main__":
    run()
