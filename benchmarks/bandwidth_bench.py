"""Predicted vs *measured* bandwidth reduction (the paper's headline
metric, Eq. 2/3, as an observable).

For each model config and each ``t_obj`` in the sweep, a block-structured
activation map is masked by the Pallas comparator, packed into the
``(bitmap, payload)`` stream, and the stream's actual byte count is
reconciled against ``stored_bits(spec, zero_frac)`` at the *measured*
zero-block fraction. The two must agree to within index-padding rounding
(< 1 byte per map) — that assertion runs on every invocation.

    PYTHONPATH=src python benchmarks/bandwidth_bench.py [--smoke] [--full]

Prints ``name,us_per_call,derived`` CSV per row (run.py convention).
"""
from __future__ import annotations

import argparse
import zlib

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.compress import BandwidthMeter, compress, decompress
from repro.core import reduced_bandwidth_pct, stored_bits
from repro.kernels import zebra_mask_op

try:
    from .common import timeit
except ImportError:                     # direct script run (CI smoke)
    from common import timeit

# reduced-width archs whose d_ff is lane-aligned (K % 128 == 0)
ARCHS = ("gemma3-4b", "recurrentgemma-2b", "starcoder2-15b")
# block scales are ~U[0,1]; blockmax of 1024 normals is ~3.3, so this sweep
# lands zero fractions near {0, ~1/4, ~1/2, ~3/4, 1}
T_SWEEP = (0.0, 0.8, 1.65, 2.5, 1e9)


def _blocky_map(key, M, K, bs, bc, dtype):
    """Activations whose (bs, bc) blocks have uniform-random magnitudes."""
    x = jax.random.normal(key, (M, K), jnp.float32)
    scale = jax.random.uniform(jax.random.fold_in(key, 1), (M // bs, K // bc))
    x = x * jnp.repeat(jnp.repeat(scale, bs, 0), bc, 1)
    return x.astype(dtype)


def run(smoke: bool = False, dtype=jnp.bfloat16):
    archs = ARCHS[:1] if smoke else ARCHS
    sweep = T_SWEEP[::2] if smoke else T_SWEEP
    batch, seq = (2, 32) if smoke else (4, 64)
    meter = BandwidthMeter()
    rows = []
    for arch in archs:
        cfg = configs.reduced(arch)
        bs, bc = cfg.zebra_block_seq, cfg.zebra_block_ch
        M, K = batch * seq, cfg.d_ff
        key = jax.random.PRNGKey(zlib.crc32(arch.encode()) & 0xFFFF)
        x = _blocky_map(key, M, K, bs, bc, dtype)
        for t in sweep:
            y, bm = zebra_mask_op(x, t, bs=bs, bc=bc)
            cm = compress(y, bm, bs=bs, bc=bc)
            np.testing.assert_array_equal(          # transport is lossless
                np.asarray(decompress(cm)), np.asarray(y))
            r = meter.record(f"{arch}/t_obj={t:g}", cm)
            us = timeit(lambda: compress(y, bm, bs=bs, bc=bc).payload,
                        iters=1 if smoke else 3, warmup=1)
            spec = cm.spec()
            rows.append({
                "name": f"bandwidth/{arch}/t_obj={t:g}",
                "us_per_call": us,
                "zero_frac": round(cm.zero_frac(), 4),
                "dense_bytes": cm.dense_bytes(),
                "measured_bytes": cm.measured_bytes(),
                "predicted_bytes": round(stored_bits(spec, cm.zero_frac()) / 8, 2),
                "measured_red_pct": round(
                    100 * (1 - cm.measured_bytes() / cm.dense_bytes()), 2),
                "predicted_red_pct": round(
                    reduced_bandwidth_pct([spec], [cm.zero_frac()]), 2),
            })
    rec = meter.reconcile()     # raises if any site breaks the padding bound
    for r in rows:
        derived = ";".join(f"{k}={v}" for k, v in r.items()
                           if k not in ("name", "us_per_call"))
        print(f"{r['name']},{r['us_per_call']:.1f},{derived}", flush=True)
    print(f"# reconcile: {rec['n_sites']} maps across {len(archs)} configs, "
          f"max |measured - predicted| = {rec['max_abs_delta_bytes']:.2f} B "
          f"(bound: index padding < 1 B/map)")
    return rows, rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="1 config x 3 thresholds, tiny maps (CI)")
    args = ap.parse_args()
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
