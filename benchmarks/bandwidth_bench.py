"""Predicted vs *measured* bandwidth reduction (the paper's headline
metric, Eq. 2/3, as an observable).

For each model config and each ``t_obj`` in the sweep, a block-structured
activation map is masked by the Pallas comparator, packed into the
``(bitmap, payload)`` stream, and the stream's actual byte count is
reconciled against ``stored_bits(spec, zero_frac)`` at the *measured*
zero-block fraction. The two must agree to within index-padding rounding
(< 1 byte per map) — that assertion runs on every invocation.

    PYTHONPATH=src python benchmarks/bandwidth_bench.py [--smoke] [--full]

Prints ``name,us_per_call,derived`` CSV per row (run.py convention).
"""
from __future__ import annotations

import argparse
import zlib

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.compress import BandwidthMeter, compress_masked, decompress
from repro.core import reduced_bandwidth_pct, stored_bits
from repro.kernels import zebra_mask_op

try:
    from .common import emit, timeit
except ImportError:                     # direct script run (CI smoke)
    from common import emit, timeit

# reduced-width archs whose d_ff is lane-aligned (K % 128 == 0)
ARCHS = ("gemma3-4b", "recurrentgemma-2b", "starcoder2-15b")
# block scales are ~U[0,1]; blockmax of 1024 normals is ~3.3, so this sweep
# lands zero fractions near {0, ~1/4, ~1/2, ~3/4, 1}
T_SWEEP = (0.0, 0.8, 1.65, 2.5, 1e9)


def _blocky_map(key, M, K, bs, bc, dtype):
    """Activations whose (bs, bc) blocks have uniform-random magnitudes."""
    x = jax.random.normal(key, (M, K), jnp.float32)
    scale = jax.random.uniform(jax.random.fold_in(key, 1), (M // bs, K // bc))
    x = x * jnp.repeat(jnp.repeat(scale, bs, 0), bc, 1)
    return x.astype(dtype)


def run_cnn(smoke: bool = False):
    """CNN forward with ``backend="stream"``: every ReLU site moves its
    NCHW map as a (bitmap, payload) stream through the site engine, and
    the per-site ``SiteAux.measured_bytes`` is reconciled against the
    Eq. 2/3 analytic prediction at the measured zero fraction. The two may
    differ only by index-byte padding (< 1 B per map); asserted per site.
    """
    from repro.core import MapSpec, ZebraConfig
    from repro.models.cnn import build as build_cnn

    B, hw = (1, 16) if smoke else (2, 32)
    sweep = (0.3,) if smoke else (0.1, 0.3, 0.8)
    model = build_cnn("vgg16", 10, hw, 0.125)
    key = jax.random.PRNGKey(0)
    variables = model.init(key, ZebraConfig(mode="infer"))
    x = jax.nn.relu(jax.random.normal(jax.random.fold_in(key, 1),
                                      (B, 3, hw, hw), jnp.float32))
    rows = []
    for t in sweep:
        zcfg = ZebraConfig(t_obj=t, mode="infer", backend="stream")
        _, _, auxes = model.apply(variables, x, False, zcfg)
        # time the jitted per-site sweep like the LM rows (the row used to
        # hard-code us_per_call=0.0 — the CNN forward was never timed)
        fwd = jax.jit(lambda xx: model.apply(variables, xx, False, zcfg)[0])
        us = timeit(fwd, x, iters=3 if smoke else 5, warmup=1)
        max_delta = 0.0
        measured_total = dense_total = 0.0
        for i, (aux, spec) in enumerate(zip(auxes, model.map_specs(hw, zcfg))):
            # fold the batch onto channels: per-forward spec at fp32 bits
            bspec = MapSpec(c=B * spec.c, h=spec.h, w=spec.w, bits=32,
                            block=spec.block)
            measured = float(aux["measured_bytes"])
            zf = float(aux["zero_frac"])
            predicted = stored_bits(bspec, zf) / 8.0
            delta = measured - predicted
            assert -1e-3 <= delta < 1.0 + 1e-3, (
                f"site z{i}: measured {measured} B vs predicted "
                f"{predicted:.2f} B breaks the index-padding bound")
            max_delta = max(max_delta, abs(delta))
            measured_total += measured
            dense_total += bspec.map_bits / 8.0
        rows.append({
            "name": f"bandwidth/cnn-vgg16/t_obj={t:g}",
            "us_per_call": us,
            "sites": len(auxes),
            "measured_bytes": int(measured_total),
            "dense_bytes": int(dense_total),
            "measured_red_pct": round(100 * (1 - measured_total / dense_total), 2),
            "max_site_delta_B": round(max_delta, 3),
        })
    print(f"# cnn stream reconcile: {len(rows)} t_obj points x "
          f"{rows[0]['sites']} sites, per-site |measured - predicted| < 1 B",
          flush=True)
    return rows


def run(smoke: bool = False, dtype=jnp.bfloat16):
    archs = ARCHS[:1] if smoke else ARCHS
    sweep = T_SWEEP[::2] if smoke else T_SWEEP
    batch, seq = (2, 32) if smoke else (4, 64)
    meter = BandwidthMeter()
    rows = []
    for arch in archs:
        cfg = configs.reduced(arch)
        bs, bc = cfg.zebra_block_seq, cfg.zebra_block_ch
        M, K = batch * seq, cfg.d_ff
        key = jax.random.PRNGKey(zlib.crc32(arch.encode()) & 0xFFFF)
        x = _blocky_map(key, M, K, bs, bc, dtype)
        for t in sweep:
            y, _ = zebra_mask_op(x, t, bs=bs, bc=bc)
            # two-phase producer: raw map -> stream, masked map never built
            cm = compress_masked(x, t, bs=bs, bc=bc)
            np.testing.assert_array_equal(          # transport is lossless
                np.asarray(decompress(cm)), np.asarray(y))
            r = meter.record(f"{arch}/t_obj={t:g}", cm)
            us = timeit(lambda: compress_masked(x, t, bs=bs, bc=bc).payload,
                        iters=5 if smoke else 9, warmup=2)
            spec = cm.spec()
            rows.append({
                "name": f"bandwidth/{arch}/t_obj={t:g}",
                "us_per_call": us,
                "zero_frac": round(cm.zero_frac(), 4),
                "dense_bytes": cm.dense_bytes(),
                "measured_bytes": cm.measured_bytes(),
                "predicted_bytes": round(stored_bits(spec, cm.zero_frac()) / 8, 2),
                "measured_red_pct": round(
                    100 * (1 - cm.measured_bytes() / cm.dense_bytes()), 2),
                "predicted_red_pct": round(
                    reduced_bandwidth_pct([spec], [cm.zero_frac()]), 2),
            })
    rec = meter.reconcile()     # raises if any site breaks the padding bound
    rows.extend(run_cnn(smoke))  # NCHW maps through the stream backend
    emit(rows, "bandwidth")     # CSV + BENCH_bandwidth.json in --json mode
    print(f"# reconcile: {rec['n_sites']} maps across {len(archs)} configs, "
          f"max |measured - predicted| = {rec['max_abs_delta_bytes']:.2f} B "
          f"(bound: index padding < 1 B/map)")
    return rows, rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="1 config x 3 thresholds, tiny maps (CI)")
    args = ap.parse_args()
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
