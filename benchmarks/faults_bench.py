"""Stream-integrity benchmark — BENCH_faults.json.

Two claims, measured at the paper's ~64%-zero-blocks operating point
(M, K, bs, bc = 256, 1024, 8, 128):

**Validation overhead is bounded** (``faults/validate.<level>`` rows):
the engine's stream pipeline is timed at every ``ZebraConfig.validation``
level. ``stream_bytes`` is emitted per row and asserted IDENTICAL across
levels in-bench — turning validation on must never change what the wire
carries — and the committed ``stream_bytes`` is drift-gated exactly by
``scripts/bench_gate.py`` like every other byte column.

**Detection is total** (``faults/detect.<boundary>.<kind>`` rows): every
(ingest boundary x fault class) pair of the chaos matrix is exercised
once with ``repro.ft.inject`` and must report ``detected == injected``
(100% detection) and ``recovered == 1`` (the per-boundary policy
restored a correct output: bitwise for stream transport / collectives /
serve / checkpoint, allclose for the fused GEMM whose dense-recompute
fallback accumulates in a different order). ``scripts/bench_gate.py``'s
``gate_faults`` enforces both columns absolutely — no baseline needed.

Boundaries covered: ``engine`` (in-graph producer->consumer stream),
``fused`` (in-graph stream feeding the compressed GEMM), ``serve`` (the
concrete prefill->decode CompressedMap handoff), ``ckpt`` (CRC-verified
step restore + compressed-acts restore), ``ring`` (8-device all-gather /
psum-stream hops). The ``value`` kind — a finite, nonzero payload flip —
is paired with ``level=checksum`` everywhere: it is exactly the fault
class structural invariants cannot see. Likewise ``ring.psum`` drop-hop
uses checksum: a zeroed union-capacity payload is structurally legal.

Standalone on purpose (NOT in ``benchmarks/run.py``'s smoke list): the
ring boundary needs the 8-device host platform forced via XLA_FLAGS
before jax imports, which a shared bench runner cannot guarantee.
``scripts/ci.sh`` runs it as its own chaos shard.
"""
from __future__ import annotations

import os

_FLAG = "--xla_force_host_platform_device_count=8"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = f"{os.environ.get('XLA_FLAGS', '')} {_FLAG}".strip()

import argparse
import functools
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from benchmarks.common import emit, set_json_dir, timeit
from repro.compress import integrity
from repro.core import ZebraConfig
from repro.core.engine import zebra_site
from repro.distributed import collectives as coll
from repro.ft import Fault, inject
from repro.launch.mesh import _make_mesh

M, K, N, BS, BC = 256, 1024, 512, 8, 128
ZERO_FRAC = 0.64            # the paper's operating point


def _operating_x(seed: int = 0) -> jax.Array:
    """(M, K) f32 map whose blocks survive t_obj=0.5 at ~ZERO_FRAC."""
    rng = np.random.default_rng(seed)
    keep = rng.random((M // BS, K // BC)) > ZERO_FRAC
    x = rng.uniform(0.6, 1.0, size=(M, K)).astype(np.float32)
    x *= np.repeat(np.repeat(keep, BS, 0), BC, 1)
    return jnp.asarray(x)


def _detect_row(name: str, level: str, injected: int, detected: int,
                recovered: bool, policy: str) -> dict:
    return {"name": name, "us_per_call": 0.0, "level": level,
            "injected": int(injected), "detected": int(detected),
            "recovered": int(bool(recovered)), "policy": policy}


# ---------------------------------------------------------------------------
# Overhead: the validated pipeline vs the untouched hot path
# ---------------------------------------------------------------------------

def bench_overhead(iters: int) -> list[dict]:
    x = _operating_x()
    rows, t_off = [], None
    for level in ("off", "structural", "checksum"):
        cfg = ZebraConfig(t_obj=0.5, mode="infer", backend="stream",
                          validation=level)
        f = jax.jit(lambda v, c=cfg: zebra_site(v, c, site="bench"))
        y, aux = f(x)
        us = timeit(f, x, iters=iters)
        t_off = t_off if t_off is not None else us
        zf = float(aux.zero_frac)
        rows.append({"name": f"faults/validate.{level}",
                     "us_per_call": us, "level": level,
                     "zero_frac": round(zf, 4),
                     "stream_bytes": int(aux.measured_bytes),
                     "overhead_vs_off": round(us / max(t_off, 1e-9), 3)})
    sb = {r["stream_bytes"] for r in rows}
    assert len(sb) == 1, f"validation changed the wire: stream_bytes {sb}"
    return rows


# ---------------------------------------------------------------------------
# Detection matrix, boundary by boundary
# ---------------------------------------------------------------------------

def bench_engine() -> list[dict]:
    """In-graph boundaries: stream transport and the fused consumer."""
    x = _operating_x(1)
    rows = []
    cases = [("bitflip", "structural"), ("truncate", "structural"),
             ("nan", "structural"), ("count", "structural"),
             ("value", "checksum")]
    for backend, bitwise in (("stream", True), ("fused", False)):
        w = (jax.random.normal(jax.random.PRNGKey(2), (K, N), jnp.float32)
             if backend == "fused" else None)
        for kind, level in cases:
            cfg = ZebraConfig(t_obj=0.5, mode="infer", backend=backend,
                              validation=level)
            clean, _ = zebra_site(x, cfg, site="b", w=w)
            integrity.clear_failures()
            with inject(Fault(kind=kind, site="engine:b", arg=3)) as plan:
                y, _ = zebra_site(x, cfg, site="b", w=w)
                jax.block_until_ready(y)
            yc, yf = np.asarray(clean), np.asarray(y)
            ok = (np.array_equal(yc, yf) if bitwise
                  else np.allclose(yc, yf, atol=1e-4, rtol=1e-4))
            rows.append(_detect_row(
                f"faults/detect.{backend}.{kind}", level,
                len(plan.injected), len(integrity.failures()), ok,
                "recompute-dense"))
    return rows


def bench_serve() -> list[dict]:
    """The concrete prefill->decode handoff: per-leaf dense fallback."""
    from repro.compress import compress_tree, decompress_tree
    from repro.launch.serve import validate_state_ingest
    rng = np.random.default_rng(4)
    keep = rng.random((M // BS, K // BC)) > ZERO_FRAC
    dense = {"k": jnp.asarray(
        rng.normal(size=(M, K)).astype(np.float32)
        * np.repeat(np.repeat(keep, BS, 0), BC, 1))}
    rows = []
    for kind, level in (("bitflip", "structural"), ("truncate", "structural"),
                        ("nan", "structural"), ("count", "structural"),
                        ("value", "checksum")):
        ctree = compress_tree(dense, bs=BS, bc=BC,
                              checksum=(level == "checksum"))
        with inject(Fault(kind=kind, site="serve", arg=2)) as plan:
            out, n_bad = validate_state_ingest(ctree, dense, level)
        got = decompress_tree(out)["k"]
        ok = np.array_equal(np.asarray(got), np.asarray(dense["k"]))
        rows.append(_detect_row(f"faults/detect.serve.{kind}", level,
                                len(plan.injected), n_bad, ok,
                                "recompute-dense"))
    return rows


def bench_ckpt() -> list[dict]:
    """On-disk boundary: CRC-verified restore with newest->older
    fallback, and the compressed-acts wire check."""
    from repro.checkpoint import CheckpointManager
    from repro.ft import CorruptStream, corrupt_file
    rows = []
    d = tempfile.mkdtemp(prefix="faults_bench_ckpt_")
    try:
        ckpt = CheckpointManager(d, keep_last=3)
        state = None
        for s in (2, 4):
            state = {"w": jnp.full((64, 64), float(s))}
            ckpt.save(s, state, {"loader_step": s})
        ckpt.wait()
        corrupt_file(os.path.join(d, "step_4", "shard_0.npz"))
        try:
            step, tree, _ = ckpt.restore(state)
            ok = step == 2 and float(np.asarray(tree["w"])[0, 0]) == 2.0
            detected = 1                    # fallback fired = CRC caught it
        except Exception:
            ok, detected = False, 0
        rows.append(_detect_row("faults/detect.ckpt.bitflip", "structural",
                                1, detected, ok, "restore-older"))

        acts = {"h": np.asarray(_operating_x(5))}
        ckpt.save_acts(1, acts, compressed=True, bs=BS, bc=BC)
        path = os.path.join(d, "acts_1.npz")
        data = dict(np.load(path).items())
        idx = np.array(data["h/index"])
        idx[0] ^= 1                          # popcount no longer matches
        data["h/index"] = idx
        np.savez(path, **data)
        try:
            ckpt.restore_acts(1)
            detected = 0
        except CorruptStream:
            detected = 1
        # recovery for acts = the step-checkpoint chain still restores
        rows.append(_detect_row("faults/detect.ckpt.acts_bitflip",
                                "structural", 1, detected, detected == 1,
                                "reject-named-invariant"))
    finally:
        shutil.rmtree(d, ignore_errors=True)
    return rows


def bench_ring() -> list[dict]:
    """Mesh boundary: a dropped ring hop on the 4-device model axis."""
    mesh = _make_mesh((2, 4), ("data", "model"))
    n = 4
    rng = np.random.default_rng(6)
    keep = rng.random((n, M // BS, K // BC)) > ZERO_FRAC
    sh = rng.normal(size=(n, M, K)).astype(np.float32) \
        * np.repeat(np.repeat(keep, BS, 1), BC, 2)
    X = jnp.asarray(sh.reshape(n * M, K))
    sm = functools.partial(coll.shard_map_compat, mesh=mesh,
                           in_specs=(P("model", None),))
    rows = []

    y_ref = jax.jit(sm(lambda x: lax.all_gather(x, "model", axis=0,
                                                tiled=True),
                       out_specs=P()))(X)
    for level in ("structural", "checksum"):
        def ag(x, lv=level):
            y, link = coll.zebra_all_gather(x, "model", bs=BS, bc=BC,
                                            tiled=True, validation=lv,
                                            site="bench")
            return y
        integrity.clear_failures()
        with inject(Fault(kind="drop_hop", site="ring:bench", arg=2)) as plan:
            y = jax.jit(sm(ag, out_specs=P()))(X)
            jax.block_until_ready(y)
        ok = np.array_equal(np.asarray(y), np.asarray(y_ref))
        rows.append(_detect_row(f"faults/detect.ring.drop_hop_{level}",
                                level, len(plan.injected),
                                min(len(integrity.failures()), 1), ok,
                                "dense-retry"))

    yp_ref = jax.jit(sm(lambda x: lax.psum(x, "model"),
                        out_specs=P("model", None)))(X)

    def ps(x):
        y, _, _ = coll.zebra_psum_stream(x, "model", bs=BS, bc=BC,
                                         validation="checksum", site="p")
        return y
    integrity.clear_failures()
    with inject(Fault(kind="drop_hop", site="ring:p", arg=1)) as plan:
        yp = jax.jit(sm(ps, out_specs=P("model", None)))(X)
        jax.block_until_ready(yp)
    ok = np.array_equal(np.asarray(yp), np.asarray(yp_ref))
    rows.append(_detect_row("faults/detect.ring.psum_drop_hop", "checksum",
                            len(plan.injected),
                            min(len(integrity.failures()), 1), ok,
                            "dense-retry"))
    return rows


def run(iters: int = 5) -> list[dict]:
    if len(jax.devices()) < 8:
        raise SystemExit(
            "faults_bench needs 8 host devices for its ring boundary; jax "
            "was imported before XLA_FLAGS could force them — run this "
            "module standalone (python -m benchmarks.faults_bench)")
    rows = bench_overhead(iters)
    rows += bench_engine()
    rows += bench_serve()
    rows += bench_ckpt()
    rows += bench_ring()
    bad = [r for r in rows if r["name"].startswith("faults/detect.")
           and (r["detected"] != r["injected"] or not r["recovered"])]
    assert not bad, f"chaos matrix holes: {[r['name'] for r in bad]}"
    emit(rows, "faults")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fewer timing iters (CI chaos shard)")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_faults.json to the CWD")
    args = ap.parse_args()
    if args.json:
        set_json_dir(os.getcwd())
    run(iters=3 if args.smoke else 10)


if __name__ == "__main__":
    main()
