"""Paper Table III: ResNet-18 on (syn-)Tiny-ImageNet, block size 8,
bandwidth reduction & top1/top5 across sparsity targets T_obj."""
from __future__ import annotations

from repro.data import SYN_TINYIMAGENET
from .common import emit, eval_row, train_cnn


def run(budget, quick=True) -> list[dict]:
    rows = []
    tobjs = (0.0, 0.2) if quick else (0.0, 0.1, 0.15, 0.2, 0.4)
    for t in tobjs:
        tr, state, _ = train_cnn("resnet18", SYN_TINYIMAGENET, t,
                                 budget, block_hw=8)
        r = {"name": f"table3/resnet18/t{t}", "t_obj": t, "block": 8}
        r.update(eval_row(tr, state, budget))
        rows.append(r)
    emit(rows, "table3")
    return rows
