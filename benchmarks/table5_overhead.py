"""Paper Table V + Eq. 2-5: required bandwidth, block-index overhead, and
Zebra compute overhead for ResNet-18 on both datasets. Pure accounting."""
from __future__ import annotations

from repro.core import ZebraConfig, index_overhead_pct, required_bandwidth_bytes
from repro.core.bandwidth import conv_flops, zebra_overhead_flops
from repro.models.cnn import build as build_cnn
from .common import emit


def run(budget=None, quick=True) -> list[dict]:
    rows = []
    for ds, hw, block, paper_mb, paper_ovh in (
            ("cifar10", 32, 4, 2.06, 0.2), ("tinyimagenet", 64, 8, 7.86, 0.04)):
        model = build_cnn("resnet18", 10, hw)           # full width for Table V
        zcfg = ZebraConfig(act_bits=8, block_hw=block)  # paper: 8-bit acts
        specs = model.map_specs(hw, zcfg)
        req = required_bandwidth_bytes(specs) / 2 ** 20
        ovh = index_overhead_pct(specs)
        rows.append({"name": f"table5/resnet18/{ds}",
                     "required_bandwidth_MB": round(req, 2),
                     "index_overhead_pct": round(ovh, 3),
                     "paper_MB": paper_mb, "paper_overhead_pct": paper_ovh})
    # Eq. 4/5 compute overhead for a representative conv layer. A float,
    # not a formatted string: the trajectory gate compares this field
    # numerically, and "4.07e-04" != 4.07e-04 byte-compares forever.
    r = zebra_overhead_flops(128, 16, 16) / conv_flops(128, 16, 16, 3, 128)
    rows.append({"name": "table5/zebra_flop_overhead",
                 "overhead_ratio": float(r), "negligible": bool(r < 1e-2)})
    emit(rows, "table5")
    return rows
