"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full|--smoke] [--json] \
        [--only table1,table5]

Prints ``name,us_per_call,derived`` CSV per row. Training-based tables use
reduced-width models on procedural data (offline container); Table V,
kernels and the roofline table are exact accounting.

``--smoke`` is the CI mode (scripts/ci.sh): import-check every bench
module and run the non-training benches (kernels, bandwidth incl. the CNN
stream reconciliation, roofline, table5) at toy sizes. ``--json``
additionally writes each bench's rows as ``BENCH_<name>.json`` at the
repo root (schema: benchmarks/common.py) — the accumulating perf
trajectory; CI fails if the kernel/bandwidth artifacts are missing or
malformed.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

SMOKE_BENCHES = ("table5", "kernels", "roofline", "bandwidth", "train")
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full grids + longer training budgets")
    ap.add_argument("--smoke", action="store_true",
                    help="CI: import-check all benches, run the exact-"
                         "accounting ones (no training) at toy sizes")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_<name>.json per bench at the repo "
                         "root (perf-trajectory artifacts)")
    ap.add_argument("--only", default=None,
                    help="comma list: table1,table2,table3,table4,table5,"
                         "kernels,roofline,bandwidth,train,serve")
    args = ap.parse_args()

    # importing every bench module IS the smoke import-check
    from . import (bandwidth_bench, kernel_bench, roofline, serve_bench,
                   table1_zero_blocks, table2_cifar, table3_tinyimagenet,
                   table4_ablation, table5_overhead, train_bench)
    from .common import FULL, QUICK, set_json_dir

    if args.json:
        set_json_dir(REPO_ROOT)

    budget = FULL if args.full else QUICK
    quick = not args.full
    benches = {
        "table5": lambda: table5_overhead.run(budget, quick),
        "kernels": lambda: kernel_bench.run(budget, quick),
        "roofline": lambda: roofline.run(budget, quick),
        "table1": lambda: table1_zero_blocks.run(budget),
        "table2": lambda: table2_cifar.run(budget, quick),
        "table3": lambda: table3_tinyimagenet.run(budget, quick),
        "table4": lambda: table4_ablation.run(budget, quick),
        "bandwidth": lambda: bandwidth_bench.run(smoke=quick or args.smoke),
        "train": lambda: train_bench.run(budget, quick),
        # NOT in SMOKE_BENCHES: the serving loop is a multi-second
        # end-to-end trace — ci.sh runs it as its own shard
        "serve": lambda: serve_bench.run(8 if quick else 24),
    }
    if args.only:
        only = args.only.split(",")
    elif args.smoke:
        only = list(SMOKE_BENCHES)
    else:
        only = list(benches)
    print("name,us_per_call,derived")
    t0 = time.time()
    for name in only:
        print(f"# --- {name} ---", flush=True)
        benches[name]()
    print(f"# total {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
