"""Paper Table I: % of zero blocks of ResNet-18, trained WITHOUT Zebra,
as a function of block size (2x2 / 4x4 / whole map). The paper's point:
plain ReLU sparsity yields very few *structured* zero blocks (24.7% /
7.9% / 1.1%), motivating the regularizer."""
from __future__ import annotations

import numpy as np

from repro.core import ZebraConfig
from repro.data import SYN_CIFAR10, image_batch
from .common import emit, train_cnn


def run(budget) -> list[dict]:
    tr, state, _ = train_cnn("resnet18", SYN_CIFAR10, t_obj=0.0,
                             budget=budget, zebra_on=False)
    rows = []
    for bs, label in ((2, "2x2"), (4, "4x4"), (32, "whole-map")):
        zcfg = ZebraConfig(t_obj=1e-6, block_hw=bs, mode="infer")
        imgs, labels = image_batch(tr.cfg.dataset, 64, 7777)
        variables = dict(state["variables"], zebra={})
        _, _, auxes = tr.model.apply(variables, imgs, False, zcfg)
        num = sum(float(a["zero_frac"]) * a["n_blocks"] for a in auxes)
        den = sum(a["n_blocks"] for a in auxes)
        rows.append({"name": f"table1/block_{label}",
                     "zero_block_pct": round(100 * num / den, 2),
                     "paper_resnet18_cifar": {"2x2": 24.7, "4x4": 7.9,
                                              "whole-map": 1.1}[label]})
    emit(rows, "table1")
    return rows
