"""Kernel microbenchmarks (interpret mode on CPU: correctness-grade timing;
the `derived` columns carry the structural numbers that matter on TPU —
bytes saved per call, MXU-block skip fraction, and for the fused-vs-
composed pairs the measured Pallas launch count, the grid coarseness of
the supertiled kernels and how many dense-map-sized transfers cross HBM
per site in the TPU design).

Fused-vs-composed pairs (the two-phase supertiled streaming engine vs
the legacy per-block pipelines; outputs asserted identical here):

  producer   zebra_mask_pack (two-phase parallel: supertiled comparator
             pass + scan + parallel pack; reads x twice, writes only the
             compressed stream — 2 dense crossings)
             vs zebra_mask -> zebra_pack (the dense masked map is
             written then re-read: 3 dense crossings)
  stream     zebra_mask_pack -> zebra_unpack (3 dense crossings: the
             expander writes the dense map once)
             vs zebra_mask -> zebra_pack -> zebra_unpack (4 crossings)
  consumer   zebra_mask_pack -> zebra_spmm_cs (supertiled GEMM consumes
             the payload — 2 dense crossings, the masked map never
             exists)
             vs zebra_mask -> zebra_spmm (write + re-read the masked
             map: 3 dense crossings)

`launches` is counted from the traced jaxpr (the structural contract
tests pin the same numbers), so the column tracks what actually runs on
this container. `speedup_vs_ref` on a fused row is composed_us/fused_us;
on the standalone kernel rows it is the row's jnp reference time over
the kernel time.

`speedup_vs_dense` is the headline the CI gate enforces (>1 at the
paper's ~64%-zeros operating point): on `kernel/zebra_spmm` it is the
plain dense matmul time over the consumer time (the misnamed
`speedup_vs_ref` alias that rode along one release is gone; the gate's
baseline comparison tolerates old baselines that still carry it); on
the `spmm_cs` pair rows it is
the single-jit mask+dense-matmul pipeline (`dense_pipeline_us` — what
the fused site replaces end to end) over the row time, with the plain
`dense_matmul_us` also emitted so both denominators stay transparent.
The consumers run their scheduled form (static prefetch schedule over
the consumer-ordered payload + the cached `gemm_plan` capacity ladder,
`consumer_form`/`caps` columns) — the rearchitecture that turned
`speedup_vs_ref 0.14` into a win.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ZebraConfig
from repro.core.engine import stream_bytes
from repro.kernels import (zebra_mask_op, zebra_mask_pack_op, zebra_pack_op,
                           zebra_spmm_cs_op, zebra_spmm_op, zebra_unpack_op)
from repro.kernels import ref
from .common import emit, timeit


def _launch_info(fn, *args):
    """(launch count, [grid sizes]) measured from the traced jaxpr —
    counted by repro.utils.pallas_eqns, the same walker the structural
    contract tests use, so the benched and tested numbers cannot drift."""
    from repro.utils import pallas_grids
    grids = pallas_grids(jax.make_jaxpr(fn)(*args).jaxpr)
    return len(grids), [list(g) for g in grids]


def _pair_rows(name, fused_fn, composed_fn, fused_meta, composed_meta,
               iters=5):
    t_f = timeit(fused_fn, iters=iters)
    t_c = timeit(composed_fn, iters=iters)
    lf, gf = _launch_info(fused_fn)
    lc, gc = _launch_info(composed_fn)
    f = {"name": f"kernel/{name}.fused", "us_per_call": t_f,
         "pair": name, "variant": "fused", "launches": lf, "grids": gf,
         "grid_steps": int(sum(np.prod(g) for g in gf)),
         "speedup_vs_ref": round(t_c / t_f, 2), **fused_meta}
    c = {"name": f"kernel/{name}.composed", "us_per_call": t_c,
         "pair": name, "variant": "composed", "launches": lc, "grids": gc,
         "grid_steps": int(sum(np.prod(g) for g in gc)),
         "speedup_vs_ref": 1.0, **composed_meta}
    return [f, c]


def run(budget=None, quick=True) -> list[dict]:
    rows = []
    M, K, N, bs, bc = 256, 1024, 512, 8, 128
    # the paper's operating point: ~64% zero blocks (live < 0.4 draws)
    zf_hint = 0.64
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (M, K), jnp.float32)
    live = (jax.random.uniform(jax.random.PRNGKey(1), (M // bs, K // bc)) < 0.4)
    x = x * jnp.repeat(jnp.repeat(live.astype(jnp.float32), bs, 0), bc, 1) * 2 + x * 0.01
    w = jax.random.normal(jax.random.PRNGKey(2), (K, N), jnp.float32)
    cfg = ZebraConfig(mode="infer", zero_frac_hint=zf_hint)
    plan = cfg.gemm_plan_for(M, K, bs, bc, x.dtype, n=N)
    stm, stk, bn = plan.stm, plan.stk, plan.bn

    t_ref = timeit(lambda: ref.zebra_mask_ref(x, 0.5, bs, bc), iters=20)
    t_ker = timeit(lambda: zebra_mask_op(x, 0.5, bs=bs, bc=bc), iters=5)
    y, bm = zebra_mask_op(x, 0.5, bs=bs, bc=bc)
    zf = 1 - float(np.mean(np.asarray(bm)))
    saved = zf * M * K * 2                                  # bf16 bytes saved
    rows.append({"name": "kernel/zebra_mask", "us_per_call": t_ker,
                 "ref_us": round(t_ref, 1),
                 "speedup_vs_ref": round(t_ref / t_ker, 2),
                 "zero_frac": round(zf, 3),
                 "hbm_bytes_saved_per_call": int(saved),
                 "index_bytes": (M // bs) * (K // bc)})

    t_spmm = timeit(lambda: zebra_spmm_op(x, w, bm, bs=bs, bc=bc,
                                          zero_frac_hint=zf_hint), iters=5)
    t_dense = timeit(lambda: (x @ w), iters=20)
    rows.append({"name": "kernel/zebra_spmm", "us_per_call": t_spmm,
                 "dense_matmul_us": round(t_dense, 1),
                 "speedup_vs_dense": round(t_dense / t_spmm, 2),
                 "zero_frac": round(zf, 3),
                 "supertile": [stm, stk, bn],
                 "consumer_form": "scheduled", "caps": list(plan.caps),
                 "mxu_blocks_skipped_frac": round(zf, 3),
                 "flops_skipped": int(zf * 2 * M * K * N)})

    # ---- fused vs composed: the two-phase supertiled streaming engine ----
    payload_f, bm_f, n_live = zebra_mask_pack_op(x, 0.5, bs=bs, bc=bc)
    payload_c, n_live_c = zebra_pack_op(y, bm, bs=bs, bc=bc)
    np.testing.assert_array_equal(np.asarray(payload_f), np.asarray(payload_c))
    assert int(n_live) == int(n_live_c)
    # the engine's ONE byte-accounting rule, not a private re-derivation
    dense_b = M * K * jnp.dtype(x.dtype).itemsize
    stream_b = int(stream_bytes(n_live, bs, bc, x.dtype, bm_f.size))

    rows += _pair_rows(
        "mask_pack",
        lambda: zebra_mask_pack_op(x, 0.5, bs=bs, bc=bc)[0],
        lambda: zebra_pack_op(zebra_mask_op(x, 0.5, bs=bs, bc=bc)[0],
                              bm, bs=bs, bc=bc)[0],
        {"dense_map_hbm_crossings": 2,
         "dense_bytes_crossed": 2 * dense_b, "stream_bytes": stream_b},
        {"dense_map_hbm_crossings": 3,
         "dense_bytes_crossed": 3 * dense_b, "stream_bytes": stream_b})

    y_stream_f = zebra_unpack_op(payload_f, bm_f, bs=bs, bc=bc)
    np.testing.assert_array_equal(np.asarray(y_stream_f), np.asarray(y))
    rows += _pair_rows(
        "stream",
        lambda: zebra_unpack_op(zebra_mask_pack_op(x, 0.5, bs=bs, bc=bc)[0],
                                bm_f, bs=bs, bc=bc),
        lambda: zebra_unpack_op(
            zebra_pack_op(zebra_mask_op(x, 0.5, bs=bs, bc=bc)[0],
                          bm, bs=bs, bc=bc)[0], bm, bs=bs, bc=bc),
        {"dense_map_hbm_crossings": 3,
         "dense_bytes_crossed": 3 * dense_b, "stream_bytes": stream_b},
        {"dense_map_hbm_crossings": 4,
         "dense_bytes_crossed": 4 * dense_b, "stream_bytes": stream_b})

    y_cs = zebra_spmm_cs_op(payload_f, w, bm_f, bs=bs, bc=bc,
                            zero_frac_hint=zf_hint)
    y_sp = zebra_spmm_op(y, w, bm, bs=bs, bc=bc, zero_frac_hint=zf_hint)
    np.testing.assert_array_equal(np.asarray(y_cs), np.asarray(y_sp))
    # what the fused site replaces end to end: ONE jit of comparator mask
    # + dense matmul (the denominator of the pair rows' speedup_vs_dense)
    dense_pipeline = jax.jit(
        lambda xx: ref.zebra_mask_ref(xx, 0.5, bs, bc)[0] @ w)
    t_pipeline = timeit(lambda: dense_pipeline(x), iters=5)
    fused_rows = _pair_rows(
        "spmm_cs",
        lambda: zebra_spmm_cs_op(zebra_mask_pack_op(x, 0.5, bs=bs, bc=bc)[0],
                                 w, bm_f, bs=bs, bc=bc,
                                 zero_frac_hint=zf_hint),
        lambda: zebra_spmm_op(zebra_mask_op(x, 0.5, bs=bs, bc=bc)[0],
                              w, bm, bs=bs, bc=bc, zero_frac_hint=zf_hint),
        {"dense_map_hbm_crossings": 2, "supertile": [stm, stk, bn],
         "consumer_form": "scheduled", "caps": list(plan.caps),
         "zero_frac": round(zf, 3),
         "dense_bytes_crossed": 2 * dense_b, "stream_bytes": stream_b},
        {"dense_map_hbm_crossings": 3, "supertile": [stm, stk, bn],
         "consumer_form": "scheduled", "caps": list(plan.caps),
         "zero_frac": round(zf, 3),
         "dense_bytes_crossed": 3 * dense_b, "stream_bytes": stream_b})
    for r in fused_rows:
        r["dense_matmul_us"] = round(t_dense, 1)
        r["dense_pipeline_us"] = round(t_pipeline, 1)
        r["speedup_vs_dense"] = round(t_pipeline / r["us_per_call"], 2)
    rows += fused_rows

    emit(rows, "kernels")
    return rows
