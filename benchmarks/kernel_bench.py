"""Kernel microbenchmarks (interpret mode on CPU: correctness-grade timing;
the `derived` column carries the structural numbers that matter on TPU —
bytes saved per call and MXU-block skip fraction)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import zebra_mask_op, zebra_spmm_op
from repro.kernels import ref
from .common import emit, timeit


def run(budget=None, quick=True) -> list[dict]:
    rows = []
    M, K, N, bs, bc = 256, 1024, 512, 8, 128
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (M, K), jnp.float32)
    live = (jax.random.uniform(jax.random.PRNGKey(1), (M // bs, K // bc)) < 0.4)
    x = x * jnp.repeat(jnp.repeat(live.astype(jnp.float32), bs, 0), bc, 1) * 2 + x * 0.01
    w = jax.random.normal(jax.random.PRNGKey(2), (K, N), jnp.float32)

    t_ref = timeit(lambda: ref.zebra_mask_ref(x, 0.5, bs, bc), iters=20)
    t_ker = timeit(lambda: zebra_mask_op(x, 0.5, bs=bs, bc=bc), iters=5)
    y, bm = zebra_mask_op(x, 0.5, bs=bs, bc=bc)
    zf = 1 - float(np.mean(np.asarray(bm)))
    saved = zf * M * K * 2                                  # bf16 bytes saved
    rows.append({"name": "kernel/zebra_mask", "us_per_call": t_ker,
                 "ref_us": round(t_ref, 1), "zero_frac": round(zf, 3),
                 "hbm_bytes_saved_per_call": int(saved),
                 "index_bytes": (M // bs) * (K // bc)})

    t_spmm = timeit(lambda: zebra_spmm_op(x, w, bm, bs=bs, bc=bc), iters=3)
    t_dense = timeit(lambda: (x @ w), iters=20)
    rows.append({"name": "kernel/zebra_spmm", "us_per_call": t_spmm,
                 "dense_matmul_us": round(t_dense, 1),
                 "mxu_blocks_skipped_frac": round(zf, 3),
                 "flops_skipped": int(zf * 2 * M * K * N)})
    emit(rows, "kernels")
    return rows
