"""Shared benchmark utilities: timing + tiny-training harness + the
``BENCH_*.json`` perf-trajectory writer.

All paper-table benchmarks train *reduced-width* models on the procedural
datasets (offline container, DESIGN.md §6) — table structure and trends
reproduce the paper; absolute accuracies are synthetic-data numbers.

JSON mode (``benchmarks/run.py --json`` -> :func:`set_json_dir`): every
``emit(rows, name)`` additionally writes ``BENCH_<name>.json`` to the
configured directory (the repo root in CI) so successive runs accumulate a
machine-readable perf trajectory. Schema (version 1)::

    {"bench": <name>, "schema_version": 1, "generated_unix": <epoch s>,
     "rows": [{"name": str, "us_per_call": float, ...derived columns}]}

Every other key of a row dict is a bench-specific derived column (plain
JSON scalars; numpy/jax values are converted).
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.core import ZebraConfig
from repro.data import ImageDatasetConfig, SYN_CIFAR10, SYN_TINYIMAGENET
from repro.optim import sgd, step_decay
from repro.train import CNNTrainer, CNNTrainConfig

QUICK = {"steps": 80, "width": 0.125, "batch": 32, "eval_batches": 2}
FULL = {"steps": 600, "width": 0.5, "batch": 64, "eval_batches": 8}


def timeit(fn, *args, iters: int = 10, warmup: int = 2) -> float:
    """Median us per call: ``warmup`` untimed calls (compile + cache
    warm), then the median of ``iters`` individually-timed calls, each
    synchronized with ``block_until_ready``. Median-of-N instead of
    mean-of-one-batch: a single GC pause or scheduler hiccup lands in
    one sample, not in the row — the old mean made fused-vs-composed
    deltas at the few-percent level pure jitter."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    n = len(times)
    mid = times[n // 2] if n % 2 else (times[n // 2 - 1] + times[n // 2]) / 2
    return mid * 1e6


def train_cnn(model: str, dataset: ImageDatasetConfig, t_obj: float,
              budget: dict, zebra_on: bool = True, ns_rho: float = 0.0,
              block_hw: int = 4, seed: int = 0):
    zcfg = ZebraConfig(enabled=zebra_on, t_obj=t_obj, block_hw=block_hw)
    cfg = CNNTrainConfig(model=model, width_mult=budget["width"],
                         dataset=dataset, batch=budget["batch"],
                         steps=budget["steps"], zebra=zcfg, ns_rho=ns_rho,
                         seed=seed)
    tr = CNNTrainer(cfg, sgd(step_decay(0.05, total_steps=budget["steps"])))
    state, hist = tr.train(log_every=max(budget["steps"] // 3, 1))
    return tr, state, hist


def eval_row(tr, state, budget):
    ev = tr.evaluate(state["variables"], batches=budget["eval_batches"],
                     batch=64)
    return {"reduced_bandwidth_pct": round(ev["reduced_bandwidth_pct"], 1),
            "acc_pct": round(100 * ev["acc"], 2),
            "top5_pct": round(100 * ev["top5"], 2),
            "zero_frac": round(ev["zero_frac"], 3)}


_JSON_DIR: str | None = None


def set_json_dir(path: str | None) -> None:
    """Enable (or disable with None) BENCH_<name>.json emission."""
    global _JSON_DIR
    _JSON_DIR = path


def _jsonable(v):
    """Coerce numpy/jax scalars (and containers thereof) to JSON scalars."""
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if hasattr(v, "item") and np.ndim(v) == 0:
        return v.item()
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)


def emit(rows, name):
    """Print one benchmark's rows as the required CSV; in JSON mode also
    write them as BENCH_<name>.json (the perf-trajectory artifact)."""
    for r in rows:
        derived = ";".join(f"{k}={v}" for k, v in r.items()
                           if k not in ("name", "us_per_call"))
        print(f"{r.get('name', name)},{r.get('us_per_call', 0):.1f},{derived}",
              flush=True)
    if _JSON_DIR is not None:
        doc = {"bench": name, "schema_version": 1,
               "generated_unix": int(time.time()),
               "rows": [{k: _jsonable(v) for k, v in r.items()}
                        for r in rows]}
        path = os.path.join(_JSON_DIR, f"BENCH_{name}.json")
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
            f.write("\n")
