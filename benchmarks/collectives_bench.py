"""Compressed-collectives benchmark — BENCH_collectives.json.

Per mesh axis of an 8-device forced-host mesh (2 "data" x 4 "model"),
times and byte-accounts the three compressed collectives of
``repro.distributed.collectives`` against their dense counterparts at
the paper's ~64%-zero-blocks operating point:

  collectives/all_gather.<axis>.{compressed,dense}
  collectives/psum_stream.<axis>.{compressed,dense}
  collectives/reduce_scatter.<axis>.{compressed,dense}

Byte columns (the CI gate's exact contract, ``scripts/bench_gate.py``):

  ici_bytes            int — bytes moved over ALL inbound links of the
                       axis for one collective (sum across the n shards'
                       links; compressed = live stream form)
  ici_dense_bytes      int — dense-equivalent bytes over the same links
  ici_predicted_bytes  int — the Eq. 2/3 analytic prediction computed
                       host-side from the known per-shard bitmaps; the
                       gate enforces ici_bytes == ici_predicted_bytes
                       EXACTLY and ici_bytes < ici_dense_bytes on every
                       compressed row

The bench also asserts correctness in-line: the compressed all-gather is
bitwise-equal to ``lax.all_gather`` of the dense masked shards, and the
payload-form psum matches ``lax.psum`` bitwise on integer-valued data
(same-order summation guarantee).

Standalone on purpose (NOT in ``benchmarks/run.py``'s smoke list): the
8-device host platform must be forced via XLA_FLAGS before jax imports,
which a shared bench runner cannot guarantee. ``scripts/ci.sh`` runs it
as its own shard.
"""
from __future__ import annotations

import os

_FLAG = "--xla_force_host_platform_device_count=8"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = f"{os.environ.get('XLA_FLAGS', '')} {_FLAG}".strip()

import argparse
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from benchmarks.common import emit, set_json_dir, timeit
from repro.distributed import collectives as coll
from repro.launch.mesh import _make_mesh

# one per-device shard: (M, K) f32 map in (bs, bc) = (8, 128) blocks
M, K, BS, BC = 256, 1024, 8, 128
NM, NK = M // BS, K // BC
NB = NM * NK
ITEM = 4
ZERO_FRAC = 0.64            # the paper's operating point


def _stream(n_live: int) -> int:
    """Eq. 2/3 byte rule for one shard map (core.engine.stream_bytes)."""
    return n_live * BS * BC * ITEM + (NB + 7) // 8


def _make_shards(n: int, seed: int) -> np.ndarray:
    """(n, M, K) integer-valued f32 shards with ~ZERO_FRAC zero blocks
    (integer values: the ring psum's accumulation order then matches
    lax.psum bitwise)."""
    rng = np.random.default_rng(seed)
    keep = (rng.random((n, NM, NK)) > ZERO_FRAC).astype(np.float32)
    vals = rng.integers(-8, 9, size=(n, M, K)).astype(np.float32)
    mask = np.repeat(np.repeat(keep, BS, axis=1), BC, axis=2)
    return vals * mask


def _bench_axis(mesh, axis: str, n: int, iters: int) -> list[dict]:
    # fixed per-axis seeds: the byte columns are bit-exact gate contracts,
    # so the drawn bitmaps must be identical run to run
    shards = _make_shards(n, seed={"model": 7, "data": 11}[axis])
    live = [int((np.abs(shards[s]).reshape(NM, BS, NK, BC)
                 .max(axis=(1, 3)) > 0).sum()) for s in range(n)]
    zf = 1.0 - sum(live) / (n * NB)
    X = jnp.asarray(shards.reshape(n * M, K))
    in_spec = P(axis, None)
    sm = functools.partial(coll.shard_map_compat, mesh=mesh,
                           in_specs=(in_spec,))

    def tot(v):          # replicated total over the axis's inbound links
        return lax.psum(jnp.asarray(v).astype(jnp.int32), axis)

    # ---- all_gather ----
    def ag_comp(x):
        y, link = coll.zebra_all_gather(x, axis, bs=BS, bc=BC, tiled=True)
        return y, tot(link.moved), tot(link.dense)

    def ag_dense(x):
        return lax.all_gather(x, axis, axis=0, tiled=True)

    f_comp = jax.jit(sm(ag_comp, out_specs=(P(), P(), P())))
    f_dense = jax.jit(sm(ag_dense, out_specs=P()))
    y_c, moved, dense = f_comp(X)
    y_d = f_dense(X)
    np.testing.assert_array_equal(np.asarray(y_c), np.asarray(y_d))
    np.testing.assert_array_equal(np.asarray(y_d), np.asarray(X))
    pred = (n - 1) * sum(_stream(lv) for lv in live)
    rows = [
        {"name": f"collectives/all_gather.{axis}.compressed",
         "us_per_call": timeit(f_comp, X, iters=iters),
         "axis": axis, "n_shards": n, "zero_frac": round(zf, 4),
         "ici_bytes": int(moved), "ici_dense_bytes": int(dense),
         "ici_predicted_bytes": pred},
        {"name": f"collectives/all_gather.{axis}.dense",
         "us_per_call": timeit(f_dense, X, iters=iters),
         "axis": axis, "n_shards": n, "zero_frac": round(zf, 4),
         "ici_bytes": n * (n - 1) * M * K * ITEM,
         "ici_dense_bytes": n * (n - 1) * M * K * ITEM,
         "ici_predicted_bytes": n * (n - 1) * M * K * ITEM},
    ]
    assert int(moved) == pred, (int(moved), pred)
    assert int(moved) < int(dense), (int(moved), int(dense))

    # ---- psum_stream ----
    union = (np.abs(shards).reshape(n, NM, BS, NK, BC).max(axis=(2, 4))
             > 0).any(axis=0)
    u_live = int(union.sum())

    def ps_comp(x):
        y, _, link = coll.zebra_psum_stream(x, axis, bs=BS, bc=BC)
        return y, tot(link.moved), tot(link.dense)

    def ps_dense(x):
        return lax.psum(x, axis)

    f_comp = jax.jit(sm(ps_comp, out_specs=(in_spec, P(), P())))
    f_dense = jax.jit(sm(ps_dense, out_specs=in_spec))
    y_c, moved, dense = f_comp(X)
    y_d = f_dense(X)
    np.testing.assert_array_equal(np.asarray(y_c), np.asarray(y_d))
    pred = n * (n - 1) * _stream(u_live)
    rows += [
        {"name": f"collectives/psum_stream.{axis}.compressed",
         "us_per_call": timeit(f_comp, X, iters=iters),
         "axis": axis, "n_shards": n, "zero_frac": round(zf, 4),
         "ici_bytes": int(moved), "ici_dense_bytes": int(dense),
         "ici_predicted_bytes": pred},
        {"name": f"collectives/psum_stream.{axis}.dense",
         "us_per_call": timeit(f_dense, X, iters=iters),
         "axis": axis, "n_shards": n, "zero_frac": round(zf, 4),
         "ici_bytes": n * (n - 1) * M * K * ITEM,
         "ici_dense_bytes": n * (n - 1) * M * K * ITEM,
         "ici_predicted_bytes": n * (n - 1) * M * K * ITEM},
    ]
    assert int(moved) == pred, (int(moved), pred)
    assert int(moved) < int(dense), (int(moved), int(dense))

    # ---- reduce_scatter ----
    Ml = M // n
    chunk_live = [int(union.reshape(n, (Ml // BS), NK)[c].sum())
                  for c in range(n)]

    def rs_comp(x):
        y, link = coll.zebra_reduce_scatter(x, axis, bs=BS, bc=BC)
        return y, tot(link.moved), tot(link.dense)

    def rs_dense(x):
        return lax.psum_scatter(x, axis, scatter_dimension=0, tiled=True)

    out_rows = P(axis, None)
    f_comp = jax.jit(sm(rs_comp, out_specs=(out_rows, P(), P())))
    f_dense = jax.jit(sm(rs_dense, out_specs=out_rows))
    y_c, moved, dense = f_comp(X)
    y_d = f_dense(X)
    np.testing.assert_array_equal(np.asarray(y_c), np.asarray(y_d))

    def _chunk_stream(lv):
        return lv * BS * BC * ITEM + ((Ml // BS) * NK + 7) // 8

    pred = (n - 1) * sum(_chunk_stream(lv) for lv in chunk_live)
    rows += [
        {"name": f"collectives/reduce_scatter.{axis}.compressed",
         "us_per_call": timeit(f_comp, X, iters=iters),
         "axis": axis, "n_shards": n, "zero_frac": round(zf, 4),
         "ici_bytes": int(moved), "ici_dense_bytes": int(dense),
         "ici_predicted_bytes": pred},
        {"name": f"collectives/reduce_scatter.{axis}.dense",
         "us_per_call": timeit(f_dense, X, iters=iters),
         "axis": axis, "n_shards": n, "zero_frac": round(zf, 4),
         "ici_bytes": n * (n - 1) * Ml * K * ITEM,
         "ici_dense_bytes": n * (n - 1) * Ml * K * ITEM,
         "ici_predicted_bytes": n * (n - 1) * Ml * K * ITEM},
    ]
    assert int(moved) == pred, (int(moved), pred)
    assert int(moved) < int(dense), (int(moved), int(dense))
    return rows


def run(iters: int = 5) -> list[dict]:
    if len(jax.devices()) < 8:
        raise SystemExit(
            "collectives_bench needs 8 host devices; jax was imported "
            "before XLA_FLAGS could force them — run this module "
            "standalone (python -m benchmarks.collectives_bench)")
    mesh = _make_mesh((2, 4), ("data", "model"))
    rows = []
    for axis, n in (("model", 4), ("data", 2)):
        rows += _bench_axis(mesh, axis, n, iters)
    emit(rows, "collectives")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fewer timing iters (CI shard)")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_collectives.json to the CWD")
    args = ap.parse_args()
    if args.json:
        set_json_dir(os.getcwd())
    run(iters=3 if args.smoke else 10)


if __name__ == "__main__":
    main()
