"""Paper Table II: bandwidth reduction vs accuracy on (syn-)CIFAR-10 for
VGG16 / ResNet-18 / ResNet-56 / MobileNet across T_obj, incl. WP/NS
combinations. Quick mode runs a representative subset of rows."""
from __future__ import annotations

from repro.data import SYN_CIFAR10
from .common import emit, eval_row, train_cnn


def _row(model, t_obj, budget, tag, **kw):
    tr, state, _ = train_cnn(model, SYN_CIFAR10, t_obj, budget, **kw)
    r = {"name": f"table2/{model}/{tag}", "t_obj": t_obj}
    r.update(eval_row(tr, state, budget))
    return r


def _combo_row(model, t_obj, budget, method, frac):
    """WP/NS combos per paper §III.A: prune a trained model, retrain w/ Zebra."""
    tr, state, _ = train_cnn(model, SYN_CIFAR10, t_obj, budget,
                             ns_rho=1e-4 if method == "ns" else 0.0)
    if method == "wp":
        sp = tr.apply_weight_pruning(state["variables"], frac)
    else:
        sp = tr.apply_network_slimming(state["variables"], frac)
    state, _ = tr.train(steps=budget["steps"] // 2, state=state,
                        log_every=budget["steps"])
    r = {"name": f"table2/{model}/zebra+{method}{int(frac*100)}",
         "t_obj": t_obj, "pruned_frac": round(sp, 3)}
    r.update(eval_row(tr, state, budget))
    return r


def run(budget, quick=True) -> list[dict]:
    rows = []
    grid = ([("vgg16", (0.0, 0.1)), ("resnet18", (0.0, 0.2)),
             ("resnet56", (0.05,)), ("mobilenet", (0.1,))] if quick else
            [("vgg16", (0.0, 0.05, 0.1, 0.15)),
             ("resnet18", (0.0, 0.1, 0.2)),
             ("resnet56", (0.0, 0.05, 0.15)),
             ("mobilenet", (0.0, 0.1, 0.15))])
    for model, tobjs in grid:
        for t in tobjs:
            rows.append(_row(model, t, budget, f"t{t}"))
    # one WP and one NS combination row (paper: +NS helps, +WP doesn't)
    rows.append(_combo_row("resnet18", 0.2, budget, "ns", 0.2))
    rows.append(_combo_row("resnet18", 0.2, budget, "wp", 0.2))
    emit(rows, "table2")
    return rows
